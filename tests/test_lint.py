"""Fixture corpus for the repro.lint static-analysis pass.

Each rule RL001-RL006 gets at least one true-positive (including the
literal pre-PR-8 regressions the rules were distilled from), one
true-negative, and one pragma-suppressed case; plus engine/pragma tests
and a meta-test asserting the shipped tree lints clean.

Fixtures are linted under fake paths (``src/repro/core/x.py``) because
RL001/RL002 scope themselves to numerics-contract modules by path.
"""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.lint import lint_source, registered_rules

CORE = "src/repro/core/fixture.py"      # in-scope path for RL001/RL002
SERVING = "src/repro/serving/fixture.py"  # out of RL001/RL002 scope

REPO = Path(__file__).resolve().parent.parent


def codes(violations):
    return sorted(v.code for v in violations)


def lint(src, path=CORE, only=None):
    return [v for v in lint_source(src, path)
            if only is None or v.code == only]


# ---------------------------------------------------------------------------
# RL001 contraction hazard
# ---------------------------------------------------------------------------

# The literal pre-PR-8 split edge-weight form whose FMA contraction flipped
# argmin ties (fixed to (d + Q) * inv in shortest_path.layer_edge_weights).
PRE_PR8_SPLIT_FORM = """
import jax, jax.numpy as jnp

@jax.jit
def layer_edge_weights(d, Q, inv):
    w = d * inv + Q * inv
    return jnp.minimum(w, 1e30)
"""

FUSED_FORM = """
import jax, jax.numpy as jnp

@jax.jit
def layer_edge_weights(d, Q, inv):
    w = (d + Q) * inv
    return jnp.minimum(w, 1e30)
"""


def test_rl001_flags_pre_pr8_split_form():
    vs = lint(PRE_PR8_SPLIT_FORM, only="RL001")
    assert len(vs) >= 1
    assert "FMA" in vs[0].message


def test_rl001_passes_fused_form():
    assert lint(FUSED_FORM, only="RL001") == []


def test_rl001_ignores_host_code():
    host = PRE_PR8_SPLIT_FORM.replace("@jax.jit\n", "")
    assert lint(host, only="RL001") == []


def test_rl001_ignores_non_numerics_modules():
    assert lint(PRE_PR8_SPLIT_FORM, path=SERVING, only="RL001") == []


def test_rl001_ignores_integer_muladd():
    src = """
import jax

@jax.jit
def f(x, j, n_jobs):
    slot = j * n_jobs + 3
    return x[slot]
"""
    assert lint(src, only="RL001") == []


def test_rl001_pragma_suppressed():
    src = PRE_PR8_SPLIT_FORM.replace(
        "    w = d * inv + Q * inv",
        "    # repro-lint: disable=RL001 -- fixture justification\n"
        "    w = d * inv + Q * inv")
    assert lint(src, only="RL001") == []


def test_rl001_fires_in_scan_body_without_jit():
    # lax.scan traces its body even from eager code
    src = """
import jax, jax.numpy as jnp

def solve(xs, inv):
    def step(c, x):
        c = c * inv + x
        return c, c
    return jax.lax.scan(step, jnp.float32(0), xs)
"""
    assert codes(lint(src, only="RL001")) == ["RL001"]


# ---------------------------------------------------------------------------
# RL002 unsafe unroll
# ---------------------------------------------------------------------------

# An unroll=8 DP scan whose body carries the multiply-add chain — the
# hoisting that changed golden values in PR 8.
UNROLLED_DP = """
import jax, jax.numpy as jnp

def dp_forward(g0, xs, cinv, nw):
    def step(g, xs):
        c_l, t_prev = xs
        moved = jnp.min(g[:, None] + t_prev, axis=0) + nw
        new_g = jnp.minimum(g, moved) + c_l * cinv
        return new_g, new_g
    return jax.lax.scan(step, g0, xs, unroll=8)
"""

SAFE_UNROLL = """
import jax, jax.numpy as jnp

def reconstruct(bp, u0):
    def step(u, b):
        nxt = b[u]
        return nxt, nxt
    return jax.lax.scan(step, u0, bp, reverse=True, unroll=8)
"""


def test_rl002_flags_unrolled_contraction_body():
    vs = lint(UNROLLED_DP, only="RL002")
    assert len(vs) == 1
    assert "unroll" in vs[0].message


def test_rl002_passes_gather_only_unroll():
    assert lint(SAFE_UNROLL, only="RL002") == []


def test_rl002_passes_unroll_one():
    assert lint(UNROLLED_DP.replace("unroll=8", "unroll=1"),
                only="RL002") == []


def test_rl002_flags_nonliteral_unroll():
    vs = lint(UNROLLED_DP.replace("unroll=8", "unroll=n"), only="RL002")
    assert len(vs) == 1
    assert "non-literal" in vs[0].message


def test_rl002_pragma_suppressed():
    src = UNROLLED_DP.replace(
        "    return jax.lax.scan(step, g0, xs, unroll=8)",
        "    # repro-lint: disable=RL002 -- fixture justification\n"
        "    return jax.lax.scan(step, g0, xs, unroll=8)")
    assert lint(src, only="RL002") == []


# ---------------------------------------------------------------------------
# RL003 host sync in device code
# ---------------------------------------------------------------------------

HOST_SYNC_IN_JIT = """
import jax, numpy as np

@jax.jit
def solve(x):
    peek = float(x[0])
    return x * peek
"""


def test_rl003_flags_scalar_sync_in_jit():
    vs = lint(HOST_SYNC_IN_JIT, only="RL003")
    assert len(vs) == 1
    assert "host sync" in vs[0].message


@pytest.mark.parametrize("expr", [
    "x.item()", "x.block_until_ready()", "np.asarray(x)",
    "jax.device_get(x)", "x.tolist()",
])
def test_rl003_flags_each_sync_form(expr):
    src = f"""
import jax, numpy as np

@jax.jit
def solve(x):
    bad = {expr}
    return x
"""
    assert codes(lint(src, only="RL003")) == ["RL003"]


def test_rl003_allows_sync_on_host():
    src = HOST_SYNC_IN_JIT.replace("@jax.jit\n", "")
    assert lint(src, only="RL003") == []


def test_rl003_allows_static_shape_int():
    src = """
import jax

@jax.jit
def solve(x):
    v = int(x.shape[0])
    return x.reshape(v)
"""
    assert lint(src, only="RL003") == []


def test_rl003_fires_in_while_loop_body():
    src = """
import jax

def drive(x):
    def cond(c):
        return c[1] < 5
    def body(c):
        y = float(c[0])
        return (c[0] * y, c[1] + 1)
    return jax.lax.while_loop(cond, body, (x, 0))
"""
    assert codes(lint(src, only="RL003")) == ["RL003"]


def test_rl003_propagates_to_local_callees():
    src = """
import jax

def helper(x):
    return x.item()

@jax.jit
def solve(x):
    return helper(x)
"""
    assert codes(lint(src, only="RL003")) == ["RL003"]


def test_rl003_pragma_suppressed():
    src = HOST_SYNC_IN_JIT.replace(
        "    peek = float(x[0])",
        "    peek = float(x[0])  # repro-lint: disable=RL003 -- fixture")
    assert lint(src, only="RL003") == []


# ---------------------------------------------------------------------------
# RL004 frozen-dataclass mutation
# ---------------------------------------------------------------------------

SETATTR_OUTSIDE = """
def cache(obj, value):
    object.__setattr__(obj, "_slot", value)
"""


def test_rl004_flags_setattr_outside_post_init():
    assert codes(lint(SETATTR_OUTSIDE, only="RL004")) == ["RL004"]


def test_rl004_allows_post_init():
    src = """
import dataclasses

@dataclasses.dataclass(frozen=True)
class C:
    x: int

    def __post_init__(self):
        object.__setattr__(self, "x", abs(self.x))
"""
    assert lint(src, only="RL004") == []


def test_rl004_flags_unfrozen_pytree():
    src = """
import dataclasses, jax

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class State:
    x: int
"""
    vs = lint(src, only="RL004")
    assert len(vs) == 1 and "frozen" in vs[0].message


def test_rl004_allows_frozen_pytree():
    src = """
import dataclasses, jax

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class State:
    x: int
"""
    assert lint(src, only="RL004") == []


def test_rl004_flags_mutable_pytree_field():
    src = """
import dataclasses, jax

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class State:
    xs: list
"""
    vs = lint(src, only="RL004")
    assert len(vs) == 1 and "mutable" in vs[0].message


def test_rl004_pragma_suppressed():
    src = SETATTR_OUTSIDE.replace(
        '    object.__setattr__(obj, "_slot", value)',
        "    # repro-lint: disable=RL004 -- fixture cache slot\n"
        '    object.__setattr__(obj, "_slot", value)')
    assert lint(src, only="RL004") == []


# ---------------------------------------------------------------------------
# RL005 clock hygiene
# ---------------------------------------------------------------------------

def test_rl005_flags_augmented_accumulation():
    src = """
def tick(self, dt):
    self.clock += dt
"""
    assert codes(lint(src, only="RL005")) == ["RL005"]


def test_rl005_flags_clock_kwarg_accumulation():
    src = """
import dataclasses

def advance(state, dt):
    return dataclasses.replace(state, clock=state.clock + dt)
"""
    assert codes(lint(src, only="RL005")) == ["RL005"]


def test_rl005_flags_cast_wrapped_accumulation():
    src = """
import jax.numpy as jnp

def advance(state, dt):
    sim_clock = jnp.float32(state.sim_clock + dt)
    return sim_clock
"""
    assert codes(lint(src, only="RL005")) == ["RL005"]


def test_rl005_allows_stamping():
    src = """
import dataclasses, jax.numpy as jnp

def stamp(self, state):
    return dataclasses.replace(state, clock=jnp.float32(self._now))
"""
    assert lint(src, only="RL005") == []


def test_rl005_allows_non_clock_targets():
    # arithmetic *reading* a clock is fine; only accumulation back in flags
    src = """
def deadline(ledger, dt):
    t_end = ledger.clock + dt
    return t_end
"""
    assert lint(src, only="RL005") == []


def test_rl005_pragma_suppressed():
    src = """
def tick(self, dt):
    # repro-lint: disable=RL005 -- fixture
    self.clock += dt
"""
    assert lint(src, only="RL005") == []


# ---------------------------------------------------------------------------
# RL006 dispatch accounting
# ---------------------------------------------------------------------------

def test_rl006_flags_plan_without_meta():
    src = """
from repro.core.plan import Plan

def solve(assign, order, bounds):
    return Plan.from_order(assign, order, bounds, solver="x")
"""
    assert codes(lint(src, only="RL006")) == ["RL006"]


def test_rl006_flags_meta_without_accounting():
    src = """
from repro.core.plan import Plan

def solve(assign, order, bounds):
    return Plan.from_order(assign, order, bounds, solver="x",
                           meta={"iters": 3})
"""
    assert codes(lint(src, only="RL006")) == ["RL006"]


def test_rl006_allows_accounted_meta():
    src = """
from repro.core.plan import Plan

def solve(assign, order, bounds):
    return Plan.from_order(assign, order, bounds, solver="x",
                           meta={"n_routings": 7})
"""
    assert lint(src, only="RL006") == []


def test_rl006_resolves_local_meta_helper():
    src = """
from repro.core.plan import Plan

def _meta(j):
    return {"fused": True, "dispatches": 1}

def solve(assign, order, bounds):
    return Plan.from_order(assign, order, bounds, solver="x",
                           meta=_meta(3))
"""
    assert lint(src, only="RL006") == []


def test_rl006_unresolvable_meta_passes():
    src = """
from repro.core.plan import Plan

def solve(assign, order, bounds, meta):
    return Plan.from_order(assign, order, bounds, solver="x", meta=meta)
"""
    assert lint(src, only="RL006") == []


def test_rl006_exempts_plan_class_itself():
    src = """
class Plan:
    @classmethod
    def from_dict(cls, d):
        return Plan.from_order(d["assign"], d["order"], d["bounds"])
"""
    assert lint(src, only="RL006") == []


def test_rl006_pragma_suppressed():
    src = """
from repro.core.plan import Plan

def solve(assign, order, bounds):
    # repro-lint: disable=RL006 -- fixture
    return Plan.from_order(assign, order, bounds, solver="x")
"""
    assert lint(src, only="RL006") == []


# ---------------------------------------------------------------------------
# engine: pragmas, registry, syntax errors
# ---------------------------------------------------------------------------

def test_all_six_rules_registered():
    assert sorted(registered_rules()) == [
        "RL001", "RL002", "RL003", "RL004", "RL005", "RL006"]


def test_pragma_without_reason_is_rl000():
    src = """
def cache(obj, value):
    # repro-lint: disable=RL004
    object.__setattr__(obj, "_slot", value)
"""
    got = codes(lint(src))
    assert "RL000" in got            # the reasonless pragma itself
    assert "RL004" in got            # ... and it does NOT suppress


def test_pragma_unknown_code_is_rl000():
    src = "x = 1  # repro-lint: disable=RL999 -- nope\n"
    assert codes(lint(src)) == ["RL000"]


def test_disable_file_pragma():
    src = ("# repro-lint: disable-file=RL004 -- fixture-wide\n"
           + SETATTR_OUTSIDE)
    assert lint(src, only="RL004") == []


def test_docstring_mention_is_not_a_pragma():
    src = '''
def f():
    """Docs may say # repro-lint: disable=RL001 without being one."""
    return 1
'''
    assert lint(src) == []


def test_syntax_error_reports_rl000():
    vs = lint("def f(:\n")
    assert codes(vs) == ["RL000"] and "syntax error" in vs[0].message


# ---------------------------------------------------------------------------
# meta: the shipped tree is clean, via the real CLI
# ---------------------------------------------------------------------------

def test_shipped_tree_lints_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "src/", "tests/",
         "benchmarks/", "--strict"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 violations" in proc.stdout


def test_cli_list_rules():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--list-rules"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0
    for code in registered_rules():
        assert code in proc.stdout
