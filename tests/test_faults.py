"""Fault events, recovery policies, and ground truth through outages.

The tentpole contract under test: a run threaded through ANY fault
schedule (node/link failures and recoveries, joins, lagged rescales)
under ANY recovery policy stays exactly replayable — the commit log's
health + removal history drives ``replay_piecewise`` to the same
completion times the incremental exact drain produced.  The engine-level
half of the same contract: ``remove_resource`` / ``restore_resource`` on
a persistent :class:`~repro.core.eventsim.EventEngine` agree with a
fresh engine rebuilt at every availability edge.  Unit tests pin the
event/schedule validation surface, victim selection, the ``migrate``
solver's one-node placement, and each recovery policy's handling of
stranded work (including bounded retry and solver-exception shedding).
"""
import copy

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from test_eventsim import _assert_same_outcome, _random_system

from repro.core import eventsim, jobs as J, solvers
from repro.scenarios import make_scenario
from repro.serving import faults as F
from repro.serving.online import OnlineScheduler, run_online
from repro.serving.stream import run_stream

FAMILIES = tuple(sorted(F.FAULT_FAMILIES))
REPLAY_EPS_S = 1e-6


# -- replay parity through fault sequences (satellite 3, end to end) ----------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_replay_matches_exact_drain_through_faults(seed):
    """Any fault family x any recovery policy: the piecewise commit-log
    replay reproduces the incremental exact drain's completion times."""
    family = FAMILIES[seed % len(FAMILIES)]
    policy = F.POLICIES[(seed // len(FAMILIES)) % len(F.POLICIES)]
    sc = make_scenario("edge-cloud", seed=0)
    rate = sc.nominal_rate(0.9)
    horizon = 12 / rate
    faults = F.make_fault_schedule(family, sc, horizon, seed=seed % 1000)
    tr = run_online(sc, horizon=horizon, rate=rate, seed=seed % 100,
                    drain="exact", track_commits=True, finish=True,
                    fault_schedule=faults, recovery=policy)
    cc, rr = tr.completions, tr.replay_completions
    assert set(cc) == set(rr)
    for name, t in cc.items():
        assert abs(rr[name] - t) <= REPLAY_EPS_S, (family, policy, name)


# -- engine remove/restore vs fresh rebuild (satellite 3, engine level) -------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_engine_remove_restore_matches_fresh_rebuild(seed, link_victim):
    """A persistent engine through an outage window [t1, t2) on one
    resource matches three fresh engines — one per availability segment,
    the middle one built with ``down=`` — over the same task state."""
    rng = np.random.default_rng(seed)
    mu_node, mu_link, tasks = _random_system(rng, staggered=True)
    V = mu_node.shape[0]
    if link_victim:
        u, v = rng.choice(V, 2, replace=False)
        res = ("link", int(u), int(v))
    else:
        res = ("node", int(rng.integers(V)))
    t1, t2 = np.sort(rng.uniform(0.0, 8.0, 2))

    live = copy.deepcopy(tasks)
    eng = eventsim.EventEngine(mu_node, mu_link)
    eng.add_tasks(live)
    eng.advance(float(t1))
    eng.remove_resource(res)
    eng.advance(float(t2))
    eng.restore_resource(res)
    eng.advance()

    ref = copy.deepcopy(tasks)
    eventsim.run_event_loop_indexed(ref, mu_node, mu_link, t=0.0,
                                    t_end=float(t1))
    eventsim.run_event_loop_indexed(ref, mu_node, mu_link, t=float(t1),
                                    t_end=float(t2), down=(res,))
    eventsim.run_event_loop_indexed(ref, mu_node, mu_link, t=float(t2))
    _assert_same_outcome(ref, live, rtol=1e-7, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_engine_sync_is_remove_then_restore(seed):
    """sync(mu, mu, down=) reaches the same trajectories as explicit
    remove/restore calls — the scheduler's one-call path is no different
    from the injector's granular one."""
    rng = np.random.default_rng(seed)
    mu_node, mu_link, tasks = _random_system(rng, staggered=True)
    res = ("node", int(rng.integers(mu_node.shape[0])))
    t1, t2 = np.sort(rng.uniform(0.0, 8.0, 2))

    a, b = copy.deepcopy(tasks), copy.deepcopy(tasks)
    ea = eventsim.EventEngine(mu_node, mu_link)
    eb = eventsim.EventEngine(mu_node, mu_link)
    ea.add_tasks(a), eb.add_tasks(b)
    ea.advance(float(t1)), eb.advance(float(t1))
    ea.remove_resource(res)
    eb.sync(mu_node, mu_link, down=(res,))
    ea.advance(float(t2)), eb.advance(float(t2))
    ea.restore_resource(res)
    eb.sync(mu_node, mu_link, down=())
    ea.advance(), eb.advance()
    _assert_same_outcome(a, b)


# -- recovery event on the scheduler (satellite 1) ----------------------------

def test_report_recovery_restores_full_health():
    sc = make_scenario("edge-cloud", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact", track_commits=True)
    sched.report_slowdown(8, 2.0)
    assert sched._slowdown[8] == 2.0
    sched.report_recovery(8, at=1.0)
    assert sched._slowdown[8] == 1.0
    assert sched.now == 1.0
    # recorded in the health history (replay_piecewise's contract) ...
    assert sched.commit_log.health[-1] == (1.0, 8, 1.0)
    # ... and on the trace
    assert sched.trace.events[-1]["event"] == "recovery"


def test_report_recovery_validates_node():
    sc = make_scenario("edge-cloud", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    with pytest.raises(ValueError, match="out of range"):
        sched.report_recovery(sc.num_nodes)
    with pytest.raises(ValueError, match="out of range"):
        sched.report_recovery(-1)


def test_availability_setters_validate():
    sc = make_scenario("edge-cloud", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    with pytest.raises(ValueError, match="out of range"):
        sched.set_node_availability(sc.num_nodes, False)
    u, v = map(int, np.argwhere(
        np.asarray(sc.topology.mu_link) == 0)[0])
    with pytest.raises(ValueError, match="does not exist"):
        sched.set_link_availability(u, v, False)


# -- event / schedule validation ----------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        F.FaultEvent(1.0, "meteor")
    with pytest.raises(ValueError, match="needs link"):
        F.FaultEvent(1.0, "link_fail")
    with pytest.raises(ValueError, match="needs node"):
        F.FaultEvent(1.0, "node_fail")
    with pytest.raises(ValueError, match="finite and > 0"):
        F.FaultEvent(1.0, "rescale", node=0, factor=0.0)
    with pytest.raises(ValueError, match="finite and > 0"):
        F.FaultEvent(1.0, "rescale", node=0, factor=np.inf)
    with pytest.raises(ValueError, match="time must be finite"):
        F.node_fail(np.inf, 0)


def test_fault_schedule_sorts_and_validates():
    sched = F.schedule_from([F.node_recover(5.0, 1), F.node_fail(2.0, 1)])
    assert [ev.kind for ev in sched] == ["node_fail", "node_recover"]
    assert len(sched) == 2
    with pytest.raises(ValueError, match="outside"):
        F.FaultSchedule((F.node_fail(1.0, 99),)).validate(4)
    with pytest.raises(ValueError, match="outside"):
        F.FaultSchedule((F.link_fail(1.0, 0, 99),)).validate(4)


def test_capacity_rescale_lag():
    ev = F.capacity_rescale(2.0, 3, 0.5, lag=0.25)
    assert ev.time == 2.25 and ev.kind == "rescale" and ev.factor == 0.5


def test_make_fault_schedule_families():
    sc = make_scenario("edge-cloud", seed=0)
    with pytest.raises(ValueError, match="unknown fault family"):
        F.make_fault_schedule("volcano", sc, 10.0)
    for family in FAMILIES:
        sched = F.make_fault_schedule(family, sc, 10.0, seed=3)
        assert len(sched) >= 2
        assert all(0.0 <= ev.time <= 10.0 for ev in sched)
        times = [ev.time for ev in sched]
        assert times == sorted(times)


def test_pick_victim_prefers_interior_compute():
    sc = make_scenario("edge-cloud", seed=0)
    # the cloud node: highest-capacity compute that is not ingress/egress
    assert F.pick_victim(sc) == 8
    u, _ = F.pick_victim_link(sc)
    assert u == 8


# -- the migrate solver -------------------------------------------------------

def test_migrate_solver_places_each_job_on_one_node():
    sc = make_scenario("edge-cloud", seed=0)
    jobs = sc.sample_jobs(np.random.default_rng(0), 3)
    plan = solvers.solve(sc.topology, J.batch_jobs(jobs), method="migrate")
    for j, job in enumerate(jobs):
        row = plan.assign[j, :job.num_layers]
        assert len(set(row.tolist())) == 1
        assert sc.topology.mu_node[row[0]] > 0
    assert plan.solver == "migrate"


# -- the injector: construction + policies ------------------------------------

def _stranded_setup(policy, **kw):
    """Two jobs committed at t=0 (greedy puts work on the cloud node 8),
    then node 8 fails at t=0.1 — returns (sched, injector, outage rec)."""
    sc = make_scenario("edge-cloud", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact", track_commits=True)
    sched.submit_jobs(0.0, sc.sample_jobs(np.random.default_rng(0), 2))
    inj = F.FaultInjector(sched, policy=policy, **kw)
    rec = inj.apply(F.node_fail(0.1, 8))
    assert rec["affected"], "setup: no work landed on the victim node"
    return sched, inj, rec


def test_injector_requires_exact_drain():
    sc = make_scenario("edge-cloud", seed=0)
    with pytest.raises(ValueError, match="drain='exact'"):
        F.FaultInjector(OnlineScheduler(sc.topology))  # fluid: no ledger


def test_injector_validates_args():
    sc = make_scenario("edge-cloud", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    with pytest.raises(ValueError, match="policy"):
        F.FaultInjector(sched, policy="pray")
    with pytest.raises(ValueError, match="max_retries"):
        F.FaultInjector(sched, max_retries=-1)


def test_policy_lost_sheds_and_accounts():
    sched, _, rec = _stranded_setup("lost")
    assert rec["lost"] and not rec["requeued"]
    assert {why for _, why in rec["lost"]} == {"failed_resource"}
    assert set(rec["lost"]) == set(sched.trace.lost)
    downs = set(sched._down_keys())
    assert all(job.stages[k][0] not in downs
               for job in sched.ledger.jobs
               for k in range(job.ptr, len(job.stages)))


def test_policy_requeue_replans_with_retry_suffix():
    sched, _, rec = _stranded_setup("requeue")
    assert rec["requeued"]
    # a job whose last finished layer's output sat ON the victim loses its
    # intermediate data with the node — shed, not requeued
    assert {why for _, why in rec["lost"]} <= {"data_lost"}
    assert all(n.endswith("#r1") for n in rec["requeued"])
    live = {j.name for j in sched.ledger.jobs}
    assert set(rec["requeued"]) <= live
    # the originals were withdrawn from the live ledger
    assert not any(F._parse_retry(n)[1] == 0 for n in live)
    # requeued latency is charged from the ORIGINAL arrival instant
    for n in rec["requeued"]:
        base, _ = F._parse_retry(n)
        assert sched.trace.arrivals_by_name[n] == \
            sched.trace.arrivals_by_name[base]


def test_policy_requeue_avoids_dead_resources():
    sched, _, rec = _stranded_setup("requeue")
    downs = set(sched._down_keys())
    for job in sched.ledger.jobs:
        assert all(res not in downs for res, _ in job.stages)


def test_policy_migrate_places_residual_on_one_node():
    sched, _, rec = _stranded_setup("migrate")
    assert rec["requeued"]
    requeued = [j for j in sched.ledger.jobs if j.name in set(rec["requeued"])]
    assert requeued
    for job in requeued:
        nodes = {res[1] for res, _ in job.stages if res[0] == "node"}
        assert len(nodes) == 1 and 8 not in nodes


def test_retries_exhausted_bounds_the_loop():
    sched, _, rec = _stranded_setup("requeue", max_retries=0)
    assert not rec["requeued"]
    assert {why for _, why in rec["lost"]} == {"retries_exhausted"}


def test_recover_event_restores_routability():
    sched, inj, _ = _stranded_setup("lost")
    assert sched.degraded
    inj.apply(F.node_recover(0.5, 8))
    assert not sched.degraded
    assert sched._slowdown[8] == 1.0


def test_rescale_event_is_absolute_slowdown():
    sc = make_scenario("edge-cloud", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    inj = F.FaultInjector(sched)
    inj.apply(F.capacity_rescale(0.0, 8, 0.5))   # half capacity
    assert sched._slowdown[8] == 2.0
    inj.apply(F.capacity_rescale(1.0, 8, 1.0))   # back to nominal
    assert sched._slowdown[8] == 1.0


# -- routability + arrival filtering ------------------------------------------

def test_filter_arrivals_sheds_unroutable():
    sc = make_scenario("edge-cloud", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    inj = F.FaultInjector(sched, policy="lost")
    sched.set_node_availability(0, False)
    assert not inj.routable(0, 3)       # dead source
    assert not inj.routable(3, 0)       # dead destination
    assert inj.routable(1, 3)
    jobs = [J.synthetic_job("dead-src", 0, 3, 4, seed=1),
            J.synthetic_job("alive", 1, 3, 4, seed=2)]
    kept = inj.filter_arrivals(0.0, jobs)
    assert [j.name for j in kept] == ["alive"]
    assert ("dead-src", "arrival_unroutable") in sched.trace.lost


# -- solver exceptions must not kill the pipeline (satellite 2) ---------------

def test_stream_survives_solver_exception():
    @solvers.register("test-bomb")
    def _bomb(net, batch, **opts):
        raise RuntimeError("solver exploded")

    sc = make_scenario("star", seed=0)
    rate = sc.nominal_rate(0.5)
    tr = run_stream(sc, horizon=8 / rate, rate=rate, seed=1,
                    drain="exact", method="test-bomb")
    s = tr.summary()
    assert s["requests"] == 0
    assert s["shed"] > 0
    assert s["shed_by_reason"] == {"solver_error": s["shed"]}


def test_stream_retries_transient_solver_failure_once():
    calls = {"n": 0}

    @solvers.register("test-flaky")
    def _flaky(net, batch, **opts):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return solvers.get("greedy")(net, batch, **opts)

    sc = make_scenario("star", seed=0)
    rate = sc.nominal_rate(0.5)
    tr = run_stream(sc, horizon=8 / rate, rate=rate, seed=1,
                    drain="exact", method="test-flaky", finish=True)
    s = tr.summary()
    assert s.get("shed", 0) == 0
    assert s["requests"] == s["arrivals"] > 0
    assert calls["n"] >= 2


# -- faults through the streaming pipeline ------------------------------------

def test_stream_fault_schedule_matches_serial_loop():
    """window_s=0, max_batch=1, zero solver latency: the faulted streaming
    run must reproduce the faulted serial loop bit for bit."""
    sc = make_scenario("edge-cloud", seed=0)
    rate = sc.nominal_rate(0.85)
    horizon = 10 / rate
    faults = F.make_fault_schedule("transient-node", sc, horizon, seed=5)
    kw = dict(horizon=horizon, rate=rate, seed=2, drain="exact",
              track_commits=True, finish=True,
              fault_schedule=faults, recovery="requeue")
    serial = run_online(make_scenario("edge-cloud", seed=0), **kw)
    pipe = run_stream(make_scenario("edge-cloud", seed=0), window_s=0.0,
                      max_batch=1, **kw)
    assert set(pipe.completions) == set(serial.completions)
    for n, t in serial.completions.items():
        assert abs(pipe.completions[n] - t) <= REPLAY_EPS_S
    assert sorted(n for n, _ in pipe.lost) == sorted(n for n, _ in serial.lost)
