"""End-to-end behaviour tests for the paper's system."""
import numpy as np

from repro.configs import registry
from repro.core import jobs as J, network as N, greedy, schedule
from repro.costs.convnets import vgg19_profile, resnet34_profile
from repro.costs.lm import cost_profile as lm_cost_profile


def test_cost_profiles_match_literature():
    comp_v, data_v = vgg19_profile()
    assert len(comp_v) == 19 and len(data_v) == 20
    assert 3.7e10 < comp_v.sum() < 4.1e10       # VGG19 ~39 GFLOP
    comp_r, data_r = resnet34_profile()
    assert len(comp_r) == 34
    assert 6.5e9 < comp_r.sum() < 8.0e9         # ResNet34 ~7.3 GFLOP
    assert data_v[0] == 224 * 224 * 3 * 4


def test_lm_cost_profile_consistency():
    cfg = registry.config("olmo_1b")
    comp, data = lm_cost_profile(cfg, seq_len=2048, batch=1)
    assert len(comp) == cfg.num_layers + 2
    assert len(data) == len(comp) + 1
    # forward flops approximately 2 * params * tokens
    assert 0.5 < comp.sum() / (2 * 1.18e9 * 2048) < 2.0
    # MLA arch moves less data per layer than an equivalent dense stack
    ds = registry.config("deepseek_v2_236b")
    comp_d, data_d = lm_cost_profile(ds, seq_len=2048, batch=1)
    assert data_d[1] == 2048 * ds.d_model * 2


def test_paper_small_topology_end_to_end():
    """The paper's §V small-topology experiment: 2 VGG19 + 6 ResNet34."""
    rng = np.random.default_rng(0)
    net, names = N.small_topology(capacity_scale=1e-4)
    jobs = []
    for i in range(2):
        s, d = rng.choice(5, 2, replace=False)
        jobs.append(registry.get("vgg19").make_job(f"v{i}", int(s), int(d)))
    for i in range(6):
        s, d = rng.choice(5, 2, replace=False)
        jobs.append(registry.get("resnet34").make_job(f"r{i}", int(s), int(d)))
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    assert np.isfinite(sol.makespan_bound) and sol.makespan_bound < 1e4
    sim = schedule.simulate(net, batch, sol.assign, sol.order)
    assert sim.makespan <= sol.makespan_bound * (1 + 1e-6)


def test_high_link_capacity_concentrates_jobs():
    """§V observation: with large link capacities greedy assigns all layers
    of a job to a single (fast) node."""
    net, _ = N.small_topology(capacity_scale=1e3)   # effectively free links
    job = registry.get("vgg19").make_job("v", 0, 4)
    batch = J.batch_jobs([job])
    sol = greedy.greedy_route(net, batch)
    L = job.num_layers
    nodes = set(int(x) for x in sol.assign[0][:L])
    assert len(nodes) == 1, f"expected single-node assignment, got {nodes}"
    assert nodes == {0}  # node s has the largest capacity (200 GF/s)


def test_low_link_capacity_splits_jobs():
    """With expensive links, computation stays near the source/dest path."""
    net, _ = N.small_topology(capacity_scale=1e-5)
    job = registry.get("vgg19").make_job("v", 0, 4)
    batch = J.batch_jobs([job])
    sol = greedy.greedy_route(net, batch)
    sim = schedule.simulate(net, batch, sol.assign, sol.order)
    assert sim.makespan <= sol.makespan_bound * (1 + 1e-6)


def test_completion_decreases_with_link_capacity():
    """Fig. 5 trend: completion time falls as link capacity scales up."""
    rng = np.random.default_rng(1)
    jobs = []
    for i in range(4):
        s, d = rng.choice(5, 2, replace=False)
        name = "vgg19" if i < 2 else "resnet34"
        jobs.append(registry.get(name).make_job(f"{name}-{i}", int(s), int(d)))
    prev = None
    for scale in [1e-4, 1e-3, 1e-2, 1e-1]:
        net, _ = N.small_topology(capacity_scale=scale)
        sol = greedy.greedy_route(net, J.batch_jobs(jobs))
        if prev is not None:
            assert sol.makespan_bound <= prev * (1 + 1e-5)
        prev = sol.makespan_bound


def test_us_backbone_runs():
    net, names = N.us_backbone(capacity_scale=1e-2)
    assert net.num_nodes == 24
    caps = np.asarray(net.mu_node) / 1e9
    np.testing.assert_allclose(caps[:5], [30, 50, 200, 100, 70], rtol=1e-6)
    job = registry.get("resnet34").make_job("r", 0, 23)
    sol = greedy.greedy_route(net, J.batch_jobs([job]))
    assert np.isfinite(sol.makespan_bound)
