"""Single-job routing DP: exactness vs the bitmask ILP oracle + invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import exact, jobs as J, routing
from util import random_instance


def _route(net, job):
    return routing.route_single(net, jnp.asarray(job.comp),
                                jnp.asarray(job.data), job.src, job.dst,
                                job.num_layers)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_dp_matches_ilp_oracle(seed, with_queues):
    """Theorem 1, constructively: the DP value equals the exact ILP optimum
    (once-per-node z_u waiting semantics) on randomized instances."""
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=1, with_queues=with_queues)
    job = jobs[0]
    r = _route(net, job)
    c_exact, _ = exact.exact_route_bitmask(net, job.comp, job.data,
                                           job.src, job.dst)
    got = float(r.cost)
    if c_exact >= 1e29:
        assert got >= 1e29
    else:
        np.testing.assert_allclose(got, c_exact, rtol=2e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_assignment_reproduces_cost(seed):
    """cost_given_assignment(DP's own assignment) == the DP optimum."""
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=1, with_queues=True)
    job = jobs[0]
    r = _route(net, job)
    if float(r.cost) >= 1e29:
        return
    val = routing.cost_given_assignment(
        net, jnp.asarray(job.comp), jnp.asarray(job.data), job.src, job.dst,
        job.num_layers, r.assign)
    np.testing.assert_allclose(float(val), float(r.cost), rtol=2e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_assignment_on_compute_nodes(seed):
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=1)
    job = jobs[0]
    r = _route(net, job)
    if float(r.cost) >= 1e29:
        return
    mu = np.asarray(net.mu_node)
    for l in range(job.num_layers):
        assert mu[int(r.assign[l])] > 0, "layer assigned to compute-less node"


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_queueing_only_increases_cost(seed):
    """Monotonicity: adding queue backlog can only increase the bound."""
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=1)
    job = jobs[0]
    c0 = float(_route(net, job).cost)
    qn = jnp.asarray(rng.uniform(0, 2, net.num_nodes), jnp.float32)
    ql = jnp.asarray(rng.uniform(0, 2, (net.num_nodes,) * 2), jnp.float32)
    ql = ql * (net.mu_link > 0)
    c1 = float(_route(net.with_queues(qn * (net.mu_node > 0), ql), job).cost)
    assert c1 >= c0 - 1e-4 * abs(c0)


def test_commit_accounting():
    """commit adds exactly c_l to each assigned node and d_l along paths."""
    rng = np.random.default_rng(3)
    net, jobs = random_instance(rng, num_jobs=1)
    job = jobs[0]
    r = _route(net, job)
    if float(r.cost) >= 1e29:
        pytest.skip("disconnected draw")
    net2 = routing.commit_assignment(
        net, jnp.asarray(job.comp), jnp.asarray(job.data), job.src, job.dst,
        job.num_layers, r.assign)
    added_comp = float(jnp.sum(net2.q_node - net.q_node))
    np.testing.assert_allclose(added_comp, float(job.comp.sum()), rtol=1e-5)
    # every link increment is a positive multiple of some d_l on a real link
    dq = np.asarray(net2.q_link - net.q_link)
    assert (dq >= -1e-6).all()
    assert (dq[np.asarray(net.mu_link) == 0] == 0).all()


def test_paths_connect_assignments():
    rng = np.random.default_rng(11)
    net, jobs = random_instance(rng, num_jobs=1)
    job = jobs[0]
    r = _route(net, job)
    if float(r.cost) >= 1e29:
        pytest.skip("disconnected draw")
    paths = routing.extract_paths(
        net, jnp.asarray(job.comp), jnp.asarray(job.data), job.src, job.dst,
        job.num_layers, r.assign)
    nodes = [job.src] + [int(r.assign[l]) for l in range(job.num_layers)] \
        + [job.dst]
    mu = np.asarray(net.mu_link)
    for l, hops in enumerate(paths):
        cur = nodes[l]
        for (u, v) in hops:
            assert u == cur and mu[u, v] > 0
            cur = v
        assert cur == nodes[l + 1]
