"""Theorem 1 (total unimodularity) and Theorem 2 (approximation ratio)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (bounds, exact, greedy, jobs as J, layered_graph,
                        network as N, schedule)
from util import random_instance


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_constraint_matrix_is_tu(seed):
    """Random square submatrices of [A1; A2] have det in {-1, 0, 1}."""
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=1)
    job = jobs[0]
    ilp = layered_graph.build_ilp(net, job.num_layers, job.src, job.dst,
                                  job.comp, job.data)
    mat = np.vstack([ilp.a1, ilp.a2])
    dets = layered_graph.random_square_submatrix_dets(
        mat, trials=150, max_k=8, seed=seed)
    np.testing.assert_allclose(dets, np.round(dets), atol=1e-7)
    assert np.all(np.abs(np.round(dets)) <= 1)


def test_b2_is_unit_flow():
    rng = np.random.default_rng(0)
    net, jobs = random_instance(rng, num_jobs=1)
    job = jobs[0]
    ilp = layered_graph.build_ilp(net, job.num_layers, job.src, job.dst,
                                  job.comp, job.data)
    assert ilp.b2.sum() == 0
    assert sorted(np.unique(ilp.b2)) in ([-1.0, 0.0, 1.0], [-1.0, 1.0])


def test_theorem2_alpha_bound_tiny():
    """Greedy completion <= alpha * T* on a brute-forced tiny instance."""
    G = 1.0
    net = N.make_network(3, [(0, 1, 10.0), (1, 2, 10.0), (0, 2, 10.0)],
                         [2 * G, 1 * G, 0])
    jobs = [
        J.InferenceJob("a", 0, 2, np.array([2.0], np.float32),
                       np.array([1.0, 1.0], np.float32)),
        J.InferenceJob("b", 2, 0, np.array([3.0], np.float32),
                       np.array([1.0, 0.5], np.float32)),
    ]
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    sim = sol.simulate(net, batch)
    tstar = exact.brute_force_makespan(net, batch)
    a = bounds.alpha(net, jobs)
    assert sim.makespan <= a * tstar * (1 + 1e-6), (sim.makespan, a, tstar)
    assert sol.makespan_bound <= a * tstar * (1 + 1e-6)


def test_corollary1_zero_delay_identical_caps():
    """Zero network delay + identical caps: greedy <= (2 - 1/|V|) T*."""
    big = 1e12
    net = N.make_network(4, [(0, 1, big), (1, 2, big), (2, 3, big),
                             (3, 0, big)], [1.0, 1.0, 1.0, 1.0])
    rng = np.random.default_rng(2)
    jobs = [J.InferenceJob(f"j{i}", int(rng.integers(4)),
                           int(rng.integers(4)),
                           np.array([rng.uniform(0.5, 2)], np.float32),
                           np.array([1e-9, 1e-9], np.float32))
            for i in range(3)]
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    sim = sol.simulate(net, batch)
    tstar = exact.brute_force_makespan(net, batch)
    factor = bounds.corollary1_factor(net)
    assert sim.makespan <= factor * tstar * (1 + 1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_lemma8_lower_bounds(seed):
    """Lemma 8: S_j^SS and the component-average lower-bound T*."""
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=2)
    batch = J.batch_jobs(jobs)
    s_ss, avg_lb = bounds.service_lower_bounds(net, batch)
    if np.any(s_ss >= 1e29):
        return
    sol = greedy.greedy_route(net, batch)
    sim = schedule.simulate(net, batch, sol.assign, sol.order)
    # any achievable completion upper-bounds T*, which dominates the LBs
    assert sim.makespan >= max(s_ss.max(), avg_lb) * (1 - 1e-5)
