"""CommittedWork ledger + exact drain: equivalence with the event
simulator, fluid-as-optimistic-bound, drain composition, and the online
fidelity invariants the benchmark gates on."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import completions as C, jobs as J, schedule, solve
from repro.scenarios import make_scenario
from repro.serving.online import OnlineScheduler, run_online
from util import random_instance


def _committed_ledger(rng, num_jobs=3):
    """(net, batch, plan-with-paths, ledger committed at t=0)."""
    net, jobs = random_instance(rng, num_jobs=num_jobs)
    batch = J.batch_jobs(jobs)
    plan = solve(net, batch, method="greedy")
    if plan.makespan_bound >= 1e29:
        return None  # disconnected/dead instance; skip
    plan = plan.replay(net, batch)  # fill explicit paths
    ledger = C.CommittedWork.empty(net.num_nodes).commit(
        batch, plan, names=[j.name for j in jobs])
    return net, batch, plan, ledger


# -- ledger structure ---------------------------------------------------------

def test_commit_requires_paths_and_monotone_time():
    rng = np.random.default_rng(0)
    net, jobs = random_instance(rng, num_jobs=2)
    batch = J.batch_jobs(jobs)
    plan = solve(net, batch, method="greedy")
    led = C.CommittedWork.empty(net.num_nodes, clock=5.0)
    with pytest.raises(ValueError, match="paths"):
        led.commit(batch, plan)
    plan = plan.replay(net, batch)
    with pytest.raises(ValueError, match="behind the ledger clock"):
        led.commit(batch, plan, at=1.0)
    led2 = led.commit(batch, plan, at=5.0, names=[j.name for j in jobs])
    assert len(led2.jobs) == 2 and led2.next_prio == 2
    assert [j.prio for j in led2.jobs] == [0, 1]
    # priority order == plan order; clock unmoved by commits
    assert led2.jobs[0].name == jobs[int(plan.order[0])].name
    assert led2.clock == 5.0


def test_queue_arrays_match_fluid_commit_at_commit_instant():
    """Before any draining, the ledger's residual work equals the fluid
    committed queues (same loads on the same resources)."""
    rng = np.random.default_rng(1)
    out = _committed_ledger(rng)
    assert out is not None
    net, batch, plan, ledger = out
    qn, ql = ledger.queue_arrays()
    np.testing.assert_allclose(qn, np.asarray(plan.net.q_node), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(ql, np.asarray(plan.net.q_link), rtol=1e-5,
                               atol=1e-5)


# -- exact drain vs the one-shot simulator -----------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_run_to_completion_matches_simulate(seed):
    """Draining a freshly committed ledger to completion reproduces the
    event simulator's per-job completion times (same machinery, same
    numbers)."""
    rng = np.random.default_rng(seed)
    out = _committed_ledger(rng)
    if out is None:
        return
    net, batch, plan, ledger = out
    sim = schedule.simulate(net.reset_queues(), batch, plan)
    comps, drained = C.run_to_completion(net.topology, ledger)
    assert not drained.jobs
    for j in range(batch.num_jobs):
        name = f"job{j}"
        np.testing.assert_allclose(comps[name], sim.completion[j],
                                   rtol=1e-9, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_drain_exact_composes(seed):
    """Chunked draining is exact: drain(a) then drain(b) == drain(a+b) in
    residual work, progress, and recorded completions."""
    rng = np.random.default_rng(seed)
    out = _committed_ledger(rng)
    if out is None:
        return
    net, batch, plan, ledger = out
    a, b = rng.uniform(0, 2, size=2)
    two = C.drain_exact(net.topology,
                        C.drain_exact(net.topology, ledger, a), b)
    one = C.drain_exact(net.topology, ledger, a + b)
    assert dict(two.completed).keys() == dict(one.completed).keys()
    for name, when in one.completed:
        np.testing.assert_allclose(dict(two.completed)[name], when,
                                   rtol=1e-9, atol=1e-12)
    qn2, ql2 = two.queue_arrays()
    qn1, ql1 = one.queue_arrays()
    np.testing.assert_allclose(qn2, qn1, atol=1e-5)
    np.testing.assert_allclose(ql2, ql1, atol=1e-5)
    assert two.clock == pytest.approx(one.clock)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_drain_exact_never_under_drains_vs_fluid(seed):
    """Fluid is the optimistic bound: per resource, the exact residual is
    >= the fluid residual after any dt (fluid serves each resource at the
    maximum possible rate, ignoring precedence and priority)."""
    rng = np.random.default_rng(seed)
    out = _committed_ledger(rng)
    if out is None:
        return
    net, batch, plan, ledger = out
    dt = float(rng.uniform(0, 3))
    led = C.drain_exact(net.topology, ledger, dt)
    qn_e, ql_e = led.queue_arrays()
    fluid = net.state.with_queues(plan.net.q_node,
                                  plan.net.q_link).advance(net.topology, dt)
    assert (qn_e >= np.asarray(fluid.q_node) - 1e-4).all()
    assert (ql_e >= np.asarray(fluid.q_link) - 1e-4).all()


def test_drain_exact_respects_precedence():
    """A layer's transfer bytes must not drain before its compute does:
    with compute far from finished after dt, the output link's queued bytes
    are untouched under exact drain (the fluid model drains them)."""
    import jax.numpy as jnp
    from repro.core import network as N
    from repro.core.plan import Plan

    net = N.make_network(3, [(0, 1, 1.0), (1, 2, 1.0)], [0.0, 1.0, 0.0])
    job = J.InferenceJob("j", 0, 2, np.asarray([10.0], np.float32),
                         np.asarray([1.0, 4.0], np.float32))
    batch = J.batch_jobs([job])
    plan = Plan(assign=np.asarray([[1]]), priority=np.asarray([0]),
                bounds=np.asarray([0.0]))
    plan = plan.replay(net, batch)
    ledger = C.CommittedWork.empty(3).commit(batch, plan, names=["j"])
    # After 2s: input transfer (1 byte @ 1 B/s) done, compute has 9 FLOPs
    # left, so the 4-byte output transfer has not started.
    led = C.drain_exact(net.topology, ledger, 2.0)
    qn, ql = led.queue_arrays()
    assert ql[1, 2] == pytest.approx(4.0)       # untouched: precedence
    assert qn[1] == pytest.approx(9.0)          # compute drained 1s worth
    fluid = net.state.with_queues(plan.net.q_node,
                                  plan.net.q_link).advance(net.topology, 2.0)
    assert float(np.asarray(fluid.q_link)[1, 2]) == pytest.approx(2.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_bound_dominates_simulation_on_ledger_drained_state(seed):
    """bound >= simulated completion still holds when the queue state a new
    batch is solved against came from an exact ledger drain."""
    rng = np.random.default_rng(seed)
    out = _committed_ledger(rng, num_jobs=2)
    if out is None:
        return
    ledger_net, batch1, plan1, ledger = out
    led = C.drain_exact(ledger_net.topology, ledger, float(rng.uniform(0, 3)))
    state = led.queue_state()
    net = ledger_net.topology.view(state)
    _, jobs2 = random_instance(rng, num_jobs=3)
    batch2 = J.batch_jobs(jobs2)
    plan2 = solve(net, batch2, method="greedy")
    if plan2.makespan_bound >= 1e29:
        return
    sim = schedule.simulate(net, batch2, plan2.assign, plan2.order)
    assert sim.makespan <= plan2.makespan_bound * (1 + 1e-5)


# -- online integration -------------------------------------------------------

def _star_run(drain, *, load=0.7, arrivals=25, **kw):
    sc = make_scenario("star", seed=0)
    rate = sc.nominal_rate(load)
    return sc, run_online(sc, horizon=arrivals / rate, seed=3, rate=rate,
                          drain=drain, **kw)


def test_online_exact_backlog_bounded_and_bounds_hold():
    """Exact drain keeps backlog bounded under sub-capacity load, and its
    per-arrival bounds dominate the actual (event-simulated) completions —
    the property the fluid drain loses."""
    sc, tr = _star_run("exact", track_commits=True, finish=True)
    assert len(tr.records) >= 15
    assert tr.backlog_growth() <= 1.5, tr.summary()
    act, bound = tr.actual_latencies(), tr.latencies
    assert act.size == bound.size == len(tr.completions)
    assert (act <= bound * (1 + 1e-6) + 1e-9).all()


def test_online_exact_incremental_matches_one_shot_replay():
    """Completion times recorded by the chunked online drain equal the
    one-shot full-horizon replay of the same commit log."""
    _, tr = _star_run("exact", track_commits=True, finish=True)
    assert tr.completions.keys() == tr.replay_completions.keys()
    for name, when in tr.completions.items():
        np.testing.assert_allclose(when, tr.replay_completions[name],
                                   rtol=1e-9, atol=1e-9)


def test_online_exact_backlog_trace_dominates_fluid():
    """Replaying the fluid policy's own commits under exact accounting
    never reports less backlog than the fluid model claimed."""
    sc, tr = _star_run("fluid", track_commits=True, finish=True)
    exb = C.exact_backlog_trace(sc.topology, tr.commit_log, tr.times)
    flb = np.array([r.backlog_before for r in tr.records])
    assert exb.shape == flb.shape
    assert (exb >= flb - 1e-6).all()


def test_exact_backlog_trace_rejects_drained_ledger():
    sc = make_scenario("star", seed=0)
    rng = np.random.default_rng(0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 1), pad_to=sc.max_layers)
    sched.advance_to(1e-3)
    with pytest.raises(ValueError, match="undrained"):
        C.exact_backlog_trace(sc.topology, sched.ledger, [1.0])


def test_scheduler_drain_mode_validation_and_reset():
    sc = make_scenario("star", seed=0)
    with pytest.raises(ValueError, match="drain must be"):
        OnlineScheduler(sc.topology, drain="magic")
    sched = OnlineScheduler(sc.topology, drain="exact")
    rng = np.random.default_rng(1)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    assert sched.ledger is not None and len(sched.ledger.jobs) == 2
    # state queues were materialized from the ledger
    qn, _ = sched.ledger.queue_arrays()
    np.testing.assert_allclose(np.asarray(sched.state.q_node), qn)
    sched.drain()
    assert not sched.ledger.jobs
    assert float(np.asarray(sched.state.q_node).max()) == 0.0


def test_exact_replan_rolls_ledger_back():
    """replan_last in exact mode restores the pre-batch ledger, drains it
    over the elapsed window, and commits the re-solved batch — the ledger
    never double-counts the superseded plan."""
    sc = make_scenario("edge-cloud", traffic="synthetic", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    rng = np.random.default_rng(3)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    assert len(sched.ledger.jobs) == 4
    bound0 = sched.last_plan.bound()
    sched.advance_to(1e9)  # everything committed has long been served
    assert not sched.ledger.jobs  # all completed by the drain
    sched.replan_last()
    # rollback + elapsed drain: batch 1 completed, batch 2 re-committed
    assert len(sched.ledger.jobs) == 2
    assert sched.last_plan.bound() < bound0


def test_ledger_rejects_duplicate_job_names():
    """Completion records key on job names; a repeat would silently
    overwrite an earlier job's completion, so commit() rejects it."""
    from repro.serving.scheduler import Request, RoutedScheduler
    from repro.core import network as N

    G, GB = 1e12, 1e9
    net = N.make_network(3, [(0, 1, 10 * GB), (1, 2, 10 * GB)],
                         [0, 50 * G, 0])
    sched = RoutedScheduler(net, drain="exact")
    sched.schedule([Request("smollm_135m", 0, 2)])  # defaults to name req0
    with pytest.raises(ValueError, match="duplicate job name 'req0'"):
        sched.schedule([Request("smollm_135m", 0, 2)])
    # distinct names are fine across batches
    sched.schedule([Request("smollm_135m", 0, 2, name="r1")])
    assert len(sched.ledger.jobs) == 2


def test_online_slowdown_invalid_node_does_not_move_clock():
    """An out-of-range node is rejected before the clock advances, like an
    invalid factor."""
    sc = make_scenario("star", seed=0)
    sched = OnlineScheduler(sc.topology)
    sched.advance_to(1.0)
    with pytest.raises(ValueError, match="out of range"):
        sched.report_slowdown(sc.num_nodes + 5, 2.0, at=9.0)
    assert sched.now == pytest.approx(1.0)
    assert sched.trace.events == []


def test_exact_bounds_hold_through_replan():
    """replan_last refreshes the superseded arrival record (the new bound,
    measured from the replan instant, plus the wait already incurred), so
    bound >= actual survives straggler replans in exact mode."""
    sc = make_scenario("edge-cloud", traffic="synthetic", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    rng = np.random.default_rng(11)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 1), pad_to=sc.max_layers)
    sched.submit_jobs(0.5, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    victim = int(sched.last_plan.assign[int(sched.last_plan.order[0]), 0])
    sched.report_slowdown(victim, 50.0, at=1.0)
    sched.replan_last()
    sched.finish()
    actual, bounds = sched.trace.actual_latencies(), sched.trace.latencies
    assert actual.size == bounds.size == 3
    assert (actual <= bounds * (1 + 1e-6) + 1e-9).all(), (actual, bounds)


def test_scenario_job_names_unique_across_batches():
    """Completion tracking keys on names; sample_jobs must never repeat one
    even across many calls on the same scenario instance."""
    sc = make_scenario("star", seed=0)
    rng = np.random.default_rng(0)
    names = [j.name for _ in range(50) for j in sc.sample_jobs(rng, 2)]
    assert len(set(names)) == len(names)


def test_online_scheduler_finish_requires_exact():
    sc = make_scenario("star", seed=0)
    sched = OnlineScheduler(sc.topology)
    with pytest.raises(ValueError, match="exact"):
        sched.finish()
    with pytest.raises(ValueError, match="track_commits"):
        sched.replay_ground_truth()
