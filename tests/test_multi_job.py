"""Greedy (Alg 1), SA (Alg 2), the actual-system simulator, and their
relationships: evaluator consistency, bound >= simulation, SA quality."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (annealing, greedy, jobs as J, network as N,
                        schedule)
from util import random_instance


def _fig1():
    G = 1e9
    net = N.make_network(
        6, [(0, 1, 1e15), (1, 2, 1e15), (3, 4, 1e15), (4, 5, 1e15),
            (0, 4, 1e15), (3, 1, 1e15)],
        [0, 25 * G, 0, 0, 50 * G, 0])
    j1 = J.InferenceJob("j1", 0, 2, np.array([25 * G], np.float32),
                        np.array([1., 1.], np.float32))
    j2 = J.InferenceJob("j2", 3, 5, np.array([50 * G], np.float32),
                        np.array([1., 1.], np.float32))
    return net, J.batch_jobs([j1, j2])


def test_fig1_greedy_and_sa():
    """Fig. 1: SA finds the completion-time-aware split (makespan 1.0s)."""
    net, batch = _fig1()
    sol = greedy.greedy_route(net, batch)
    sim = sol.simulate(net, batch)
    assert sim.makespan <= sol.bound() + 1e-6
    sa = annealing.anneal(net, batch, seed=0, d=0.98, num_chains=4)
    assert sa.bound() <= 1.0 + 1e-3    # the (u, v)-disjoint optimum
    sim2 = sa.simulate(net, batch)
    np.testing.assert_allclose(sim2.makespan, 1.0, rtol=1e-3)


def test_greedy_bounds_nondecreasing():
    """Queues only grow during greedy => later jobs have >= bounds."""
    rng = np.random.default_rng(0)
    net, jobs = random_instance(rng, num_jobs=5)
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    ordered = sol.bounds[sol.order]
    assert (np.diff(ordered) >= -1e-5 * np.abs(ordered[:-1])).all()


def test_evaluator_matches_greedy_bound():
    rng = np.random.default_rng(1)
    net, jobs = random_instance(rng, num_jobs=4)
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    val = float(annealing.evaluate_solution(
        net, batch, jnp.asarray(sol.assign), jnp.asarray(sol.order)))
    np.testing.assert_allclose(val, sol.makespan_bound, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_bound_dominates_simulation(seed):
    """The fictitious-system objective upper-bounds the simulated actual
    completion time (the paper's §III-B claim), on random instances."""
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=3)
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    if sol.makespan_bound >= 1e29:
        return
    sim = schedule.simulate(net, batch, sol.assign, sol.order)
    assert sim.makespan <= sol.makespan_bound * (1 + 1e-5)


def test_sa_warm_start_never_worse_than_greedy():
    rng = np.random.default_rng(5)
    net, jobs = random_instance(rng, num_jobs=4)
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    sa = annealing.anneal(net, batch, seed=2, d=0.97, num_chains=2,
                          init="greedy", block_move_prob=0.3)
    assert sa.bound() <= sol.bound() * (1 + 1e-5)


def test_replay_matches_greedy():
    rng = np.random.default_rng(7)
    net, jobs = random_instance(rng, num_jobs=4)
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    bounds, paths, final = schedule.replay_solution(
        net, batch, sol.assign, sol.order)
    np.testing.assert_allclose(bounds, sol.bounds, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final.q_node),
                               np.asarray(sol.net.q_node), rtol=1e-4)


def test_simulator_single_job_analytic():
    """One job, one compute node, serial path: completion = sum of terms."""
    net = N.make_network(3, [(0, 1, 10.0), (1, 2, 5.0)], [0, 2.0, 0])
    job = J.InferenceJob("j", 0, 2, np.array([4.0], np.float32),
                         np.array([10.0, 5.0], np.float32))
    batch = J.batch_jobs([job])
    sol = greedy.greedy_route(net, batch)
    # input 10B over link(0,1)@10 = 1s; compute 4/2 = 2s; out 5B over (1,2)@5 = 1s
    np.testing.assert_allclose(sol.makespan_bound, 4.0, rtol=1e-5)
    sim = schedule.simulate(net, batch, sol.assign, sol.order)
    np.testing.assert_allclose(sim.makespan, 4.0, rtol=1e-5)


def test_preemption_priority_order():
    """Two identical jobs on one node: priority-1 job finishes first."""
    net = N.make_network(2, [(0, 1, 1e9)], [0, 1.0])
    jobs = [J.InferenceJob(f"j{i}", 0, 1, np.array([1.0], np.float32),
                           np.array([0.0, 0.0], np.float32))
            for i in range(2)]
    batch = J.batch_jobs(jobs)
    sol = greedy.greedy_route(net, batch)
    sim = schedule.simulate(net, batch, sol.assign, sol.order)
    first = sol.order[0]
    assert sim.completion[first] <= sim.completion[sol.order[1]]
    np.testing.assert_allclose(sorted(sim.completion), [1.0, 2.0], rtol=1e-6)


def test_lazy_greedy_matches_eager():
    """Lazy greedy (monotone-cost caching) = Algorithm 1 up to ties."""
    from repro.core import greedy as G
    for seed in range(4):
        rng = np.random.default_rng(seed + 100)
        net, jobs = random_instance(rng, num_jobs=6)
        batch = J.batch_jobs(jobs)
        eager = G.greedy_route(net, batch)
        lazy = G.greedy_route(net, batch, lazy=True)
        np.testing.assert_allclose(lazy.makespan_bound, eager.makespan_bound,
                                   rtol=1e-5)
        assert lazy.meta["n_routings"] <= 6 * 6
