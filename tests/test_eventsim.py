"""Indexed event engine vs the reference loop: parity properties.

The engine (`repro.core.eventsim`) must reproduce the reference linear-scan
loop's trajectories — same preempt-resume priority semantics, same
tolerance discipline — up to float accumulation order (the reference
decrements every serving residual at every global event, the engine once
per head change).  Random systems exercise shared resources, random
priorities, staggered stage arrivals, finite drain-window splits, and the
dead-resource error path; the scheduler-level tests pin the persistent
engine's behaviour through commits, drains, rollbacks, and replays.
"""
import copy

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import completions as C, eventsim, jobs as J, schedule
from repro.scenarios import make_scenario
from repro.serving.online import OnlineScheduler, run_online


def _random_system(rng, *, staggered=False, V=5, max_tasks=6,
                   dead_node=None, t0=0.0):
    """Random rates + task stage lists (no solver involved: pure loop test)."""
    mu_node = rng.uniform(0.5, 3.0, V)
    mu_link = rng.uniform(0.5, 3.0, (V, V))
    if dead_node is not None:
        mu_node[dead_node] = 0.0
    n = int(rng.integers(1, max_tasks + 1))
    prios = rng.permutation(n)
    tasks = []
    for i in range(n):
        stages = []
        for _ in range(int(rng.integers(1, 7))):
            if rng.random() < 0.5:
                stages.append((("node", int(rng.integers(V))),
                               float(rng.uniform(0.2, 3.0))))
            else:
                u, v = rng.choice(V, 2, replace=False)
                stages.append((("link", int(u), int(v)),
                               float(rng.uniform(0.2, 3.0))))
        arrived = t0 + (float(rng.uniform(0, 3.0)) if staggered else 0.0)
        tasks.append(schedule.TaskRun(stages=stages, prio=int(prios[i]),
                                      arrived=arrived))
    return mu_node, mu_link, tasks


def _residual(task):
    """Total unfinished work of a task (current-stage residual included)."""
    out = 0.0
    for k in range(task.ptr, len(task.stages)):
        w = task.stages[k][1]
        if k == task.ptr and task.remaining is not None:
            w = task.remaining
        out += w
    return out


def _assert_same_outcome(ref, idx, *, rtol=1e-9, atol=1e-9):
    for a, b in zip(ref, idx):
        assert a.done == b.done
        if a.done:
            np.testing.assert_allclose(b.completion, a.completion,
                                       rtol=rtol, atol=atol)
        else:
            np.testing.assert_allclose(_residual(b), _residual(a),
                                       rtol=1e-7, atol=1e-7)
            np.testing.assert_allclose(b.arrived, a.arrived,
                                       rtol=rtol, atol=atol)


# -- to-completion parity -----------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_indexed_matches_ref_to_completion(seed, staggered):
    """Random priorities, shared resources, optional staggered arrivals:
    identical completion trajectories up to float accumulation order."""
    rng = np.random.default_rng(seed)
    mu_node, mu_link, tasks = _random_system(rng, staggered=staggered)
    ref = copy.deepcopy(tasks)
    idx = copy.deepcopy(tasks)
    t_ref = schedule.run_event_loop_ref(ref, mu_node, mu_link)
    t_idx = eventsim.run_event_loop_indexed(idx, mu_node, mu_link)
    _assert_same_outcome(ref, idx)
    np.testing.assert_allclose(t_idx, t_ref, rtol=1e-9, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_window_splits_compose_and_match_ref(seed):
    """Finite t_end windows: the persistent engine advanced window by
    window matches the reference loop run over the same windows *and* its
    own one-shot run (drain composition across arbitrary cuts)."""
    rng = np.random.default_rng(seed)
    mu_node, mu_link, tasks = _random_system(rng, staggered=True)
    ref = copy.deepcopy(tasks)
    idx = copy.deepcopy(tasks)
    one = copy.deepcopy(tasks)
    cuts = np.sort(rng.uniform(0.0, 12.0, 3))
    eng = eventsim.EventEngine(mu_node, mu_link)
    eng.add_tasks(idx)
    t = 0.0
    for c in cuts:
        schedule.run_event_loop_ref(ref, mu_node, mu_link, t=t, t_end=float(c))
        eng.advance(float(c))
        _assert_same_outcome(ref, idx, rtol=1e-7, atol=1e-7)
        t = float(c)
    schedule.run_event_loop_ref(ref, mu_node, mu_link, t=t)
    eng.advance()
    eventsim.run_event_loop_indexed(one, mu_node, mu_link)
    _assert_same_outcome(ref, idx)
    _assert_same_outcome(one, idx)   # windowing is invisible


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_zero_rate_resource_error_parity(seed):
    """A job routed over a dead resource raises the same error from both
    engines (and neither silently serves at rate 0)."""
    rng = np.random.default_rng(seed)
    V = 5
    dead = int(rng.integers(V))
    mu_node, mu_link, tasks = _random_system(rng, V=V, dead_node=dead)
    # force at least one stage onto the dead node
    victim = tasks[int(rng.integers(len(tasks)))]
    victim.stages[int(rng.integers(len(victim.stages)))] = (
        ("node", dead), 1.0)
    with pytest.raises(RuntimeError, match="dead resource"):
        schedule.run_event_loop_ref(copy.deepcopy(tasks), mu_node, mu_link)
    with pytest.raises(RuntimeError, match="dead resource"):
        eventsim.run_event_loop_indexed(copy.deepcopy(tasks), mu_node,
                                        mu_link)


# -- tolerance discipline -----------------------------------------------------

def test_time_eps_is_relative():
    """The arrival guard must not degrade to exact comparison at nonzero
    clock: eps scales with |t| (the seed's absolute 1e-18 was below one
    ulp for any t >~ 1e-2)."""
    assert schedule.time_eps(0.0) == 1e-12
    assert schedule.time_eps(1.0) == 1e-12
    t = 2.0**26
    assert t + schedule.time_eps(t) > t          # representable nudge
    assert t + 1e-18 == t                        # the seed guard was not
    assert schedule.time_eps(-t) == schedule.time_eps(t)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_large_clock_drain_matches_time_shifted_run(seed):
    """Regression for the absolute-epsilon guard: the same system released
    at clock 2^26 must reproduce the t=0 trajectory shifted, for both
    engines — event-time comparisons are relative, not absolute."""
    t0 = float(2**26)
    rng = np.random.default_rng(seed)
    mu_node, mu_link, base = _random_system(rng, staggered=True)
    shifted = copy.deepcopy(base)
    for task in shifted:
        task.arrived += t0
    schedule.run_event_loop_ref(base, mu_node, mu_link)
    for eng_tasks, runner in ((copy.deepcopy(shifted),
                               schedule.run_event_loop_ref),
                              (copy.deepcopy(shifted),
                               eventsim.run_event_loop_indexed)):
        runner(eng_tasks, mu_node, mu_link, t=t0)
        for a, b in zip(base, eng_tasks):
            assert b.done
            np.testing.assert_allclose(b.completion - t0, a.completion,
                                       rtol=1e-9, atol=1e-4)


# -- persistent engine through the serving stack ------------------------------

def _lockstep_schedulers(sc, seeds=(0,), arrivals=6, **kw):
    """Two exact-mode schedulers fed identical jobs, one per engine."""
    scheds = {eng: OnlineScheduler(sc.topology, drain="exact",
                                   sim_engine=eng, **kw)
              for eng in ("indexed", "ref")}
    rng = np.random.default_rng(11)
    t = 0.0
    for _ in range(arrivals):
        jobs = sc.sample_jobs(rng, 1)
        for sched in scheds.values():
            sched.submit_jobs(t, list(jobs), pad_to=sc.max_layers)
        t += float(rng.uniform(0.05, 0.4))
    return scheds


def test_scheduler_engines_agree_end_to_end():
    """The full online loop — drains, commits, ledger-materialized queue
    states, final completions — agrees between the persistent indexed
    engine and the per-window reference loop."""
    sc = make_scenario("star", seed=0)
    scheds = _lockstep_schedulers(sc)
    a, b = scheds["indexed"], scheds["ref"]
    # the solver saw the same ledger-materialized queues at every arrival
    la = np.array([r.latencies for r in a.trace.records], np.float64)
    lb = np.array([r.latencies for r in b.trace.records], np.float64)
    np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-6)
    ca, cb = a.finish(), b.finish()
    assert ca.keys() == cb.keys()
    for name in ca:
        np.testing.assert_allclose(ca[name], cb[name], rtol=1e-7, atol=1e-7)


def test_persistent_engine_is_threaded_not_rebuilt():
    """Sequential drains/commits reuse one live index: the ledger returned
    by each step carries the same engine object, while a stale snapshot
    (rollback semantics) loses the slot and rebuilds lazily."""
    sc = make_scenario("star", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact")
    rng = np.random.default_rng(3)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    eng0 = C._engine_of(sched.ledger)
    assert eng0 is not None
    snapshot = sched.ledger
    sched.advance_to(0.05)
    sched.submit_jobs(0.1, sc.sample_jobs(rng, 1), pad_to=sc.max_layers)
    assert C._engine_of(sched.ledger) is eng0        # same index, threaded
    assert C._engine_of(snapshot) is None            # snapshot went stale
    # and the stale snapshot still drains correctly (lazy rebuild)
    re = C.drain_exact(sc.topology, snapshot, 0.05)
    ref = C.drain_exact(sc.topology, snapshot, 0.05, engine="ref")
    np.testing.assert_allclose(re.queue_arrays()[0], ref.queue_arrays()[0],
                               rtol=1e-6, atol=1e-6)


def test_replan_rollback_with_indexed_engine():
    """replan_last's ledger rollback works on the indexed engine: the
    pre-batch snapshot rebuilds, drains the elapsed window, and the chain
    continues without double counting."""
    sc = make_scenario("edge-cloud", traffic="synthetic", seed=0)
    for eng in ("indexed", "ref"):
        sched = OnlineScheduler(sc.topology, drain="exact", sim_engine=eng)
        rng = np.random.default_rng(3)
        sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
        sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
        assert len(sched.ledger.jobs) == 4
        sched.advance_to(1e9)
        assert not sched.ledger.jobs
        sched.replan_last()
        assert len(sched.ledger.jobs) == 2


def test_exact_backlog_trace_single_pass_matches_ref():
    """The one-forward-pass engine trace equals the seed per-sample
    rebuild, on a commit log from a real fluid run."""
    sc = make_scenario("star", seed=0)
    rate = sc.nominal_rate(0.7)
    tr = run_online(sc, horizon=20 / rate, seed=3, rate=rate,
                    track_commits=True)
    fast = C.exact_backlog_trace(sc.topology, tr.commit_log, tr.times)
    ref = C.exact_backlog_trace(sc.topology, tr.commit_log, tr.times,
                                engine="ref")
    np.testing.assert_allclose(fast, ref, rtol=1e-5, atol=1e-6)


# -- piecewise-health ground truth -------------------------------------------

def test_piecewise_replay_matches_incremental_through_slowdown():
    """With a mid-run straggler, the incremental exact drain served each
    window at the health then in force; the ground-truth replay now does
    too (the seed replayed one end-state topology for the whole horizon,
    so completion times disagreed whenever health changed mid-run)."""
    sc = make_scenario("star", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact", track_commits=True)
    rng = np.random.default_rng(5)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    victim = int(sched.last_plan.assign[int(sched.last_plan.order[0]), 0])
    sched.report_slowdown(victim, 6.0, at=0.02)
    sched.submit_jobs(0.05, sc.sample_jobs(rng, 1), pad_to=sc.max_layers)
    incremental = sched.finish()
    assert sched.commit_log.health == ((0.02, victim, 6.0),)
    replay = sched.replay_ground_truth()
    assert incremental.keys() == replay.keys()
    for name in incremental:
        np.testing.assert_allclose(replay[name], incremental[name],
                                   rtol=1e-6, atol=1e-6)
    # the end-state-topology replay is *not* the truth here
    end_state, _ = C.run_to_completion(sched._effective_topology(),
                                       sched.commit_log)
    worst = max(abs(end_state[n] - incremental[n]) for n in incremental)
    assert worst > 1e-4


def test_replan_keeps_health_history_in_commit_log():
    """Rolling back the superseded batch must not erase straggler records:
    the health history survives replan_last."""
    sc = make_scenario("edge-cloud", traffic="synthetic", seed=0)
    sched = OnlineScheduler(sc.topology, drain="exact", track_commits=True)
    rng = np.random.default_rng(7)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 1), pad_to=sc.max_layers)
    sched.submit_jobs(0.2, sc.sample_jobs(rng, 1), pad_to=sc.max_layers)
    sched.report_slowdown(0, 2.0, at=0.3)
    sched.replan_last()
    assert len(sched.commit_log.health) == 1
    at, node, factor = sched.commit_log.health[0]
    assert (at, node, factor) == (0.3, 0, 2.0)


def test_solver_extracted_paths_match_replay():
    """greedy/lazy extract_paths=True fills plan.paths during the solve
    (one extraction per round, reusing the round's closures) with exactly
    the hops replay_solution derives — and leaves bounds untouched."""
    from repro.core import schedule as S, solve

    sc = make_scenario("edge-cloud", traffic="synthetic", seed=0)
    rng = np.random.default_rng(9)
    batch = J.batch_jobs(sc.sample_jobs(rng, 4), pad_to=sc.max_layers)
    net = sc.topology.view()
    for method in ("greedy", "lazy"):
        plan = solve(net, batch, method=method, extract_paths=True)
        assert plan.paths is not None and set(plan.paths) == set(range(4))
        _, paths, _ = S.replay_solution(net, batch, plan.assign, plan.order)
        assert plan.paths == paths
        base = solve(net, batch, method=method)
        assert base.paths is None
        np.testing.assert_array_equal(np.asarray(base.assign),
                                      np.asarray(plan.assign))
        assert base.bounds.tolist() == plan.bounds.tolist()


# -- engine selection ---------------------------------------------------------

def test_engine_validation():
    with pytest.raises(ValueError, match="engine must be"):
        schedule.run_event_loop([], np.ones(1), np.ones((1, 1)),
                                engine="magic")
    sc = make_scenario("star", seed=0)
    with pytest.raises(ValueError, match="sim_engine must be"):
        OnlineScheduler(sc.topology, drain="exact", sim_engine="magic")
    led = C.CommittedWork.empty(3)
    with pytest.raises(ValueError, match="engine must be"):
        C.drain_exact(None, led, 1.0, engine="magic")


def test_simulate_engine_param_agrees():
    """One-shot simulate: default (ref) and indexed engines agree on a
    solved instance; the default path is the reference loop, so seed
    results are unchanged bit-for-bit."""
    from repro.core import solve
    from util import random_instance

    rng = np.random.default_rng(2)
    net, jobs = random_instance(rng, num_jobs=3)
    batch = J.batch_jobs(jobs)
    plan = solve(net, batch, method="greedy")
    if plan.makespan_bound >= 1e29:
        pytest.skip("disconnected instance")
    ref = schedule.simulate(net, batch, plan)
    idx = schedule.simulate(net, batch, plan, engine="indexed")
    np.testing.assert_allclose(idx.completion, ref.completion,
                               rtol=1e-9, atol=1e-9)
    again = schedule.simulate(net, batch, plan)   # default == ref, bitwise
    assert again.completion.tolist() == ref.completion.tolist()
