"""Fused single-dispatch greedy solver: bit-identity vs the host-loop
reference (``greedy_route_ref``) across a seeded scenario catalog, honest
dispatch accounting, cross-arrival multi-window parity, scheduler-level
lockstep, and warmup purity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import greedy, jobs as J, network as N, solvers
from repro.core import shortest_path as SP
from repro.scenarios import make_scenario
from repro.serving.online import OnlineScheduler
from util import random_instance


def _assert_plans_bitwise(fused, ref, *, paths=False):
    assert fused.order.tolist() == ref.order.tolist()
    np.testing.assert_array_equal(np.asarray(fused.assign),
                                  np.asarray(ref.assign))
    assert np.asarray(fused.bounds).tolist() == np.asarray(ref.bounds).tolist()
    np.testing.assert_array_equal(np.asarray(fused.net.q_node),
                                  np.asarray(ref.net.q_node))
    np.testing.assert_array_equal(np.asarray(fused.net.q_link),
                                  np.asarray(ref.net.q_link))
    if paths:
        assert fused.paths == ref.paths


# ---------------------------------------------------------------------------
# Scenario-catalog bit-identity (the CI parity gate's test-suite twin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,num_jobs,with_queues", [
    (0, 5, False), (1, 5, True), (2, 7, True),   # 7: odd J exercises pow2 pad
    (3, 3, False), (4, 8, True), (5, 1, True),
])
def test_fused_bit_identical_to_ref(seed, num_jobs, with_queues):
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=num_jobs,
                                with_queues=with_queues)
    batch = J.batch_jobs(jobs)
    fused = greedy.greedy_route(net, batch)
    ref = greedy.greedy_route_ref(net, batch)
    _assert_plans_bitwise(fused, ref)


@pytest.mark.parametrize("with_queues", [False, True])
def test_fused_extract_paths_matches_ref(with_queues):
    """The post-pass path extraction replays the reference's per-round
    extraction bit-for-bit — including at queued states, where an
    FMA-contracted edge weight would flip equal-cost hop ties."""
    rng = np.random.default_rng(10 + with_queues)
    net, jobs = random_instance(rng, num_jobs=6, with_queues=with_queues)
    batch = J.batch_jobs(jobs)
    fused = greedy.greedy_route(net, batch, extract_paths=True)
    ref = greedy.greedy_route_ref(net, batch, extract_paths=True)
    _assert_plans_bitwise(fused, ref, paths=True)
    assert set(fused.paths) == set(range(batch.num_jobs))


def test_fused_dedupe_rows_bit_identical():
    """Duplicate data rows (the dedupe fast path) keep bit-identity."""
    rng = np.random.default_rng(20)
    net, jobs = random_instance(rng, num_jobs=3, with_queues=True)
    base = jobs[0]
    twins = [dataclasses.replace(base, name=f"twin{i}", src=int(s), dst=int(d))
             if dataclasses.is_dataclass(base) else base
             for i, (s, d) in enumerate([(1, 4), (2, 5)])]
    if not dataclasses.is_dataclass(base):  # plain class: rebuild by hand
        twins = [J.InferenceJob(f"twin{i}", int(s), int(d),
                                base.comp.copy(), base.data.copy())
                 for i, (s, d) in enumerate([(1, 4), (2, 5)])]
    batch = J.batch_jobs(jobs + twins)
    dp = SP.dedupe_plan(batch)
    assert dp.uniq.shape[0] < batch.num_jobs  # dedupe actually engaged
    _assert_plans_bitwise(greedy.greedy_route(net, batch),
                          greedy.greedy_route_ref(net, batch))


def test_fused_unroutable_inf_tie():
    """A stranded job's INF-clipped cost must not tie into the routed-job
    mask inside the fused scan (same guard as the host loop)."""
    net = N.make_network(4, [(0, 1, 2.0), (1, 2, 2.0)],
                         [0.0, 1.0, 1.0, 1.0])  # node 3 unreachable
    j0 = J.InferenceJob("ok", 0, 2, np.array([1.0], np.float32),
                        np.array([2.0, 2.0], np.float32))
    j1 = J.InferenceJob("stranded", 0, 3, np.array([1.0], np.float32),
                        np.array([2.0, 2.0], np.float32))
    batch = J.batch_jobs([j0, j1])
    fused = greedy.greedy_route(net, batch)
    ref = greedy.greedy_route_ref(net, batch)
    _assert_plans_bitwise(fused, ref)
    assert fused.order[0] == 0
    assert fused.bounds[1] >= 1e29


# ---------------------------------------------------------------------------
# Honest dispatch accounting
# ---------------------------------------------------------------------------

def test_fused_solve_is_one_dispatch():
    rng = np.random.default_rng(30)
    net, jobs = random_instance(rng, num_jobs=8)  # 8 = pow2: exact meta
    batch = J.batch_jobs(jobs)
    greedy.greedy_route(net, batch)     # compile warmup, outside the guard
    SP.reset_closure_build_count()
    greedy.reset_fused_dispatch_count()
    # transfer_guard("disallow") is the runtime complement of lint rule
    # RL003: any *implicit* host<->device transfer in the warm solve path
    # (all staging must be explicit jax.device_put) fails loudly here, not
    # just via the dispatch counter.
    with jax.transfer_guard("disallow"):
        plan = greedy.greedy_route(net, batch)
    assert greedy.fused_dispatch_count() == 1
    assert SP.closure_build_count() == 0
    assert plan.meta["fused"] is True
    assert plan.meta["dispatches"] == 1
    assert plan.meta["rounds_per_dispatch"] == batch.num_jobs
    assert plan.meta["windows_per_dispatch"] == 1
    # a second solve at the same shapes must not recompile
    with jax.transfer_guard("disallow"):
        greedy.greedy_route(net, batch)
    assert greedy.fused_dispatch_count() == 2


# ---------------------------------------------------------------------------
# Cross-arrival multi-window parity
# ---------------------------------------------------------------------------

def test_multi_window_matches_sequential_fused():
    """W ragged windows in one multi-window dispatch == W sequential fused
    solves threading committed queues, bit-for-bit."""
    rng = np.random.default_rng(40)
    net, jobs = random_instance(rng, num_jobs=12, with_queues=True)
    sizes = (5, 3, 4)
    batches, off = [], 0
    for n in sizes:
        batches.append(J.batch_jobs(jobs[off:off + n],
                                    pad_to=max(j.num_layers
                                               for j in jobs)))
        off += n
    greedy.greedy_route_windows(net, batches, extract_paths=True)  # warmup
    greedy.reset_fused_dispatch_count()
    # warm multi-window solve must also be implicit-transfer-free (RL003's
    # runtime complement) — ragged windows are padded/staged via device_put
    with jax.transfer_guard("disallow"):
        fused = greedy.greedy_route_windows(net, batches, extract_paths=True)
    assert greedy.fused_dispatch_count() == 1
    cur, seq = net, []
    for b in batches:
        p = greedy.greedy_route(cur, b, extract_paths=True)
        seq.append(p)
        cur = p.net
    for pf, ps in zip(fused, seq):
        _assert_plans_bitwise(pf, ps, paths=True)
        assert pf.meta["windows_per_dispatch"] == len(sizes)


def test_solve_fused_entrypoint_meta():
    rng = np.random.default_rng(41)
    net, jobs = random_instance(rng, num_jobs=6)
    lmax = max(j.num_layers for j in jobs)
    batches = [J.batch_jobs(jobs[:4], pad_to=lmax),
               J.batch_jobs(jobs[4:], pad_to=lmax)]
    plans = solvers.solve_fused(net, batches)
    assert len(plans) == 2
    total_share = sum(p.meta["solve_share_s"] for p in plans)
    for p in plans:
        assert p.meta["fused"] is True
        assert p.meta["solve_share_s"] <= p.meta["solve_s"]
    assert total_share == pytest.approx(plans[0].meta["solve_s"], rel=1e-6)


# ---------------------------------------------------------------------------
# Scheduler-level lockstep
# ---------------------------------------------------------------------------

def _run_online(sc, method, n_windows=3, per=4):
    rng = np.random.default_rng(9)
    s = OnlineScheduler(sc.topology, drain="exact", sim_engine="indexed",
                        track_commits=True, method=method)
    t = 0.0
    for _ in range(n_windows):
        t += 0.05
        s.submit_window(t, sc.sample_jobs(rng, per), pad_to=sc.max_layers)
    s.finish()
    return s, s.replay_ground_truth()


def test_online_fused_reproduces_serial_trace():
    """Exact-mode online run with the fused solver == the greedy_ref run:
    every recorded latency, backlog, completion and replayed ground truth
    (compares values, not names — the scenario job-name counter differs
    between runs)."""
    sc = make_scenario("paper-small", seed=0)
    (sf, gf), (sr, gr) = (_run_online(sc, "greedy"),
                          _run_online(sc, "greedy_ref"))
    for x, y in zip(sf.trace.records, sr.trace.records):
        assert x.latencies == y.latencies
        assert x.backlog_before == y.backlog_before
        assert x.backlog_after == y.backlog_after
    assert (list(sf.trace.completions.values())
            == list(sr.trace.completions.values()))
    assert list(gf.values()) == list(gr.values())


def test_submit_windows_matches_sequential_submits():
    """Fused cross-arrival submission vs W sequential submit_window calls.

    Fluid mode is bit-identical.  Exact mode re-materializes queues from
    the ledger between sequential commits while the fused chain threads
    the solver's committed queues mid-dispatch, so recorded *bounds* may
    drift by f32-ulp rounding (~1e-7 relative); committed work — and
    hence completions and replayed ground truth — stays bitwise equal,
    as does the backlog telemetry (read from per-window post-commit
    snapshots)."""
    sc = make_scenario("paper-small", seed=0)

    def run(mode, drain):
        rng = np.random.default_rng(7)
        kw = (dict(track_commits=True, sim_engine="indexed")
              if drain == "exact" else {})
        s = OnlineScheduler(sc.topology, drain=drain, **kw)
        t = 0.0
        for _ in range(3):
            t += 0.05
            wins = [sc.sample_jobs(rng, n) for n in (4, 3)]
            if mode == "fused":
                s.submit_windows(t, wins, pad_to=sc.max_layers)
            else:
                for w in wins:
                    s.submit_window(t, w, pad_to=sc.max_layers)
        if drain == "exact":
            s.finish()
        return s

    for drain in ("fluid", "exact"):
        mf, ms = run("fused", drain), run("seq", drain)
        assert len(mf.trace.records) == len(ms.trace.records)
        for x, y in zip(mf.trace.records, ms.trace.records):
            assert x.backlog_before == y.backlog_before
            assert x.backlog_after == y.backlog_after
            if drain == "fluid":
                assert x.latencies == y.latencies
            else:
                np.testing.assert_allclose(np.asarray(x.latencies),
                                           np.asarray(y.latencies),
                                           rtol=1e-5)
        if drain == "exact":
            assert (list(mf.trace.completions.values())
                    == list(ms.trace.completions.values()))
            assert (list(mf.replay_ground_truth().values())
                    == list(ms.replay_ground_truth().values()))


# ---------------------------------------------------------------------------
# Warmup purity
# ---------------------------------------------------------------------------

def test_warmup_is_pure_and_caches():
    sc = make_scenario("paper-small", seed=0)
    rng = np.random.default_rng(50)
    s = OnlineScheduler(sc.topology, drain="exact", sim_engine="indexed",
                        track_commits=True)
    sample = sc.sample_jobs(rng, 5)
    qn0 = np.asarray(s.state.q_node).copy()
    ql0 = np.asarray(s.state.q_link).copy()
    clock0, ledger0 = s._now, s.ledger
    n_records0 = len(s.trace.records)
    out = s.warmup(sample, pad_to=sc.max_layers, window_counts=(2,))
    assert out["compiles"] >= 1
    assert out["wall_s"] > 0
    np.testing.assert_array_equal(np.asarray(s.state.q_node), qn0)
    np.testing.assert_array_equal(np.asarray(s.state.q_link), ql0)
    assert s._now == clock0 and s.ledger is ledger0
    assert len(s.trace.records) == n_records0
    # warmed shapes: a second warmup compiles nothing
    again = s.warmup(sample, pad_to=sc.max_layers, window_counts=(2,))
    assert again["compiles"] == 0
