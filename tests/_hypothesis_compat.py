"""Hypothesis if installed, else a deterministic seeded fallback.

The tier-1 suite used to hard-import ``hypothesis`` from four modules, so a
container without it aborted the whole collection.  ``pytest.importorskip``
would silence that but also skip every *non*-property test in those
modules.  Instead this shim re-exports the real library when available and
otherwise substitutes a minimal ``@given``/``@settings``/``st`` that runs
each property test over a fixed number of seeded draws — reduced search
breadth, full collection, zero lost tests.

Only the strategies the suite actually uses are implemented
(``st.integers``, ``st.booleans``); install the real package
(``pip install -r requirements-dev.txt``) for shrinking and the full
search.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        # NB: no functools.wraps — pytest must see a zero-arg signature, or
        # it treats the strategy parameters as (missing) fixtures.
        def deco(fn):
            def run():
                n = getattr(run, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    fn(*(s.draw(rng) for s in strats))
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco
