"""Data pipeline, checkpointing (incl. elastic restore), gradient
compression, fault-tolerant restart, schedules."""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.optim.adamw import AdamW
from repro.optim.grad_compress import Int8Compressor, topk_mask
from repro.optim import schedules

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=97, seq_len=32, global_batch=8, seed=3)
    s0 = SyntheticStream(cfg, shard_id=0, num_shards=2)
    s1 = SyntheticStream(cfg, shard_id=1, num_shards=2)
    a = s0.batch_at(5)
    b = s0.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])     # pure fn
    c = s1.batch_at(5)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))           # disjoint
    assert a["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(np.asarray(a["tokens"][:, 1:]),
                                  np.asarray(a["labels"][:, :-1]))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(tmp_path, 7, tree)
    assert ckpt.latest_step(tmp_path) == 7
    out = ckpt.restore(tmp_path, 7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == np.asarray(tree["b"]["c"]).dtype


def test_checkpoint_atomicity(tmp_path):
    tree = {"x": jnp.zeros((3,))}
    ckpt.save(tmp_path, 1, tree)
    # a stale tmp dir (simulated crash) must not confuse latest_step
    (tmp_path / ".tmp_dead").mkdir()
    assert ckpt.latest_step(tmp_path) == 1
    ckpt.save(tmp_path, 2, tree)
    assert ckpt.latest_step(tmp_path) == 2
    ckpt.prune(tmp_path, keep=1)
    assert ckpt.latest_step(tmp_path) == 2
    assert not (tmp_path / "step_00000001").exists()


def test_elastic_restore_across_meshes(tmp_path):
    """Save on 1 device, restore+place onto an 8-device mesh (subprocess with
    forced host device count), verify values and shardings."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(tmp_path, 3, tree)
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {SRC!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import ckpt
tree = {{"w": jnp.zeros((8, 8), jnp.float32)}}
host = ckpt.restore({str(tmp_path)!r}, 3, tree)
mesh = jax.make_mesh((4, 2), ("data", "model"), devices=jax.devices()[:8])
sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
placed = ckpt.place(host, sh)
assert placed["w"].sharding.num_devices == 8
np.testing.assert_array_equal(np.asarray(placed["w"]).ravel(),
                              np.arange(64, dtype=np.float32))
print("ELASTIC_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


def test_int8_error_feedback_converges():
    """Compressed-gradient descent tracks exact descent on a quadratic."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(16, 16)) / 4 + np.eye(16))
    b = jnp.asarray(rng.normal(size=(16,)))
    loss = lambda x: 0.5 * x @ A @ A.T @ x - b @ x
    grad = jax.grad(loss)
    comp = Int8Compressor()

    x_exact = jnp.zeros((16,))
    x_comp = jnp.zeros((16,))
    err = comp.init({"x": x_comp})
    lr = 0.05
    for _ in range(300):
        x_exact = x_exact - lr * grad(x_exact)
        g, err = comp.roundtrip({"x": grad(x_comp)}, err)
        x_comp = x_comp - lr * g["x"]
    l_exact, l_comp = float(loss(x_exact)), float(loss(x_comp))
    assert l_comp < l_exact + 1e-2 * (abs(l_exact) + 1)
    assert comp.compressed_bytes({"x": x_comp}) * 4 == \
        comp.raw_bytes({"x": x_comp})


def test_topk_mask():
    g = jnp.asarray([3.0, -5.0, 0.1, 0.2])
    out = np.asarray(topk_mask(g, 0.5))
    np.testing.assert_array_equal(out, [3.0, -5.0, 0.0, 0.0])


def test_schedules():
    wsd = schedules.wsd(jnp.asarray([0, 100, 5000, 10500]),
                        peak_lr=1.0, warmup_steps=200, stable_steps=9800,
                        decay_steps=1000)
    assert float(wsd[0]) == 0.0
    assert float(wsd[1]) == 0.5
    assert float(wsd[2]) == 1.0
    assert float(wsd[3]) < 1.0
    cos = schedules.warmup_cosine(jnp.asarray([0, 100, 100000]),
                                  peak_lr=1.0, warmup_steps=200,
                                  total_steps=100000)
    assert float(cos[2]) <= 0.11


def test_train_restart_bit_identical(tmp_path):
    """Kill at step 35, restart, final losses equal an uninterrupted run."""
    from repro.launch.train import train
    kw = dict(preset="smoke", steps=60, batch=2, seq=32, ckpt_every=20,
              log_every=1000)
    full = train("smollm_135m", **kw)
    try:
        train("smollm_135m", ckpt_dir=tmp_path, fail_at=35, **kw)
        raise AssertionError("injected failure did not fire")
    except RuntimeError as e:
        assert "injected node failure" in str(e)
    resumed = train("smollm_135m", ckpt_dir=tmp_path, **kw)
    assert resumed.resumed_from == 20
    np.testing.assert_allclose(resumed.losses[-1], full.losses[-1],
                               rtol=1e-4)


def test_adamw_step():
    opt = AdamW(schedule=lambda s: 0.1)
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 0.5)}
    st = opt.init(params)
    p2, st2, info = opt.apply(params, grads, st)
    assert float(info["grad_norm"]) == 1.0
    assert int(st2["step"]) == 1
    assert np.all(np.asarray(p2["w"]) < 1.0)
