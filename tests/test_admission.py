"""Deadline-aware admission control & SLO-guarded auto re-planning:
policy validation, predicted-miss gating (reject/defer), original-arrival
expiry accounting, the measured-EMA cold-start seed, replan reasons, and
the monitor's hysteresis (cooldown + exponential backoff + budget)."""

import dataclasses
import types

import numpy as np
import pytest

from repro.core import jobs as J
from repro.scenarios import make_scenario
from repro.serving.admission import (AdmissionController, AdmissionPolicy,
                                     ReplanMonitor, ReplanPolicy)
from repro.serving.faults import FaultEvent
from repro.serving.online import OnlineScheduler, run_online
from repro.serving.stream import StreamConfig, StreamingPipeline, run_stream


@pytest.fixture(scope="module")
def scenario():
    return make_scenario("paper-small", seed=0)


# -- validation ---------------------------------------------------------------

def test_admission_policy_validation():
    with pytest.raises(ValueError, match="admission policy"):
        AdmissionPolicy(policy="bogus")
    with pytest.raises(ValueError, match="margin_s"):
        AdmissionPolicy(policy="reject", margin_s=-1.0)
    ctl = AdmissionController("defer")
    assert ctl.policy.policy == "defer" and ctl.gating
    assert not AdmissionController().gating       # admit_all default


def test_replan_policy_validation():
    for bad in (dict(threshold=-0.1), dict(cooldown_s=-1.0),
                dict(backoff=0.5), dict(budget=-1),
                dict(min_improvement=1.0),
                dict(cooldown_s=10.0, max_cooldown_s=1.0)):
        with pytest.raises(ValueError):
            ReplanPolicy(**bad)


def test_job_deadline_field():
    job = J.synthetic_job("d0", 0, 1, 3)
    assert job.deadline_s == float("inf")         # default: no SLO
    tight = job.with_deadline(0.25)
    assert tight.deadline_s == 0.25 and job.deadline_s == float("inf")
    with pytest.raises(ValueError, match="deadline_s"):
        J.InferenceJob("d1", 0, 1, job.comp, job.data, deadline_s=0.0)
    with pytest.raises(ValueError, match="deadline_s"):
        job.with_deadline(float("nan"))


# -- predicted-miss gating ----------------------------------------------------

def test_reject_policy_beats_admit_all_under_overload(scenario):
    """The acceptance contrast: under overload, deadline-aware admission
    has a strictly lower SLO-miss rate at equal-or-better goodput."""
    rate = scenario.nominal_rate(2.5)
    kw = dict(horizon=12 / rate, seed=3, rate=rate, batch_size=2,
              drain="exact", finish=True,
              deadline_s=1.2 * scenario.mean_service_s)
    base = run_online(scenario, admission="admit_all", **kw).summary()
    gated = run_online(scenario, admission="reject", **kw).summary()
    assert gated["slo"]["slo_miss_rate"] < base["slo"]["slo_miss_rate"]
    assert gated["slo"]["goodput"] >= base["slo"]["goodput"]
    assert gated["shed_by_reason"].get("admission_reject", 0) > 0
    assert gated["admission"]["rejected"] == \
        gated["shed_by_reason"]["admission_reject"]
    # exact predictions: every admitted request actually met its SLO
    assert gated["slo"]["late"] == 0


def test_defer_then_expire_charged_from_original_arrival(scenario):
    """A deferred request that can no longer make its deadline is shed as
    ``deadline_miss`` with its ORIGINAL arrival instant in the record."""
    sched = OnlineScheduler(scenario.topology, drain="exact",
                            admission="defer")
    rng = np.random.default_rng(4)
    filler = scenario.sample_jobs(rng, 3)
    (victim,) = scenario.sample_jobs(rng, 1)
    victim = victim.with_deadline(1e-3)   # can never be met once queued
    sched.submit_jobs(0.0, filler + [victim], pad_to=scenario.max_layers)
    assert [j.name for j, _ in sched.admission.deferred] == [victim.name]
    # next window, past the deadline: the deferral expires
    later = scenario.sample_jobs(rng, 1)
    sched.submit_jobs(0.5, later, pad_to=scenario.max_layers)
    (rec,) = [s for s in sched.trace.shed if s["name"] == victim.name]
    assert rec["reason"] == "deadline_miss"
    assert rec["arrival"] == 0.0 and rec["time"] == 0.5
    assert sched.trace.arrivals_by_name[victim.name] == 0.0
    assert sched.admission.counters["expired"] == 1


def test_flush_deferred_drains_out(scenario):
    sched = OnlineScheduler(scenario.topology, drain="exact",
                            admission="defer")
    rng = np.random.default_rng(6)
    jobs = [j.with_deadline(1e-3) for j in scenario.sample_jobs(rng, 2)]
    filler = scenario.sample_jobs(rng, 2)
    sched.submit_jobs(0.0, filler + jobs, pad_to=scenario.max_layers)
    assert len(sched.admission.deferred) == 2
    placed = sched.flush_deferred(at=0.25, pad_to=scenario.max_layers)
    assert placed == [] and not sched.admission.deferred
    assert not sched.admission.final          # reset even on the shed path
    by = sched.trace.shed_by_reason()
    assert by.get("deadline_miss", 0) == 2


def test_submit_windows_rejects_gating_admission(scenario):
    sched = OnlineScheduler(scenario.topology, drain="exact",
                            admission="reject")
    rng = np.random.default_rng(8)
    with pytest.raises(ValueError, match="one at a time"):
        sched.submit_windows(0.0, [scenario.sample_jobs(rng, 1)])


def test_streaming_defer_preserves_original_arrival(scenario):
    """Through the pipeline, admission-deferred requests re-enter with
    their original arrival and a later expiry is charged from it."""
    rate = scenario.nominal_rate(2.5)
    tr = run_stream(scenario, horizon=8 / rate, seed=3, rate=rate,
                    batch_size=2, window_s=0.5 / rate, max_batch=4,
                    drain="exact", finish=True,
                    deadline_s=1.2 * scenario.mean_service_s,
                    admission="defer")
    misses = [s for s in tr.shed if s["reason"] == "deadline_miss"]
    assert misses, "overloaded defer run must eventually shed"
    for s in misses:
        assert s["time"] >= s["arrival"]
        assert tr.arrivals_by_name[s["name"]] == s["arrival"]
    s = tr.summary()
    assert s["slo"]["pending"] == 0          # finish + drain-out decide all
    assert s["slo"]["offered"] == (s["slo"]["met"] + s["slo"]["late"]
                                   + s["slo"]["shed"])


# -- measured-EMA cold start --------------------------------------------------

def test_seed_latency_fixes_ema_cold_start(scenario):
    cfg = StreamConfig(solver_latency="measured")
    pipe = StreamingPipeline(scenario.topology, cfg, drain="exact")
    assert pipe._model_latency() == 0.0           # the old cold-start hole
    pipe.seed_latency(0.02)
    assert pipe._model_latency() == 0.02
    pipe.seed_latency(0.5)                        # no-op once seeded
    assert pipe._model_latency() == 0.02
    pipe._observe_solve(0.04)                     # EMA folds real walls in
    assert pipe._model_latency() == pytest.approx(0.03)


def test_warmup_seeds_measured_latency_model(scenario):
    """Regression: with warmup, the *first* window's commit already models
    a positive solver latency instead of riding free."""
    rate = scenario.nominal_rate(0.5)
    tr = run_stream(scenario, horizon=4 / rate, seed=3, rate=rate,
                    solver_latency="measured", warmup=True, drain="exact")
    assert tr.windows[0].solve_model_s > 0.0
    assert tr.windows[0].commit_s > tr.windows[0].close_s


def test_warmup_reports_compile_free_solve_wall(scenario):
    sched = OnlineScheduler(scenario.topology, drain="exact")
    rng = np.random.default_rng(5)
    info = sched.warmup(scenario.sample_jobs(rng, 2),
                        pad_to=scenario.max_layers)
    assert info["warm_solve_s"] > 0.0
    assert info["warm_solve_s"] < info["wall_s"]  # excludes compile walls


# -- replan reasons & monitor hysteresis -------------------------------------

def test_replan_reasons_recorded(scenario):
    sched = OnlineScheduler(scenario.topology, drain="exact")
    assert sched.replan_last() is None
    assert sched.last_replan_reason == "no_batch"
    rng = np.random.default_rng(12)
    sched.submit_jobs(0.0, scenario.sample_jobs(rng, 2),
                      pad_to=scenario.max_layers)
    # steady health: a re-solve ties, so any positive margin declines it
    assert sched.replan_last(min_improvement=0.25) is None
    assert sched.last_replan_reason == "no_improvement"
    assert sched.replan_last() is not None        # manual = always commit
    assert sched.last_replan_reason == "replanned"
    events = [e["event"] for e in sched.trace.events]
    assert events.count("replan_skipped") == 2
    assert events.count("replan") == 1
    s = sched.trace.summary()
    assert s["replans"] == 1
    assert s["replans_skipped"] == {"no_batch": 1, "no_improvement": 1}


def _fake_sched(divergences):
    """Minimal stand-in for the monitor's scheduler surface."""
    sched = types.SimpleNamespace(
        now=0.0, trace=types.SimpleNamespace(events=[]), committed=0)
    seq = iter(divergences)

    def plan_divergence():
        return next(seq)

    def replan_last(*, min_improvement=None):
        sched.committed += 1
        return ["placement"]

    sched.plan_divergence = plan_divergence
    sched.replan_last = replan_last
    return sched


def test_monitor_threshold_and_calm_reset():
    mon = ReplanMonitor(ReplanPolicy(threshold=0.5, cooldown_s=1.0,
                                     backoff=2.0, max_cooldown_s=8.0))
    sched = _fake_sched([0.2, None, 0.8])
    assert not mon.check(sched)                   # under threshold
    assert not mon.check(sched)                   # no data
    assert mon.check(sched)                       # crossed: triggers
    assert mon.triggers == 1 and sched.committed == 1


def test_monitor_cooldown_and_exponential_backoff():
    mon = ReplanMonitor(ReplanPolicy(threshold=0.1, cooldown_s=1.0,
                                     backoff=2.0, max_cooldown_s=8.0))
    sched = _fake_sched([1.0, 1.0, 1.0, 1.0, 1.0, 1.0])
    sched.now = 0.0
    assert mon.check(sched)                       # t=0: quiet until 1.0
    sched.now = 0.5
    assert not mon.check(sched)                   # cooling down — no call
    sched.now = 1.0
    assert mon.check(sched)                       # t=1: quiet until 3.0 (2x)
    sched.now = 2.5
    assert not mon.check(sched)
    sched.now = 3.0
    assert mon.check(sched)                       # quiet until 7.0 (4x)
    assert mon.triggers == 3 and sched.committed == 3
    # a calm observation resets the backoff to the base cooldown
    calm = _fake_sched([0.0, 1.0])
    calm.now = 10.0
    mon2 = ReplanMonitor(ReplanPolicy(threshold=0.1, cooldown_s=1.0,
                                      backoff=4.0, max_cooldown_s=64.0))
    mon2._cool = 16.0                             # as if after 2 triggers
    assert not mon2.check(calm)
    assert mon2._cool == 1.0
    assert mon2.check(calm)                       # next storm: base cooldown


def test_monitor_budget_bounds_replans():
    mon = ReplanMonitor(ReplanPolicy(threshold=0.1, cooldown_s=0.0,
                                     budget=2))
    sched = _fake_sched([1.0] * 5)
    fired = sum(mon.check(sched) for _ in range(5))
    assert fired == 2 and mon.triggers == 2 and sched.committed == 2


def test_auto_replan_under_fault(scenario):
    """Integration: a capacity rescale mid-run arms the monitor; triggers
    stay within budget and are visible in the summary."""
    rate = scenario.nominal_rate(2.0)   # overload: backlog persists
    horizon = 10 / rate
    faults = [FaultEvent(0.4 * horizon, "rescale", node=0, factor=0.2)]
    tr = run_online(scenario, horizon=horizon, seed=3, rate=rate,
                    batch_size=2, drain="exact", finish=True,
                    fault_schedule=faults,
                    auto_replan=ReplanPolicy(threshold=0.1,
                                             cooldown_s=horizon / 20,
                                             budget=3))
    s = tr.summary()
    assert s.get("auto_replan_triggers", 0) >= 1
    assert s.get("auto_replan_triggers", 0) <= 3      # budget respected
    # every trigger resolved into a commit or an audited decline
    resolved = s.get("replans", 0) + sum(
        s.get("replans_skipped", {}).values())
    assert resolved >= s.get("auto_replan_triggers", 0)


def test_admission_counters_live_on_trace(scenario):
    sched = OnlineScheduler(scenario.topology, drain="exact",
                            admission="reject")
    rng = np.random.default_rng(21)
    jobs = [j.with_deadline(1e-3) for j in scenario.sample_jobs(rng, 2)]
    sched.submit_jobs(0.0, jobs, pad_to=scenario.max_layers)
    s = sched.trace.summary()
    assert s["admission"]["assessed"] == 2
    assert s["admission"]["rejected"] + s["admission"]["expired"] == 2
    assert s["shed"] == 2


def test_admit_all_matches_no_admission_trajectory(scenario):
    """admit_all gates nothing: identical trace to a run with admission
    disabled (one code path for the A/B baseline)."""
    rate = scenario.nominal_rate(1.0)
    kw = dict(horizon=6 / rate, seed=9, rate=rate, drain="exact",
              finish=True, deadline_s=2 * scenario.mean_service_s)
    a = run_online(scenario, admission=None, **kw)
    b = run_online(scenario, admission="admit_all", **kw)
    # job names carry a process-global counter; compare trajectories
    assert sorted(a.completions.values()) == sorted(b.completions.values())
    assert a.latencies.tolist() == b.latencies.tolist()
    assert not b.shed and b.admission["rejected"] == 0
