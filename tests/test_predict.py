"""What-if fork & predicted completions: the tentpole invariants of the
admission layer.  ``EventEngine.fork()`` + ``predict_completions`` must be
(a) exact — predictions made at any instant equal the completions the live
system later realizes, to rtol 1e-9, at fresh and queued states across the
scenario catalog; (b) free of side effects — serving a fork to quiescence
(and mutating it arbitrarily) never perturbs the live engine; and (c)
exact through fault outages — a prediction made after a fail/recover
sequence still matches the realized completions."""

import dataclasses

import numpy as np
import pytest

from repro.core import completions as C, jobs as J, schedule, solve
from repro.core.eventsim import EventEngine
from repro.scenarios import FAMILIES, make_scenario
from repro.serving.faults import FaultEvent
from repro.serving.online import OnlineScheduler
from util import random_instance

RTOL = 1e-9


def _drive(sched, sc, rng, windows, batch=2, dt=0.05):
    t = 0.0
    for _ in range(windows):
        sched.submit_jobs(t, sc.sample_jobs(rng, batch),
                          pad_to=sc.max_layers)
        t += dt
    return t


# -- (a) exactness across the catalog, fresh and queued ----------------------

@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_predictions_match_realized_completions(family):
    """At a fresh commit and again at a queued mid-run state, the forked
    prediction equals what finish() later realizes — rtol 1e-9."""
    sc = make_scenario(family, seed=0)
    rng = np.random.default_rng(7)
    sched = OnlineScheduler(sc.topology, drain="exact")
    _drive(sched, sc, rng, windows=1)
    fresh = C.predict_completions(sched._effective_topology(), sched.ledger)
    _drive(sched, sc, rng, windows=2)
    queued = C.predict_completions(sched._effective_topology(),
                                   sched.ledger)
    realized = sched.finish()
    # Jobs committed after the fresh prediction exist only in the queued
    # one; every predicted job must match its realized completion.
    assert set(queued) >= set(realized)
    for name, t_done in realized.items():
        np.testing.assert_allclose(queued[name], t_done, rtol=RTOL)
        if name in fresh:
            np.testing.assert_allclose(fresh[name], t_done, rtol=RTOL)


def test_prediction_with_extra_plan_matches_commit_then_finish():
    """Scoring an uncommitted candidate window through ``extra_plans``
    predicts exactly the completions realized when that window is then
    committed at the same instant."""
    sc = make_scenario("paper-small", seed=0)
    rng = np.random.default_rng(3)
    sched = OnlineScheduler(sc.topology, drain="exact")
    t = _drive(sched, sc, rng, windows=2)
    jobs = sc.sample_jobs(rng, 3)
    names = [j.name for j in jobs]
    batch, plan = sched.presolve(jobs, pad_to=sc.max_layers)
    if plan.paths is None:
        eff = sched._effective_topology()
        _, paths, _ = schedule.replay_solution(eff.view(sched.state), batch,
                                               plan.assign, plan.order)
        plan = dataclasses.replace(plan, paths=paths)
    preds = C.predict_completions(
        sched._effective_topology(), sched.ledger,
        extra_plans=[(batch, plan, names)], at=t)
    sched.advance_to(t)
    sched.commit_presolved(jobs, batch, plan)
    realized = sched.finish()
    for name in names:
        np.testing.assert_allclose(preds[name], realized[name], rtol=RTOL)


def test_indexed_and_ref_prediction_engines_agree():
    sc = make_scenario("star", seed=0)
    rng = np.random.default_rng(11)
    sched = OnlineScheduler(sc.topology, drain="exact")
    _drive(sched, sc, rng, windows=2)
    topo = sched._effective_topology()
    fast = C.predict_completions(topo, sched.ledger, engine="indexed")
    ref = C.predict_completions(topo, sched.ledger, engine="ref")
    assert set(fast) == set(ref)
    for name in fast:
        np.testing.assert_allclose(fast[name], ref[name], rtol=RTOL)


# -- (b) the fork is side-effect free -----------------------------------------

def test_fork_mutation_never_perturbs_live_engine():
    """Lockstep parity: two identical ledgers, one repeatedly forked and
    mutated between drains, must realize bit-identical completions."""
    rng = np.random.default_rng(5)
    net, jobs = random_instance(rng, num_jobs=4)
    batch = J.batch_jobs(jobs)
    plan = solve(net, batch, method="greedy").replay(net, batch)
    names = [j.name for j in jobs]

    def fresh():
        led = C.CommittedWork.empty(net.num_nodes).commit(batch, plan,
                                                          names=names)
        return C.warm_engine(net.topology, led)

    control, probed = fresh(), fresh()
    t = 0.0
    for _ in range(4):
        # Abuse the probed ledger's fork between drains: predict (which
        # serves a fork to quiescence) and separately mutate a raw fork.
        C.predict_completions(net.topology, probed)
        eng = C._engine_of(probed).eng
        fk = eng.fork()
        fk.advance(fk.now + 0.7)
        fk.add_tasks([C._task_of(j) for j in probed.jobs[:1]])
        t += 0.2
        control = C.drain_exact(net.topology, control, 0.2)
        probed = C.drain_exact(net.topology, probed, 0.2)
        assert control.completed == probed.completed  # bit-identical
    done_c, _ = C.run_to_completion(net.topology, control)
    done_p, _ = C.run_to_completion(net.topology, probed)
    assert done_c == done_p


def test_fork_is_independent_copy():
    """Mutating every forked structure leaves the original's behaviour
    untouched (heaps, events, tasks, rates, down-set are all copied)."""
    rng = np.random.default_rng(9)
    net, jobs = random_instance(rng, num_jobs=3)
    batch = J.batch_jobs(jobs)
    plan = solve(net, batch, method="greedy").replay(net, batch)
    led = C.CommittedWork.empty(net.num_nodes).commit(
        batch, plan, names=[j.name for j in jobs])
    led = C.warm_engine(net.topology, led)
    eng: EventEngine = C._engine_of(led).eng
    before = (eng.now, len(eng.completions), eng.events_processed,
              [(t.ptr, t.remaining, t.done) for t in eng.tasks])
    fk = eng.fork()
    fk.advance(np.inf)
    assert fk.live == 0 and len(fk.completions) == len(eng.tasks)
    after = (eng.now, len(eng.completions), eng.events_processed,
             [(t.ptr, t.remaining, t.done) for t in eng.tasks])
    assert before == after
    # the original still drains to the same completions the fork predicted
    eng.advance(np.inf)
    assert eng.completions == fk.completions


# -- (c) exact through a fault outage -----------------------------------------

def test_predictions_exact_through_outage_segment():
    """A prediction made after a node fail/recover cycle (requeue policy)
    matches the realized completions exactly."""
    from repro.serving.online import run_online
    sc = make_scenario("paper-small", seed=0)
    rate = sc.nominal_rate(0.8)
    horizon = 10 / rate
    faults = [FaultEvent(0.3 * horizon, "node_fail", node=1),
              FaultEvent(0.6 * horizon, "node_recover", node=1)]
    rng = np.random.default_rng(2)
    sched = OnlineScheduler(sc.topology, drain="exact")
    from repro.serving.faults import FaultInjector
    injector = FaultInjector(sched, policy="requeue", pad_to=sc.max_layers)
    times = np.linspace(0, horizon, 8)
    fi = 0
    for t in times:
        while fi < len(faults) and faults[fi].time <= float(t):
            injector.apply(faults[fi])
            fi += 1
        jobs = sc.sample_jobs(rng, 1)
        if sched.degraded:
            jobs = injector.filter_arrivals(float(t), jobs)
            if not jobs:
                continue
        sched.submit_jobs(float(t), jobs, pad_to=sc.max_layers)
    while fi < len(faults):
        injector.apply(faults[fi])
        fi += 1
    preds = C.predict_completions(sched._effective_topology(), sched.ledger,
                                  down=sched._down_keys())
    realized = sched.finish()
    for name, t_done in realized.items():
        np.testing.assert_allclose(preds[name], t_done, rtol=RTOL)
