"""Sharding-rule legality across all architectures, input-spec shapes, and
HLO collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import SHAPES, shape_applicable
from repro.distributed import sharding as sh
from repro.launch import hlo_analysis
from repro.models import model as M

def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: (sizes, names) vs ((name, size),...)."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH1 = _abstract_mesh((16, 16), ("data", "model"))
MESH2 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["single", "multi"])
def test_param_specs_legal_for_all_archs(arch, mesh):
    """Every sharded dim divides by its mesh-axis size (GSPMD legality)."""
    cfg = registry.config(arch)
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = sh.param_specs(shapes, mesh)

    def check(path, leaf, spec):
        for i, axis in enumerate(spec):
            if axis is None:
                continue
            parts = axis if isinstance(axis, tuple) else (axis,)
            size = int(np.prod([mesh.shape[a] for a in parts]))
            assert leaf.shape[i] % size == 0, (arch, path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs)


def test_some_params_are_sharded():
    """The rules must actually shard the big matrices (not everything P())."""
    cfg = registry.config("olmo_1b")
    shapes = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = sh.param_specs(shapes, MESH1)
    n_sharded = sum(1 for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
        if any(a is not None for a in s))
    assert n_sharded >= 5


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg = registry.config(arch)
    spec = SHAPES[shape]
    ok, reason = shape_applicable(cfg, spec)
    if not ok:
        assert "sub-quadratic" in reason or "full-attention" in reason
        return
    batch = registry.get(arch).input_specs(spec, cfg)
    if spec.kind == "decode":
        assert batch["tokens"].shape == (spec.global_batch, 1)
        assert batch["pos"].shape == ()
    else:
        assert batch["tokens"].shape == (spec.global_batch, spec.seq_len)
    for leaf in jax.tree.leaves(batch):
        assert isinstance(leaf, jax.ShapeDtypeStruct)  # no allocation


def test_long_500k_runs_only_for_subquadratic():
    runs = [a for a in registry.ARCH_IDS
            if shape_applicable(registry.config(a), SHAPES["long_500k"])[0]]
    assert sorted(runs) == ["xlstm_125m", "zamba2_2_7b"]


def test_hlo_collective_parsing():
    hlo = """
  %ag = f32[256,256]{1,0} all-gather(%x), replica_groups=[2,4]<=[4,2]T(1,0), dimensions={0}
  %fused = f32[256,256]{1,0} fusion(%ag), kind=kLoop
  %ar = bf16[128]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[32,16]{1,0} reduce-scatter(%z), replica_groups=[8,2]<=[16], dimensions={0}
  %cp = f32[64]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ars = (f32[8]{0}, f32[8]{0}) all-reduce-start(%a, %b), replica_groups=[1,8]<=[8]
"""
    stats = hlo_analysis.collective_stats(hlo)
    per = stats["per_op"]
    assert per["all-gather"]["count"] == 1
    assert per["all-gather"]["bytes"] == 256 * 256 * 4
    assert per["all-reduce"]["count"] == 2          # incl. -start form
    assert per["all-reduce"]["bytes"] == 128 * 2 + 2 * 8 * 4
    assert per["reduce-scatter"]["bytes"] == 32 * 16 * 4
    assert per["collective-permute"]["effective_bytes"] == 64 * 4
    # all-gather over groups of 4: factor 3/4
    np.testing.assert_allclose(per["all-gather"]["effective_bytes"],
                               256 * 256 * 4 * 0.75)
    assert stats["total_bytes"] > 0


def test_batch_specs_shard_batch_dim():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = sh.batch_specs(batch, MESH2)
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["pos"] == P()
    odd = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}
    assert sh.batch_specs(odd, MESH2)["tokens"] == P(None, None)
