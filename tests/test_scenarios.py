"""Scenario catalog: make_scenario(name, seed) is the single entry point —
coverage of all topology families, seed determinism, traffic mixes."""
import numpy as np
import pytest

from repro.core import jobs as J, solve
from repro.scenarios import (FAMILIES, MIXES, available_scenarios,
                             make_scenario, make_traffic)


def test_catalog_covers_required_families():
    names = available_scenarios()
    assert {"paper-small", "us-backbone", "edge-cloud", "random-geometric",
            "star"} <= set(names)
    assert len(names) >= 4


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_every_family_builds_and_routes(name):
    sc = make_scenario(name, seed=0)
    assert sc.num_nodes >= 2
    assert sc.ingress and sc.egress
    assert all(0 <= i < sc.num_nodes for i in sc.ingress + sc.egress)
    # compute reachable: at least one node has capacity
    assert float(np.asarray(sc.topology.mu_node).max()) > 0
    rng = np.random.default_rng(0)
    jobs = sc.sample_jobs(rng, 2)
    assert all(j.num_layers <= sc.max_layers for j in jobs)
    plan = solve(sc.topology, J.batch_jobs(jobs, pad_to=sc.max_layers),
                 method="greedy", state=sc.topology.empty_state())
    assert plan.makespan_bound < 1e29  # routable: src reaches dst
    assert sc.mean_service_s > 0 and np.isfinite(sc.mean_service_s)
    assert sc.nominal_rate(0.5) > 0


def test_scenarios_deterministic_in_seed():
    for name in ("random-geometric", "edge-cloud", "star"):
        a = make_scenario(name, seed=7)
        b = make_scenario(name, seed=7)
        np.testing.assert_array_equal(np.asarray(a.topology.mu_link),
                                      np.asarray(b.topology.mu_link))
        ja = a.sample_jobs(np.random.default_rng(1), 3)
        jb = b.sample_jobs(np.random.default_rng(1), 3)
        for x, y in zip(ja, jb):
            assert (x.src, x.dst) == (y.src, y.dst)
            np.testing.assert_array_equal(x.comp, y.comp)
    # seeded generators actually vary with the seed
    g7 = make_scenario("random-geometric", seed=7)
    g8 = make_scenario("random-geometric", seed=8)
    assert not np.array_equal(np.asarray(g7.topology.mu_link),
                              np.asarray(g8.topology.mu_link))


def test_traffic_selection_by_name_and_kwarg():
    assert make_scenario("us-backbone:lm").traffic.name == "lm"
    assert make_scenario("us-backbone", traffic="lm").traffic.name == "lm"
    assert make_scenario("us-backbone").traffic.name == "paper"
    with pytest.raises(ValueError, match="either in the name"):
        make_scenario("us-backbone:lm", traffic="paper")
    with pytest.raises(ValueError, match="unknown scenario family"):
        make_scenario("not-a-family")
    with pytest.raises(ValueError, match="unknown traffic mix"):
        make_traffic("not-a-mix")


def test_traffic_mixes_cost_profiles():
    assert set(MIXES) >= {"paper", "lm", "synthetic", "conv"}
    rng = np.random.default_rng(0)
    for mix_name in MIXES:
        mix = make_traffic(mix_name)
        job = mix.sample(rng, "j", 0, 1)
        assert job.num_layers <= mix.max_layers
        assert mix.mean_flops() > 0


def test_src_dst_distinct_when_possible():
    sc = make_scenario("star", seed=0)
    rng = np.random.default_rng(0)
    for _ in range(20):
        s, d = sc.sample_src_dst(rng)
        assert s != d
