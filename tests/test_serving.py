"""Serving: routed scheduler behaviour (straggler avoidance, queue-aware
spreading) and the decode engine end-to-end."""
import numpy as np

from repro.core import network as N
from repro.serving.scheduler import Request, RoutedScheduler


def _cluster():
    """4 TPU slices in a line + 2 edge ingress nodes."""
    G = 1e12
    GB = 1e9
    #   0 (edge) - 1 - 2 - 3 - 4 (slices) - 5 (edge)
    edges = [(0, 1, 10 * GB), (1, 2, 40 * GB), (2, 3, 40 * GB),
             (3, 4, 40 * GB), (4, 5, 10 * GB), (1, 3, 40 * GB),
             (2, 4, 40 * GB)]
    caps = [0, 50 * G, 50 * G, 50 * G, 50 * G, 0]
    return N.make_network(6, edges, caps)


def test_placements_valid_and_prioritized():
    sched = RoutedScheduler(_cluster())
    reqs = [Request("smollm_135m", src=0, dst=5, seq_len=1024, name=f"r{i}")
            for i in range(4)]
    plans = sched.schedule(reqs)
    assert [p.priority for p in plans] == [0, 1, 2, 3]
    for p in plans:
        assert all(n in (1, 2, 3, 4) for n in p.nodes_used)
        assert p.bound_s > 0


def test_queue_aware_spreading():
    """Many identical jobs: the waiting term must spread them over slices
    rather than piling all on one (the paper's Fig. 1 argument)."""
    sched = RoutedScheduler(_cluster())
    reqs = [Request("olmo_1b", src=0, dst=5, seq_len=2048, name=f"r{i}")
            for i in range(8)]
    plans = sched.schedule(reqs)
    used = {n for p in plans for n in p.nodes_used}
    assert len(used) >= 2, f"all jobs piled on {used}"


def test_straggler_avoidance():
    """A slice reported 10x slow receives no new placements."""
    sched = RoutedScheduler(_cluster())
    plans0 = sched.schedule([Request("olmo_1b", 0, 5, name="warm")])
    hot = plans0[0].nodes_used[0]
    sched.drain()
    sched.report_slowdown(hot, 10.0)
    plans = sched.schedule([Request("olmo_1b", 0, 5, name=f"r{i}")
                            for i in range(4)])
    for p in plans:
        assert hot not in p.nodes_used, (hot, p.nodes_used)


def test_engine_generates():
    import jax
    from repro.configs import registry
    from repro.models import model as M
    from repro.serving.engine import DecodeEngine

    cfg = registry.smoke_config("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, max_len=64)
    prompts = np.full((3, 4), 7, np.int32)
    res = eng.generate(prompts, gen_len=8)
    assert res.tokens.shape == (3, 8)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.padded_vocab).all()
    # determinism
    res2 = eng.generate(prompts, gen_len=8)
    np.testing.assert_array_equal(res.tokens, res2.tokens)
