"""Serving: routed scheduler behaviour (straggler avoidance, queue-aware
spreading) and the decode engine end-to-end."""
import numpy as np

from repro.core import network as N
from repro.serving.scheduler import Request, RoutedScheduler


def _cluster():
    """4 TPU slices in a line + 2 edge ingress nodes."""
    G = 1e12
    GB = 1e9
    #   0 (edge) - 1 - 2 - 3 - 4 (slices) - 5 (edge)
    edges = [(0, 1, 10 * GB), (1, 2, 40 * GB), (2, 3, 40 * GB),
             (3, 4, 40 * GB), (4, 5, 10 * GB), (1, 3, 40 * GB),
             (2, 4, 40 * GB)]
    caps = [0, 50 * G, 50 * G, 50 * G, 50 * G, 0]
    return N.make_network(6, edges, caps)


def test_placements_valid_and_prioritized():
    sched = RoutedScheduler(_cluster())
    reqs = [Request("smollm_135m", src=0, dst=5, seq_len=1024, name=f"r{i}")
            for i in range(4)]
    plans = sched.schedule(reqs)
    # regression: the list is built in priority order (no re-sort needed)
    assert [p.priority for p in plans] == [0, 1, 2, 3]
    for p in plans:
        assert all(n in (1, 2, 3, 4) for n in p.nodes_used)
        assert p.bound_s > 0


def test_placements_are_views_over_stored_plan():
    """Placements share the scheduler's stored Plan; bounds agree and the
    plan round-trips through JSON with the placements' data intact."""
    import json
    from repro.core.plan import Plan

    sched = RoutedScheduler(_cluster())
    plans = sched.schedule([Request("smollm_135m", 0, 5, name=f"r{i}")
                            for i in range(3)])
    stored = sched.last_plan
    assert stored is not None and stored.solver == "greedy"
    for p in plans:
        assert p.plan is stored
        assert p.bound_s == float(stored.bounds[p.job])
    rt = Plan.from_dict(json.loads(json.dumps(stored.to_dict())))
    np.testing.assert_array_equal(rt.assign, stored.assign)
    np.testing.assert_array_equal(rt.priority, stored.priority)


def test_scheduler_method_flag():
    """Solver choice is a string flag; lazy greedy places identically."""
    reqs = [Request("smollm_135m", 0, 5, name=f"r{i}") for i in range(3)]
    by_method = {}
    for method in ("greedy", "lazy"):
        sched = RoutedScheduler(_cluster(), method=method)
        sched.schedule(reqs)
        by_method[method] = sched.last_plan
    np.testing.assert_allclose(by_method["greedy"].bounds,
                               by_method["lazy"].bounds, rtol=1e-6)


def test_replan_last_routes_around_straggler():
    """report_slowdown + replan_last re-places the same batch."""
    sched = RoutedScheduler(_cluster())
    plans = sched.schedule([Request("olmo_1b", 0, 5, name=f"r{i}")
                            for i in range(2)])
    victim = plans[0].nodes_used[0]
    sched.report_slowdown(victim, 50.0)
    replans = sched.replan_last()
    assert replans is not None and len(replans) == 2
    for p in replans:
        assert victim not in p.nodes_used, (victim, p.nodes_used)


def test_queue_aware_spreading():
    """Many identical jobs: the waiting term must spread them over slices
    rather than piling all on one (the paper's Fig. 1 argument)."""
    sched = RoutedScheduler(_cluster())
    reqs = [Request("olmo_1b", src=0, dst=5, seq_len=2048, name=f"r{i}")
            for i in range(8)]
    plans = sched.schedule(reqs)
    used = {n for p in plans for n in p.nodes_used}
    assert len(used) >= 2, f"all jobs piled on {used}"


def test_straggler_avoidance():
    """A slice reported 10x slow receives no new placements."""
    sched = RoutedScheduler(_cluster())
    plans0 = sched.schedule([Request("olmo_1b", 0, 5, name="warm")])
    hot = plans0[0].nodes_used[0]
    sched.drain()
    sched.report_slowdown(hot, 10.0)
    plans = sched.schedule([Request("olmo_1b", 0, 5, name=f"r{i}")
                            for i in range(4)])
    for p in plans:
        assert hot not in p.nodes_used, (hot, p.nodes_used)


def test_engine_generates():
    import jax
    from repro.configs import registry
    from repro.models import model as M
    from repro.serving.engine import DecodeEngine

    cfg = registry.smoke_config("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, max_len=64)
    prompts = np.full((3, 4), 7, np.int32)
    res = eng.generate(prompts, gen_len=8)
    assert res.tokens.shape == (3, 8)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.padded_vocab).all()
    # determinism
    res2 = eng.generate(prompts, gen_len=8)
    np.testing.assert_array_equal(res.tokens, res2.tokens)
    # fused single-call prefill == per-token reference loop
    ref = eng.generate(prompts, gen_len=8, prefill_mode="per_token")
    np.testing.assert_array_equal(res.tokens, ref.tokens)
    import pytest
    with pytest.raises(ValueError, match="prefill_mode"):
        eng.generate(prompts, gen_len=8, prefill_mode="bogus")


def test_report_slowdown_validates_inputs():
    """factor must be finite and > 0 (factor=2 == half speed); node must be
    in range.  Invalid reports leave health untouched."""
    import pytest

    sched = RoutedScheduler(_cluster())
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="slowdown factor"):
            sched.report_slowdown(1, bad)
    with pytest.raises(ValueError, match="out of range"):
        sched.report_slowdown(99, 2.0)
    assert (sched._slowdown == 1.0).all()
    sched.report_slowdown(1, 2.0)
    assert sched._slowdown[1] == 2.0


def test_scheduler_exact_drain_end_to_end():
    """drain='exact' on the request path: placements come out the same shape,
    advance() drains the ledger, and full drain empties the queues."""
    sched = RoutedScheduler(_cluster(), drain="exact")
    plans = sched.schedule([Request("smollm_135m", 0, 5, name=f"r{i}")
                            for i in range(3)])
    assert [p.priority for p in plans] == [0, 1, 2]
    assert len(sched.ledger.jobs) == 3
    q0 = float(np.asarray(sched.state.q_node).sum())
    assert q0 > 0
    sched.advance(1e-3)
    assert float(np.asarray(sched.state.q_node).sum()) < q0
    sched.advance(1e9)  # plenty of time: everything completes
    assert not sched.ledger.jobs and len(sched.ledger.completed) == 3
    assert float(np.asarray(sched.state.q_node).max()) == 0.0
    assert float(np.asarray(sched.state.q_link).max()) == 0.0


def test_scheduler_advance_drains_queues():
    """Time passing drains the committed backlog at effective rates."""
    sched = RoutedScheduler(_cluster())
    sched.schedule([Request("olmo_1b", 0, 5, name="r0")])
    q0 = float(np.asarray(sched.state.q_node).sum())
    assert q0 > 0
    sched.advance(1e-3)
    q1 = float(np.asarray(sched.state.q_node).sum())
    assert q1 < q0
    sched.advance(1e9)  # plenty of time: everything drains
    assert float(np.asarray(sched.state.q_node).max()) == 0.0
    assert float(np.asarray(sched.state.q_link).max()) == 0.0
    assert sched.clock > 0
