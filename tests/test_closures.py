"""Closure-reuse pipeline: build counting, batched dispatch, dedupe,
extract_paths vectorization parity, lazy-greedy device-side bounds, and
bit-identity of the reuse-enabled solvers vs the seed solver."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import greedy, jobs as J, network as N, routing, solvers
from repro.core import shortest_path as SP
from repro.kernels import ops
from util import random_instance

# Pre-change reference captured from the seed solver on the quickstart
# instance (examples/quickstart.py: small_topology(1e-3), 2 VGG19 +
# 6 ResNet34, rng(0)).  The closure-reuse pipeline must reproduce these
# bit-for-bit.
QUICKSTART_BOUNDS = [
    0.9737289547920227, 2.1123697757720947, 0.7822328209877014,
    0.17777971923351288, 0.17777971923351288, 0.334226131439209,
    0.25363287329673767, 0.5179324150085449,
]
QUICKSTART_ORDER = [3, 4, 6, 5, 7, 2, 0, 1]


def _quickstart():
    from repro.configs import registry
    net, _ = N.small_topology(capacity_scale=1e-3)
    rng = np.random.default_rng(0)
    jobs = []
    for i, kind in enumerate(["vgg19"] * 2 + ["resnet34"] * 6):
        src, dst = rng.choice(5, size=2, replace=False)
        jobs.append(registry.get(kind).make_job(f"{kind}-{i}",
                                                int(src), int(dst)))
    return net, J.batch_jobs(jobs)


# ---------------------------------------------------------------------------
# Closure artifact + counting
# ---------------------------------------------------------------------------

def test_one_closure_build_per_greedy_round():
    """A reference greedy round = exactly one closure build (routing +
    commit share the round's stack; the seed rebuilt it J+2 times per
    round).  The fused solver does its closure work inside the device
    program, so the host-level counter stays at zero."""
    rng = np.random.default_rng(0)
    net, jobs = random_instance(rng, num_jobs=5)
    batch = J.batch_jobs(jobs)
    SP.reset_closure_build_count()
    greedy.greedy_route_ref(net, batch)
    assert SP.closure_build_count() == batch.num_jobs  # one per round
    SP.reset_closure_build_count()
    greedy.greedy_route(net, batch)
    assert SP.closure_build_count() == 0  # fused: all in-program


def test_lazy_one_closure_build_per_round():
    rng = np.random.default_rng(1)
    net, jobs = random_instance(rng, num_jobs=5)
    batch = J.batch_jobs(jobs)
    SP.reset_closure_build_count()
    greedy.greedy_route(net, batch, lazy=True)
    assert SP.closure_build_count() == batch.num_jobs


def test_solver_meta_reports_closure_builds():
    rng = np.random.default_rng(2)
    net, jobs = random_instance(rng, num_jobs=4)
    batch = J.batch_jobs(jobs)
    plan = solvers.solve(net, batch, method="greedy_ref")
    assert plan.meta["closure_builds"] == batch.num_jobs
    # fused greedy: zero host builds, one dispatch, honest meta
    fused = solvers.solve(net, batch, method="greedy")
    assert fused.meta["closure_builds"] == 0
    assert fused.meta["fused"] is True
    assert fused.meta["dispatches"] == 1
    assert fused.meta["rounds_per_dispatch"] == batch.num_jobs


def test_batch_closures_dedupe_identical_data():
    """Jobs sharing a data-size vector dedupe to one closure computation."""
    net = N.make_network(4, [(0, 1, 2.0), (1, 2, 3.0), (2, 3, 1.0)],
                         [1.0, 2.0, 0.0, 1.5])
    base = J.synthetic_job("a", 0, 3, num_layers=3, seed=0)
    twin = J.InferenceJob("b", 1, 2, base.comp.copy(), base.data.copy())
    other = J.synthetic_job("c", 0, 2, num_layers=3, seed=5)
    batch = J.batch_jobs([base, twin, other])
    cl = SP.build_closures_batch(net, batch)
    assert cl.t.shape == (3, batch.max_layers + 1, 4, 4)
    # w is dropped from batch stacks (cheap to recompute; avoids a J-fold
    # gather) and consumers rebuild it on demand
    assert cl.w is None and cl.job(0).w is None
    # identical data rows -> identical gathered closures
    np.testing.assert_array_equal(np.asarray(cl.t[0]), np.asarray(cl.t[1]))
    # and they match the per-job builder
    single = SP.closures_for(net, batch.data[0])
    np.testing.assert_array_equal(np.asarray(cl.t[0]), np.asarray(single.t))


def test_transfer_closure_stack_dispatches_to_batched_kernel():
    """[L+1, V, V] stacks with V >= the Pallas threshold take the batched
    kernel path (dispatch introspection — acceptance criterion)."""
    import jax
    lmax = 8
    v = 256
    assert ops.minplus_dispatch((lmax + 1, v, v)) == "pallas_batched"
    # trace a real transfer_closure at that size (eval_shape: no execution)
    # and assert its squaring loop recorded the batched-kernel choice
    net = N.make_network(v, [(i, (i + 1) % v, 1.0) for i in range(v)],
                         np.ones(v))
    data = jnp.ones((lmax + 1,), jnp.float32)
    ops.reset_dispatch_counts()
    out = jax.eval_shape(SP.transfer_closure, net, data)
    assert out.shape == (lmax + 1, v, v)
    assert ops.dispatch_counts().get("pallas_batched", 0) >= 1
    assert ops.dispatch_counts().get("oracle", 0) == 0
    # and the batched path is numerically right where it is cheap to run
    rng = np.random.default_rng(0)
    w = jnp.asarray(np.where(rng.random((3, 30, 30)) < 0.4,
                             rng.uniform(0.1, 5, (3, 30, 30)),
                             1e30), jnp.float32)
    from repro.kernels import ref
    np.testing.assert_allclose(
        np.asarray(ops.minplus_closure(w, use_pallas=True)),
        np.asarray(ref.minplus_closure_ref(w)), rtol=1e-6)


def test_routing_accepts_prebuilt_closures():
    """route/cost/commit with an explicit Closures == the internal build."""
    rng = np.random.default_rng(3)
    net, jobs = random_instance(rng, num_jobs=1, with_queues=True)
    job = jobs[0]
    comp, data = jnp.asarray(job.comp), jnp.asarray(job.data)
    args = (comp, data, job.src, job.dst, job.num_layers)
    cl = SP.build_closures(net, data)
    r0 = routing.route_single(net, *args)
    r1 = routing.route_single(net, *args, closures=cl)
    # tolerances: the standalone closure build compiles separately from the
    # fused in-jit one, so XLA fusion (FMA) may differ in the last ulp
    np.testing.assert_array_equal(np.asarray(r0.assign), np.asarray(r1.assign))
    np.testing.assert_allclose(float(r0.cost), float(r1.cost), rtol=1e-6)
    c0 = routing.cost_given_assignment(net, *args, r0.assign)
    c1 = routing.cost_given_assignment(net, *args, r0.assign, closures=cl)
    np.testing.assert_allclose(float(c0), float(c1), rtol=1e-6)
    n0 = routing.commit_assignment(net, *args, r0.assign)
    n1 = routing.commit_assignment(net, *args, r0.assign, closures=cl)
    np.testing.assert_allclose(np.asarray(n0.q_link), np.asarray(n1.q_link),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(n0.q_node), np.asarray(n1.q_node),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# extract_paths vectorization parity
# ---------------------------------------------------------------------------

def test_extract_paths_matches_host_reference():
    """Vectorized (vmapped reconstruct_path, one device_get) extract_paths
    == the seed's per-hop host loop."""
    checked = 0
    for seed in range(12):
        rng = np.random.default_rng(seed)
        net, jobs = random_instance(rng, num_jobs=1, with_queues=(seed % 2 == 0))
        job = jobs[0]
        args = (jnp.asarray(job.comp), jnp.asarray(job.data), job.src,
                job.dst, job.num_layers)
        r = routing.route_single(net, *args)
        if float(r.cost) >= 1e29:
            continue
        new = routing.extract_paths(net, *args, r.assign)
        old = routing.extract_paths_ref(net, *args, r.assign)
        assert new == old
        checked += 1
    assert checked >= 5


# ---------------------------------------------------------------------------
# Lazy greedy: device-side cached bounds
# ---------------------------------------------------------------------------

def test_lazy_matches_eager_order_and_routing_budget():
    """Lazy greedy orders jobs exactly like eager Algorithm 1 on seeded
    instances and performs at most J^2 routings."""
    for seed in range(3):
        rng = np.random.default_rng(seed + 40)
        net, jobs = random_instance(rng, num_jobs=6)
        batch = J.batch_jobs(jobs)
        eager = greedy.greedy_route(net, batch)
        lazy = greedy.greedy_route(net, batch, lazy=True)
        assert lazy.meta["n_routings"] <= batch.num_jobs ** 2
        np.testing.assert_array_equal(lazy.order, eager.order)
        np.testing.assert_allclose(lazy.bounds, eager.bounds, rtol=1e-6)


@pytest.mark.parametrize("lazy", [False, True])
def test_unroutable_job_never_double_commits(lazy):
    """A job whose cost clips to the INF sentinel must not tie with (and,
    at a lower index, beat) the routed-job mask in the argmin selection —
    that double-committed a routed job and broke the priority permutation."""
    # job0 feasible (lower index), job1's destination unreachable; data
    # sizes >= 1 so the stranded bound clips to exactly the INF sentinel
    # (data * INF-invrate >= INF), reproducing the tie
    net = N.make_network(4, [(0, 1, 2.0), (1, 2, 2.0)],
                         [0.0, 1.0, 1.0, 1.0])  # node 3: no links at all
    j0 = J.InferenceJob("ok", 0, 2, np.array([1.0], np.float32),
                        np.array([2.0, 2.0], np.float32))
    j1 = J.InferenceJob("stranded", 0, 3, np.array([1.0], np.float32),
                        np.array([2.0, 2.0], np.float32))
    batch = J.batch_jobs([j0, j1])
    plan = greedy.greedy_route(net, batch, lazy=lazy)  # must not raise
    assert sorted(plan.order.tolist()) == [0, 1]
    assert plan.order[0] == 0                 # feasible job routed first
    assert plan.bounds[1] >= 1e29             # stranded job keeps INF bound


# ---------------------------------------------------------------------------
# Bit-identity vs the seed solver (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lazy", [False, True])
def test_quickstart_bounds_bit_identical_to_seed(lazy):
    net, batch = _quickstart()
    plan = greedy.greedy_route(net, batch, lazy=lazy)
    assert plan.bounds.tolist() == QUICKSTART_BOUNDS
    assert plan.order.tolist() == QUICKSTART_ORDER
