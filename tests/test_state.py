"""Time-aware state split: Topology/QueueState semantics, fluid drain
properties, constructor validation, and static-path bit-identity."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import jobs as J, network as N, schedule, solve
from repro.core.state import QueueState, Topology, advance, backlog_seconds
from util import random_instance


# -- advance / drain properties ---------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_advance_never_negative_and_clock_moves(seed):
    rng = np.random.default_rng(seed)
    net, _ = random_instance(rng, with_queues=True)
    dt = float(rng.uniform(0, 5))
    st2 = advance(net.topology, net.state, dt)
    assert (np.asarray(st2.q_node) >= 0).all()
    assert (np.asarray(st2.q_link) >= 0).all()
    np.testing.assert_allclose(float(st2.clock),
                               float(net.state.clock) + dt, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_advance_composes(seed):
    """Fluid drain composes: advance(a).advance(b) == advance(a+b)."""
    rng = np.random.default_rng(seed)
    net, _ = random_instance(rng, with_queues=True)
    a, b = rng.uniform(0, 2, size=2)
    two = net.state.advance(net.topology, a).advance(net.topology, b)
    one = net.state.advance(net.topology, a + b)
    np.testing.assert_allclose(np.asarray(two.q_node),
                               np.asarray(one.q_node), atol=1e-4)
    np.testing.assert_allclose(np.asarray(two.q_link),
                               np.asarray(one.q_link), atol=1e-4)


def test_advance_exact_drain_rate():
    net = N.make_network(2, [(0, 1, 4.0)], [2.0, 0.0])
    state = net.state.with_queues(jnp.asarray([6.0, 0.0]),
                                  net.q_link.at[0, 1].set(8.0))
    st2 = advance(net.topology, state, 1.0)
    np.testing.assert_allclose(np.asarray(st2.q_node), [4.0, 0.0])
    assert np.asarray(st2.q_link)[0, 1] == 4.0  # drained at mu_link
    st3 = advance(net.topology, state, 100.0)   # fully drained, clipped at 0
    assert float(np.asarray(st3.q_node).max()) == 0.0
    assert float(np.asarray(st3.q_link).max()) == 0.0


def test_backlog_seconds_worst_resource():
    net = N.make_network(2, [(0, 1, 4.0)], [2.0, 0.0])
    state = net.state.with_queues(jnp.asarray([6.0, 0.0]),
                                  net.q_link.at[0, 1].set(8.0))
    # node wait 6/2 = 3s > link wait 8/4 = 2s
    np.testing.assert_allclose(backlog_seconds(net.topology, state), 3.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_bound_dominates_simulation_on_drained_state(seed):
    """bound >= simulated completion still holds after advance(dt)."""
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=3, with_queues=True)
    net = net.advance(float(rng.uniform(0, 3)))
    batch = J.batch_jobs(jobs)
    plan = solve(net, batch, method="greedy")
    if plan.makespan_bound >= 1e29:
        return
    sim = schedule.simulate(net, batch, plan.assign, plan.order)
    assert sim.makespan <= plan.makespan_bound * (1 + 1e-5)


# -- view composition --------------------------------------------------------

def test_network_is_composed_view():
    net, _ = N.small_topology()
    assert isinstance(net.topology, Topology)
    assert isinstance(net.state, QueueState)
    assert net.topology.view(net.state).mu_node is net.mu_node
    # with_queues preserves topology (identity) and clock
    q = jnp.ones_like(net.q_node)
    net2 = net.with_queues(q, net.q_link)
    assert net2.topology is net.topology
    assert float(net2.clock) == float(net.clock)


def test_solve_accepts_topology_and_state():
    rng = np.random.default_rng(3)
    net, jobs = random_instance(rng, num_jobs=2, with_queues=True)
    batch = J.batch_jobs(jobs)
    a = solve(net, batch, method="greedy")
    b = solve(net.topology, batch, method="greedy", state=net.state)
    np.testing.assert_array_equal(a.assign, b.assign)
    np.testing.assert_array_equal(a.bounds, b.bounds)
    with pytest.raises(ValueError):
        solve(net, batch, state=net.state)  # state only with Topology


def test_plan_net_roundtrips_clock():
    from repro.core.plan import Plan
    rng = np.random.default_rng(4)
    net, jobs = random_instance(rng, num_jobs=2)
    net = net.advance(1.5)
    plan = solve(net, batch := J.batch_jobs(jobs), method="greedy")
    rt = Plan.from_dict(plan.to_dict())
    np.testing.assert_allclose(float(rt.net.clock), float(plan.net.clock))
    np.testing.assert_array_equal(np.asarray(rt.net.q_node),
                                  np.asarray(plan.net.q_node))


# -- static-path bit-identity (acceptance criterion) -------------------------

# Deliberately duplicated from benchmarks/common.py: the test pins the seed
# solver's golden values independently, so a bad re-capture of the bench-side
# reference cannot silently re-baseline this regression gate too.
QUICKSTART_BOUNDS = [
    0.9737289547920227, 2.1123697757720947, 0.7822328209877014,
    0.17777971923351288, 0.17777971923351288, 0.334226131439209,
    0.25363287329673767, 0.5179324150085449,
]
QUICKSTART_ORDER = [3, 4, 6, 5, 7, 2, 0, 1]


def _quickstart_instance():
    from repro.configs import registry
    net, _ = N.small_topology(capacity_scale=1e-3)
    rng = np.random.default_rng(0)
    jobs = []
    for i, kind in enumerate(["vgg19"] * 2 + ["resnet34"] * 6):
        src, dst = rng.choice(5, size=2, replace=False)
        jobs.append(registry.get(kind).make_job(f"{kind}-{i}",
                                                int(src), int(dst)))
    return net, J.batch_jobs(jobs)


@pytest.mark.parametrize("method", ["greedy", "lazy"])
def test_static_solve_bit_identical_after_split(method):
    """The Topology/QueueState split must not move the static path by a ULP:
    bounds recorded from the pre-split solver reproduce exactly."""
    net, batch = _quickstart_instance()
    plan = solve(net, batch, method=method)
    assert plan.bounds.tolist() == QUICKSTART_BOUNDS
    assert plan.order.tolist() == QUICKSTART_ORDER


# -- constructor validation (satellite) --------------------------------------

def test_make_network_rejects_bad_inputs():
    with pytest.raises(ValueError, match="node_caps"):
        N.make_network(2, [(0, 1, 1.0)], [1.0, -2.0])
    with pytest.raises(ValueError, match="node_caps"):
        N.make_network(2, [(0, 1, 1.0)], [1.0, float("nan")])
    with pytest.raises(ValueError, match="node_caps must have shape"):
        N.make_network(2, [(0, 1, 1.0)], [1.0, 1.0, 1.0])
    with pytest.raises(ValueError, match=r"edges\[0\]"):
        N.make_network(2, [(0, 1, -5.0)], [1.0, 1.0])
    with pytest.raises(ValueError, match=r"edges\[1\]"):
        N.make_network(2, [(0, 1, 1.0), (0, 2, 1.0)], [1.0, 1.0])
    with pytest.raises(ValueError, match="self-loop"):
        N.make_network(2, [(1, 1, 1.0)], [1.0, 1.0])
    with pytest.raises(ValueError, match="num_nodes"):
        N.make_network(0, [], [])


def test_jobs_reject_bad_inputs():
    good_comp = np.ones(3, np.float32)
    good_data = np.ones(4, np.float32)
    with pytest.raises(ValueError, match="comp"):
        J.InferenceJob("j", 0, 1, -good_comp, good_data)
    with pytest.raises(ValueError, match="comp"):
        J.InferenceJob("j", 0, 1, good_comp * np.nan, good_data)
    with pytest.raises(ValueError, match="data"):
        J.InferenceJob("j", 0, 1, good_comp, np.ones(3, np.float32))
    with pytest.raises(ValueError, match="data"):
        J.InferenceJob("j", 0, 1, good_comp, -good_data)
    with pytest.raises(ValueError, match="src/dst"):
        J.InferenceJob("j", -1, 1, good_comp, good_data)


def test_batch_jobs_pad_to():
    jobs = [J.InferenceJob("a", 0, 1, np.ones(2, np.float32),
                           np.ones(3, np.float32))]
    b = J.batch_jobs(jobs, pad_to=5)
    assert b.max_layers == 5
    assert int(b.num_layers[0]) == 2
    with pytest.raises(ValueError, match="pad_to"):
        J.batch_jobs(jobs, pad_to=1)
