"""Streaming serving pipeline: window semantics (δ/B), serial equivalence,
decoupled solver stage, and backpressure (defer/shed) accounting."""
import dataclasses
import json

import numpy as np
import pytest

from repro.scenarios import make_scenario
from repro.serving.online import run_online
from repro.serving.stream import (StreamConfig, StreamingPipeline,
                                  StreamTrace, run_stream)


@pytest.fixture(scope="module")
def star():
    return make_scenario("star", seed=0)


def _jobs(sc, n, seed=0):
    return sc.sample_jobs(np.random.default_rng(seed), n)


def _pipe(sc, **cfg):
    return StreamingPipeline(sc.topology, StreamConfig(**cfg))


# -- config validation -------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="window_s"):
        StreamConfig(window_s=-1.0)
    with pytest.raises(ValueError, match="max_batch"):
        StreamConfig(max_batch=0)
    with pytest.raises(ValueError, match="policy"):
        StreamConfig(policy="drop")
    with pytest.raises(ValueError, match="max_pending"):
        StreamConfig(max_pending=0)
    with pytest.raises(ValueError, match="solver_latency"):
        StreamConfig(solver_latency="estimated")
    with pytest.raises(ValueError, match="solver_latency"):
        StreamConfig(solver_latency=-0.1)


# -- the correctness gate: δ=0, B=1, zero latency == serial loop -------------

def test_serial_equivalence_bit_identical():
    """With no window, no batching and no modeled solver latency the
    pipeline must reproduce the serial OnlineScheduler trace bit-identically
    (everything except the measured solver wall, which is wall-clock)."""
    # rate from a throwaway instance: nominal_rate's calibration samples 32
    # jobs and advances the scenario's name sequence, so both runs must
    # start from untouched scenarios.
    rate = make_scenario("star", seed=0).nominal_rate(0.5)
    kw = dict(horizon=20 / rate, seed=1, rate=rate)
    serial = run_online(make_scenario("star", seed=0), **kw)
    pipe = run_stream(make_scenario("star", seed=0), window_s=0.0,
                      max_batch=1, solver_latency=0.0, **kw)
    assert len(serial.records) == len(pipe.records) >= 10
    for a, b in zip(serial.records, pipe.records):
        assert dataclasses.replace(a, solve_s=0.0) == \
            dataclasses.replace(b, solve_s=0.0)
    assert serial.events == pipe.events
    # decomposition agrees: zero wait, every window is one request
    assert all(r.wait_s == 0.0 for r in pipe.requests)
    assert all(w.size == 1 for w in pipe.windows)
    assert [r.commit_s for r in pipe.requests] == \
        [r.arrival_s for r in pipe.requests]


def test_serial_equivalence_exact_drain():
    """The gate holds under the exact (ledger) drain too — the pipeline
    changes when plans land, never how the drain accounts for them."""
    rate = make_scenario("paper-small", seed=0).nominal_rate(0.8)
    kw = dict(horizon=8 / rate, seed=2, rate=rate, drain="exact",
              finish=True)
    serial = run_online(make_scenario("paper-small", seed=0), **kw)
    pipe = run_stream(make_scenario("paper-small", seed=0), window_s=0.0,
                      max_batch=1, solver_latency=0.0, **kw)
    for a, b in zip(serial.records, pipe.records):
        assert dataclasses.replace(a, solve_s=0.0) == \
            dataclasses.replace(b, solve_s=0.0)
    assert serial.completions == pipe.completions


# -- window semantics --------------------------------------------------------

def test_window_closes_at_batch_cap(star):
    """B arrivals inside δ close the window early — at the B-th arrival."""
    jobs = _jobs(star, 6)
    stream = [(0.1 * i, [j]) for i, j in enumerate(jobs)]
    tr = _pipe(star, window_s=100.0, max_batch=3).run(
        iter(stream), horizon=1000.0, pad_to=star.max_layers)
    assert [w.size for w in tr.windows] == [3, 3]
    # closed by cap, not by the δ timer: at the 3rd/6th arrival instants
    assert [w.close_s for w in tr.windows] == [0.2, 0.5]
    assert [w.commit_s for w in tr.windows] == [0.2, 0.5]
    assert len(tr.records) == 2  # one ArrivalRecord per window commit


def test_window_flushes_at_delta(star):
    """Fewer than B arrivals: the window flushes δ after it opened."""
    jobs = _jobs(star, 2)
    stream = [(0.0, [jobs[0]]), (0.3, [jobs[1]])]
    tr = _pipe(star, window_s=1.0, max_batch=100).run(
        iter(stream), horizon=1000.0, pad_to=star.max_layers)
    assert [w.size for w in tr.windows] == [2]
    assert tr.windows[0].open_s == 0.0 and tr.windows[0].close_s == 1.0
    # both requests waited for the flush: wait = commit - arrival
    assert [r.wait_s for r in tr.requests] == [1.0, 0.7]


def test_partial_window_flushed_at_horizon_end(star):
    """A window still open when the stream ends flushes at the horizon,
    not after the full δ."""
    jobs = _jobs(star, 2)
    stream = [(0.2, [jobs[0]]), (0.4, [jobs[1]])]
    tr = _pipe(star, window_s=50.0, max_batch=100).run(
        iter(stream), horizon=1.0, pad_to=star.max_layers)
    assert [w.size for w in tr.windows] == [2]
    assert tr.windows[0].close_s == 1.0
    assert all(r.commit_s == 1.0 for r in tr.requests)


def test_empty_windows_skipped(star):
    """Stale flush timers and empty arrival epochs never produce an empty
    solve: every recorded window carries at least one request."""
    jobs = _jobs(star, 2)
    # epoch 1 fills the window to its cap (closing it, leaving the δ=5
    # flush timer stale); epoch 2 is an empty epoch at t=1
    stream = [(0.0, jobs), (1.0, [])]
    tr = _pipe(star, window_s=5.0, max_batch=2).run(
        iter(stream), horizon=100.0, pad_to=star.max_layers)
    assert [w.size for w in tr.windows] == [2]
    assert len(tr.records) == 1


def test_sequential_mode_commits_serial_plans(star):
    """solve_mode='sequential' places a window with width-1 solves in
    window order — bit-identical plans (bounds, latencies, solve total) to
    the serial loop submitting the same jobs one call at a time at the
    same instant."""
    from repro.serving.online import OnlineScheduler

    jobs = _jobs(star, 5)
    seq = OnlineScheduler(star.topology)
    seq.trace = StreamTrace()
    got = seq.submit_window(2.0, jobs, pad_to=star.max_layers,
                            solve_mode="sequential")
    serial = OnlineScheduler(star.topology)
    want = [p for j in jobs
            for p in serial.submit_jobs(2.0, [j], pad_to=star.max_layers)]
    assert [p.job_name for p in got] == [p.job_name for p in want]
    assert [p.bound_s for p in got] == [p.bound_s for p in want]
    assert [p.assign.tolist() for p in got] == \
        [p.assign.tolist() for p in want]
    # one window record carrying the whole window, solve wall = the sum
    assert len(seq.trace.records) == 1
    rec = seq.trace.records[0]
    assert rec.latencies == tuple(
        x for r in serial.trace.records for x in r.latencies)
    # solve wall is the window total (walls themselves aren't comparable
    # across schedulers — the first run pays jit compilation)
    assert rec.solve_s > 0 and seq.last_solve_s == rec.solve_s
    with pytest.raises(ValueError, match="solve_mode"):
        seq.submit_window(3.0, jobs[:1], solve_mode="fused")
    with pytest.raises(ValueError, match="solve_mode"):
        StreamConfig(solve_mode="fused")


def test_sequential_pipeline_matches_serial_at_b1(star):
    """At B=1 the two solve modes are the same code path — the serial
    equivalence gate holds for either."""
    rate = make_scenario("star", seed=0).nominal_rate(0.5)
    kw = dict(horizon=8 / rate, seed=6, rate=rate, window_s=0.0,
              max_batch=1, solver_latency=0.0)
    a = run_stream(make_scenario("star", seed=0), solve_mode="batched", **kw)
    b = run_stream(make_scenario("star", seed=0),
                   solve_mode="sequential", **kw)
    assert len(a.records) == len(b.records) >= 4
    for ra, rb in zip(a.records, b.records):
        assert dataclasses.replace(ra, solve_s=0.0) == \
            dataclasses.replace(rb, solve_s=0.0)


def test_solver_latency_delays_commits(star):
    """Modeled solver wall-time lands on the simulated clock: commits are
    pushed out by the latency and a busy solver queues the next window."""
    jobs = _jobs(star, 2)
    stream = [(0.0, [jobs[0]]), (0.1, [jobs[1]])]
    tr = _pipe(star, window_s=0.0, max_batch=1, solver_latency=0.5).run(
        iter(stream), horizon=10.0, pad_to=star.max_layers)
    # window 1: solve starts at 0.0, commits at 0.5; window 2 closed at
    # 0.1 but the solver is busy until 0.5 -> commits at 1.0
    assert [w.commit_s for w in tr.windows] == [0.5, 1.0]
    assert [r.wait_s for r in tr.requests] == [0.5, 0.9]
    assert [r.queue_s for r in tr.requests] == pytest.approx([0.0, 0.4])
    # the scheduler's authoritative clock followed the commits
    assert [r.time for r in tr.records] == [0.5, 1.0]


def test_latency_is_wait_plus_service(star):
    """The recorded per-request latency (OnlineTrace.latencies) equals the
    decomposition's wait + service, request by request."""
    rate = star.nominal_rate(0.5)
    tr = run_stream(star, horizon=12 / rate, seed=3, rate=rate,
                    window_s=1.0 / rate, max_batch=4, solver_latency=0.01)
    assert tr.requests
    by_window: dict[int, list] = {}
    for r in tr.requests:
        by_window.setdefault(r.window, []).append(r)
    lat_from_records = np.sort(tr.latencies)
    lat_from_requests = np.sort([r.latency_s for r in tr.requests])
    np.testing.assert_allclose(lat_from_records, lat_from_requests,
                               rtol=1e-12)


# -- backpressure ------------------------------------------------------------

def test_defer_never_reorders_arrivals(star):
    """Deferred arrivals re-enter FIFO at commit instants, ahead of later
    traffic: the committed order is exactly the arrival order."""
    jobs = _jobs(star, 10)
    stream = [(0.1 * i, [j]) for i, j in enumerate(jobs)]
    tr = _pipe(star, window_s=0.0, max_batch=1, solver_latency=0.5,
               max_pending=2, policy="defer").run(
        iter(stream), horizon=1.0, pad_to=star.max_layers)
    assert [r.name for r in tr.requests] == [j.name for j in jobs]
    assert tr.deferred == 8 and not tr.shed
    # deferral is visible in the decomposition: admit > arrival, and the
    # whole deferral wait is charged to the request's latency
    deferred = [r for r in tr.requests if r.admit_s > r.arrival_s]
    assert len(deferred) == 8
    assert all(r.wait_s >= r.admit_s - r.arrival_s for r in deferred)
    # pending buffer never exceeded its bound: commits are serialized, so
    # each commit's window plus spill re-admissions stay within cap
    assert all(w.size <= 2 for w in tr.windows)


def test_shed_policy_accounting(star):
    """policy='shed' drops arrivals beyond the buffer and accounts them:
    shed requests never commit, committed + shed == offered."""
    jobs = _jobs(star, 10)
    stream = [(0.1 * i, [j]) for i, j in enumerate(jobs)]
    tr = _pipe(star, window_s=0.0, max_batch=1, solver_latency=0.5,
               max_pending=2, policy="shed").run(
        iter(stream), horizon=1.0, pad_to=star.max_layers)
    committed = {r.name for r in tr.requests}
    shed = {s["name"] for s in tr.shed}
    assert committed | shed == {j.name for j in jobs}
    assert committed.isdisjoint(shed)
    assert len(shed) == 7 and tr.deferred == 0
    s = tr.summary()
    assert s["shed"] == 7 and s["requests"] == 3


def test_backlog_bounded_under_subcapacity_window(star):
    """Sub-capacity bursty load through a batching window: the drained
    backlog stays bounded (the serial stability property survives
    batching)."""
    rate = star.nominal_rate(0.5)
    tr = run_stream(star, horizon=60 / rate, seed=4, process="bursty",
                    rate=rate, window_s=0.2 / rate, max_batch=4)
    assert len(tr.records) >= 10
    assert tr.backlog_growth() <= 1.3, tr.summary()


# -- trace -------------------------------------------------------------------

def test_stream_trace_serialization_roundtrips(star):
    rate = star.nominal_rate(0.4)
    tr = run_stream(star, horizon=10 / rate, seed=5, rate=rate,
                    window_s=0.5 / rate, max_batch=3, solver_latency=0.01,
                    drain="exact", finish=True)
    blob = json.loads(json.dumps(tr.to_dict()))
    assert blob["windows"] == len(tr.windows)
    assert len(blob["requests"]) == len(tr.requests)
    assert blob["requests"][0]["latency_s"] == pytest.approx(
        tr.requests[0].latency_s)
    # the satellite fix: serialized traces keep the exact-drain results
    assert blob["completions"] == tr.completions
    assert "p99_actual_s" in blob and "p99_wait_s" in blob
    assert blob["sustained_arr_s"] == pytest.approx(tr.sustained_arr_s())


def test_pipeline_rejects_backwards_stream(star):
    jobs = _jobs(star, 2)
    with pytest.raises(ValueError, match="backwards"):
        _pipe(star, window_s=0.0, max_batch=1).run(
            iter([(1.0, [jobs[0]]), (0.5, [jobs[1]])]),
            pad_to=star.max_layers)


def test_measured_latency_uses_observed_walls(star):
    """solver_latency='measured' charges an EMA of real solve walls to the
    clock: after the first (free) window, commits trail closes."""
    jobs = _jobs(star, 4)
    stream = [(float(i), [j]) for i, j in enumerate(jobs)]
    tr = _pipe(star, window_s=0.0, max_batch=1,
               solver_latency="measured").run(
        iter(stream), horizon=10.0, pad_to=star.max_layers)
    assert tr.windows[0].solve_model_s == 0.0  # no observation yet
    walls = [w.solve_wall_s for w in tr.windows]
    assert all(w > 0 for w in walls)
    assert all(w.solve_model_s > 0 for w in tr.windows[1:])
