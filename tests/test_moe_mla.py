"""MoE dispatch and MLA attention against dense per-token oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import model as M
from repro.models.moe import moe_block, init_moe, _ranks_in_expert


def test_ranks_in_expert():
    e = jnp.asarray([0, 0, 1, 1, 1, 3, 3, 5])
    r = np.asarray(_ranks_in_expert(e))
    np.testing.assert_array_equal(r, [0, 1, 0, 1, 2, 0, 1, 0])


def test_moe_matches_dense_oracle():
    """With ample capacity, the sort/scatter dispatch equals computing every
    token's top-k experts densely."""
    cfg = dataclasses.replace(
        registry.smoke_config("olmoe_1b_7b"),
        moe_capacity_factor=8.0, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, cfg.d_model),
                          jnp.float32)
    got = moe_block(p, x, cfg)

    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(cfg.moe_top_k):
            e = int(top_e[t, j])
            gate = jax.nn.silu(xf[t] @ p["w_gate"][e])
            up = xf[t] @ p["w_up"][e]
            want[t] += float(top_w[t, j]) * np.asarray(
                (gate * up) @ p["w_down"][e])
    np.testing.assert_allclose(np.asarray(got).reshape(-1, cfg.d_model),
                               want, atol=2e-4, rtol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0, outputs stay finite and close to the
    uncapped result for most tokens (drops only zero out contributions)."""
    cfg = dataclasses.replace(registry.smoke_config("olmoe_1b_7b"),
                              moe_capacity_factor=1.0, dtype=jnp.float32)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    out = moe_block(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


def test_mla_latent_cache_shape():
    """MLA decode caches latents, not per-head K/V — the memory win."""
    cfg = registry.smoke_config("deepseek_v2_236b")
    cache = M.init_cache(cfg, batch=2, max_len=32)
    assert set(cache.keys()) == {"c_kv", "k_rope"}
    assert cache["c_kv"].shape == (cfg.num_layers, 2, 32, cfg.kv_lora_rank)
    # vs. a per-head cache which would be heads x (nope+rope) wide
    latent_w = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    per_head_w = cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
    assert latent_w < per_head_w


def test_mla_full_config_cache_ratio():
    cfg = registry.config("deepseek_v2_236b")
    latent = cfg.kv_lora_rank + cfg.qk_rope_head_dim          # 576
    mha = cfg.num_heads * 2 * cfg.v_head_dim                  # 32768
    assert mha / latent > 50  # the paper-relevant d_jl compression


def test_moe_local_dispatch_trivial_mesh():
    """shard_map'ed per-shard dispatch == global dispatch on a 1-dev mesh."""
    import jax
    import pytest
    if not hasattr(jax, "set_mesh"):
        pytest.skip("ambient-mesh API (jax.set_mesh) not in this jax version")
    cfg = dataclasses.replace(registry.smoke_config("olmoe_1b_7b"),
                              dtype=jnp.float32, moe_capacity_factor=8.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab_size}
    a = M.prefill_logits(cfg, params, batch)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    with jax.set_mesh(mesh):
        b = M.prefill_logits(
            dataclasses.replace(cfg, moe_local_dispatch=True), params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)
