"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, shape + finiteness assertions) and decode-vs-prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.steps import make_train_step, default_optimizer
from repro.models import model as M

B, S = 2, 16


def _batch(cfg):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_frames, cfg.d_model)), cfg.dtype)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = M.prefill_logits(cfg, params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt = default_optimizer(cfg)
    step = make_train_step(cfg, opt)
    loss, params2, _ = step(params, opt.init(params), batch)
    assert np.isfinite(float(loss))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_decode_matches_prefill(arch):
    """Running serve_step token-by-token reproduces the prefill logits at the
    final position (KV-cache / recurrent-state correctness)."""
    cfg = registry.smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg)
    want = M.prefill_logits(cfg, params, batch)[:, -1]

    cache = M.init_cache(cfg, B, S + 4)
    extra = {}
    if cfg.family == "encdec":
        from repro.models import encdec
        extra["enc_out"] = encdec.encode(cfg, params, batch["frames"],
                                         remat=False)
    logits = None
    toks = batch["tokens"]
    if cfg.family == "vlm":
        # decode path has no patch prefix; compare against text-only prefill
        want = M.prefill_logits(cfg, params, {"tokens": toks})[:, -1]
    for i in range(S):
        sb = {"tokens": toks[:, i: i + 1], "pos": jnp.int32(i), **extra}
        logits, cache = M.serve_step(cfg, params, cache, sb)
    got = np.asarray(logits, np.float32)
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               atol=0.11, rtol=0.05)


def test_scan_vs_unrolled_identical():
    """scan_layers=False (dry-run mode) computes the same function."""
    for arch in ["smollm_135m", "olmoe_1b_7b", "zamba2_2_7b", "whisper_base",
                 "xlstm_125m"]:
        # f32 so the comparison is exact-ish (bf16 reorders summation)
        cfg = dataclasses.replace(registry.smoke_config(arch),
                                  dtype=jnp.float32)
        params = M.init_params(cfg, jax.random.PRNGKey(2))
        batch = _batch(cfg)
        a = M.prefill_logits(cfg, params, batch)
        cfg2 = dataclasses.replace(cfg, scan_layers=False)
        b = M.prefill_logits(cfg2, params, batch)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-4)


def test_full_configs_match_assignment():
    """Exact architecture hyper-parameters from the assignment table."""
    expect = {
        "olmo_1b": dict(num_layers=16, d_model=2048, num_heads=16,
                        num_kv_heads=16, d_ff=8192, vocab_size=50304),
        "smollm_135m": dict(num_layers=30, d_model=576, num_heads=9,
                            num_kv_heads=3, d_ff=1536, vocab_size=49152),
        "minicpm_2b": dict(num_layers=40, d_model=2304, num_heads=36,
                           num_kv_heads=36, d_ff=5760, vocab_size=122753),
        "gemma3_1b": dict(num_layers=26, d_model=1152, num_heads=4,
                          num_kv_heads=1, d_ff=6912, vocab_size=262144),
        "xlstm_125m": dict(num_layers=12, d_model=768, num_heads=4,
                           vocab_size=50304),
        "olmoe_1b_7b": dict(num_layers=16, d_model=2048, num_heads=16,
                            moe_num_experts=64, moe_top_k=8,
                            vocab_size=50304),
        "deepseek_v2_236b": dict(num_layers=60, d_model=5120, num_heads=128,
                                 kv_lora_rank=512, moe_num_experts=160,
                                 moe_top_k=6, moe_num_shared=2,
                                 vocab_size=102400),
        "whisper_base": dict(num_layers=6, d_model=512, num_heads=8,
                             d_ff=2048, vocab_size=51865, dec_layers=6),
        "zamba2_2_7b": dict(num_layers=54, d_model=2560, num_heads=32,
                            d_ff=10240, vocab_size=32000, ssm_state=64),
        "phi3_vision_4_2b": dict(num_layers=32, d_model=3072, num_heads=32,
                                 d_ff=8192, vocab_size=32064),
    }
    for arch, fields in expect.items():
        cfg = registry.config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_plausible():
    approx = {"olmo_1b": 1.18e9, "smollm_135m": 1.35e8, "minicpm_2b": 2.7e9,
              "gemma3_1b": 1.0e9, "xlstm_125m": 1.2e8, "olmoe_1b_7b": 6.8e9,
              "deepseek_v2_236b": 2.39e11, "whisper_base": 7.1e7,
              "zamba2_2_7b": 2.3e9, "phi3_vision_4_2b": 3.8e9}
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    for arch, want in approx.items():
        cfg = registry.config(arch)
        shapes = jax.eval_shape(lambda k: M.init_params(cfg, k), key)
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
        assert 0.85 * want < n < 1.2 * want, (arch, n, want)


def test_chunked_attention_matches_baseline():
    """attn_chunk_q (flash-style blocking) computes the same function."""
    for arch in ["smollm_135m", "gemma3_1b", "deepseek_v2_236b"]:
        cfg = dataclasses.replace(registry.smoke_config(arch),
                                  dtype=jnp.float32)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.arange(2 * S).reshape(2, S) % cfg.vocab_size}
        a = M.prefill_logits(cfg, params, batch)
        b = M.prefill_logits(dataclasses.replace(cfg, attn_chunk_q=4),
                             params, batch)
        c = M.prefill_logits(
            dataclasses.replace(cfg, attn_chunk_q=4, scan_layers=False),
            params, batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


def test_remat_policy_dots():
    cfg = dataclasses.replace(registry.smoke_config("smollm_135m"),
                              remat_policy="dots")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    loss = M.loss_fn(cfg, params, b)
    assert np.isfinite(float(loss))


def test_grouped_gqa_matches_repeat():
    for arch in ["gemma3_1b", "smollm_135m"]:
        cfg = dataclasses.replace(registry.smoke_config(arch),
                                  dtype=jnp.float32)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.arange(2 * S).reshape(2, S) % cfg.vocab_size}
        a = M.prefill_logits(cfg, params, batch)
        b = M.prefill_logits(dataclasses.replace(cfg, gqa_grouped=True),
                             params, batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


def test_flash_attn_impl_matches_xla():
    for arch in ["smollm_135m", "deepseek_v2_236b"]:
        cfg = dataclasses.replace(registry.smoke_config(arch),
                                  dtype=jnp.float32)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        S_ = 256
        batch = {"tokens": jnp.arange(2 * S_).reshape(2, S_) % cfg.vocab_size,
                 "labels": jnp.arange(2 * S_).reshape(2, S_) % cfg.vocab_size}
        a = M.prefill_logits(cfg, params, batch)
        b = M.prefill_logits(dataclasses.replace(cfg, attn_impl="flash"),
                             params, batch)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=3e-4)
