"""Online serving loop: arrival processes, queue draining keeps backlog
bounded under sub-capacity load (while the legacy no-drain loop diverges),
and straggler/replan events on the clock."""
import dataclasses

import numpy as np
import pytest

from repro.core import arrivals as A
from repro.scenarios import make_scenario
from repro.serving.online import OnlineScheduler, run_online


# -- arrival processes -------------------------------------------------------

def test_poisson_times_rate_and_sorted():
    rng = np.random.default_rng(0)
    t = A.poisson_times(rng, rate=5.0, horizon=200.0)
    assert (np.diff(t) >= 0).all() and (t >= 0).all() and (t < 200.0).all()
    assert 700 <= t.size <= 1300  # ~1000 expected, generous tolerance


def test_bursty_times_long_run_rate():
    rng = np.random.default_rng(1)
    t = A.bursty_times(rng, rate=8.0, horizon=100.0, burst_size=4)
    assert (np.diff(t) >= 0).all()
    assert 550 <= t.size <= 1050  # ~800 expected
    # bursts: many tiny gaps
    assert (np.diff(t) < 1e-3).sum() > t.size / 3


def test_diurnal_times_peak_heavier_than_base():
    rng = np.random.default_rng(2)
    t = A.diurnal_times(rng, base_rate=0.5, peak_rate=8.0, horizon=100.0,
                        period=100.0)
    mid = ((t > 35) & (t < 65)).sum()     # around the peak
    edges = ((t < 15) | (t > 85)).sum()   # around the base
    assert mid > 2 * max(edges, 1)


def test_make_process_registry():
    assert set(A.available()) >= {"poisson", "bursty", "diurnal"}
    fn = A.make_process("poisson", rate=2.0)
    assert fn(np.random.default_rng(0), 10.0).size > 0
    with pytest.raises(ValueError, match="unknown arrival process"):
        A.make_process("nope")


# -- the headline regression: drain bounded, no-drain diverges ---------------

def test_online_backlog_bounded_iff_draining():
    """Sub-capacity Poisson load: the draining scheduler's backlog stays
    bounded (flat second half) while the no-drain commit loop grows without
    bound — the reason the time-aware state split exists."""
    sc = make_scenario("star", seed=0)
    rate = sc.nominal_rate(0.5)
    horizon = 80 / rate
    drain = run_online(sc, horizon=horizon, seed=1, rate=rate,
                       drain_queues=True)
    nodrain = run_online(sc, horizon=horizon, seed=1, rate=rate,
                         drain_queues=False)
    assert len(drain.records) == len(nodrain.records) >= 40
    # bounded: the second half's peak backlog does not keep climbing
    assert drain.backlog_growth() <= 1.3, drain.summary()
    # divergent: backlog is (weakly) monotone and roughly doubles
    nb = nodrain.backlogs
    assert (np.diff(nb) >= -1e-6).all()
    assert nodrain.backlog_growth() >= 1.7, nodrain.summary()
    assert nodrain.percentile(99) > drain.percentile(99)


def test_online_drained_latency_matches_fresh_solve_at_low_rate():
    """Arrivals far apart: queues fully drain, so every request sees an
    empty network — latency equals the scenario's empty-network service."""
    sc = make_scenario("star", seed=0)
    rate = sc.nominal_rate(0.01)  # gaps ~100x the service time
    tr = run_online(sc, horizon=20 / rate, seed=3, rate=rate)
    assert tr.records, "no arrivals sampled"
    # an exponential gap is occasionally shorter than the service time, so
    # ask for "almost always fully drained", not "always"
    empty = [r.backlog_before == 0.0 for r in tr.records[1:]]
    assert np.mean(empty) >= 0.7, tr.summary()


# -- events on the clock -----------------------------------------------------

def _edge_cloud_sched(**kw):
    sc = make_scenario("edge-cloud", traffic="synthetic", seed=0)
    return sc, OnlineScheduler(sc.topology, **kw)


def test_slowdown_and_replan_are_clock_events():
    sc, sched = _edge_cloud_sched()
    rng = np.random.default_rng(0)
    sched.submit_jobs(1.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    before = sched.last_plan
    victim = int(before.assign[int(before.order[0]), 0])
    sched.report_slowdown(victim, 100.0, at=2.5)
    assert sched.now == 2.5 and sched.clock == pytest.approx(2.5)
    replans = sched.replan_last()
    assert replans is not None
    for p in replans:
        assert victim not in p.nodes_used
    kinds = [e["event"] for e in sched.trace.events]
    assert kinds == ["slowdown", "replan"]
    assert sched.trace.events[0]["time"] == 2.5


def test_nodrain_clock_still_advances():
    """Time passing and queue draining are independent: the no-drain
    baseline freezes backlogs but not the clock."""
    sc, sched = _edge_cloud_sched(drain_queues=False)
    rng = np.random.default_rng(2)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 1), pad_to=sc.max_layers)
    q0 = np.asarray(sched.state.q_node).copy()
    sched.advance_to(5.0)
    assert sched.clock == pytest.approx(5.0)
    np.testing.assert_array_equal(np.asarray(sched.state.q_node), q0)


def test_replan_drains_elapsed_time_from_rollback():
    """replan_last after time has passed must not resurrect already-served
    backlog: the pre-batch snapshot is drained over the elapsed window."""
    sc, sched = _edge_cloud_sched()
    rng = np.random.default_rng(3)
    # batch 1 builds backlog; batch 2's pre-state snapshot carries it
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 2), pad_to=sc.max_layers)
    assert float(np.asarray(sched._last[2].q_node).sum()) > 0
    bound0 = sched.last_plan.bound()  # scored against batch-1 backlog
    sched.advance_to(1e9)  # everything committed has long been served
    sched.replan_last()
    # the rollback snapshot was drained before re-solving, so batch 2 now
    # sees an empty network and its bound strictly improves
    assert sched.last_plan.bound() < bound0
    assert sched.clock == pytest.approx(1e9)


def test_inherited_advance_shares_the_one_clock():
    """RoutedScheduler.advance and OnlineScheduler.advance_to move the same
    clock: mixing them must not drain the same interval twice."""
    sc, sched = _edge_cloud_sched()
    rng = np.random.default_rng(5)
    sched.submit_jobs(0.0, sc.sample_jobs(rng, 1), pad_to=sc.max_layers)
    sched.advance(5.0)                 # inherited explicit-drain call
    assert sched.now == pytest.approx(5.0)
    q_after_advance = np.asarray(sched.state.q_node).copy()
    sched.advance_to(5.0)              # same instant: dt == 0, no extra drain
    np.testing.assert_array_equal(np.asarray(sched.state.q_node),
                                  q_after_advance)
    assert sched.clock == pytest.approx(5.0)


def test_time_cannot_go_backwards():
    _, sched = _edge_cloud_sched()
    sched.advance_to(5.0)
    with pytest.raises(ValueError, match="backwards"):
        sched.advance_to(4.0)


def test_slowdown_slows_draining():
    """A degraded node drains its backlog at the degraded rate."""
    sc, fast = _edge_cloud_sched()
    _, slow = _edge_cloud_sched()
    rng = np.random.default_rng(1)
    jobs = sc.sample_jobs(rng, 2)
    for s in (fast, slow):
        s.submit_jobs(0.0, list(jobs), pad_to=sc.max_layers)
    q = np.asarray(fast.state.q_node, np.float64)
    mu = np.asarray(sc.topology.mu_node, np.float64)
    waits = np.where(mu > 0, q / np.maximum(mu, 1e-30), 0.0)
    hot = int(np.argmax(waits))
    slow.report_slowdown(hot, 10.0)
    dt = 0.25 * waits[hot]  # partial drain even at the healthy rate
    assert dt > 0
    fast.advance_to(dt)
    slow.advance_to(dt)
    q_fast = float(np.asarray(fast.state.q_node)[hot])
    q_slow = float(np.asarray(slow.state.q_node)[hot])
    assert q_slow > q_fast  # drained at mu/10 instead of mu


# -- bugfix regressions ------------------------------------------------------

def test_backlog_growth_flat_zero_run_is_one():
    """Low-load runs whose backlog is all ~zero must report growth 1.0, not
    the ~1e12 artifact of dividing by the 1e-12 floor."""
    from repro.serving.online import ArrivalRecord, OnlineTrace
    tr = OnlineTrace(records=[
        ArrivalRecord(time=float(i), names=(f"r{i}",), latencies=(0.1,),
                      backlog_before=0.0, backlog_after=0.0, solve_s=0.0)
        for i in range(8)])
    assert tr.backlog_growth() == 1.0
    # ...but genuine growth from a ~zero first half still reads as huge
    tr.records[-1] = dataclasses.replace(tr.records[-1], backlog_after=5.0)
    assert tr.backlog_growth() > 1e6


def test_run_online_rate_scales_diurnal():
    """run_online(rate=) must drive the diurnal process (peak_rate=rate,
    base_rate=rate/5), not be silently dropped."""
    sc = make_scenario("star", seed=0)
    rate = sc.nominal_rate(0.4)
    lo = run_online(sc, horizon=10 / rate, seed=5, process="diurnal",
                    rate=rate)
    hi = run_online(sc, horizon=10 / rate, seed=5, process="diurnal",
                    rate=4 * rate)
    assert len(hi.records) > len(lo.records) >= 1
    # explicit process_params always win over the shorthand
    explicit = run_online(sc, horizon=10 / rate, seed=5, process="diurnal",
                          rate=4 * rate,
                          process_params={"peak_rate": rate,
                                          "base_rate": rate / 5})
    assert len(explicit.records) == len(lo.records)


def test_run_online_rate_rejected_for_unknown_mapping():
    """A registered process with no defined rate mapping must reject the
    shorthand instead of silently ignoring it."""
    from repro.core import arrivals as A

    @A.register_process("every-second")
    def _every_second(gap: float = 1.0):
        return lambda rng, horizon: np.arange(0.0, horizon, gap)

    sc = make_scenario("star", seed=0)
    try:
        with pytest.raises(ValueError, match="no defined mapping"):
            run_online(sc, horizon=3.0, process="every-second", rate=2.0)
        tr = run_online(sc, horizon=3.0, process="every-second",
                        process_params={"gap": 1.0})
        assert len(tr.records) == 3
    finally:
        A._PROCESSES.pop("every-second", None)


def test_report_slowdown_rejects_nonpositive_factor():
    """factor <= 0 or non-finite would flip 1/factor into negative or
    infinite effective capacity; the convention is factor=2 == half speed."""
    _, sched = _edge_cloud_sched()
    sched.advance_to(1.0)
    for bad in (0.0, -2.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="slowdown factor"):
            sched.report_slowdown(0, bad, at=5.0)
    # the invalid event must not have moved the clock or logged an event
    assert sched.now == pytest.approx(1.0)
    assert sched.trace.events == []
    sched.report_slowdown(0, 2.0, at=5.0)  # valid: half speed
    assert sched.now == pytest.approx(5.0)


def test_trace_to_dict_roundtrips_json():
    import json
    sc = make_scenario("random-geometric", seed=2)
    rate = sc.nominal_rate(0.3)
    tr = run_online(sc, horizon=10 / rate, seed=4, rate=rate)
    blob = json.loads(json.dumps(tr.to_dict()))
    assert blob["arrivals"] == len(tr.records)
    assert len(blob["backlogs"]) == len(tr.records)


def test_trace_to_dict_keeps_exact_drain_results():
    """to_dict must not drop completions/replay_completions or the
    actual-latency percentiles — the exact-drain results PR 4/5 compute."""
    import json
    sc = make_scenario("paper-small", seed=0)
    rate = sc.nominal_rate(0.6)
    tr = run_online(sc, horizon=6 / rate, seed=7, rate=rate, drain="exact",
                    track_commits=True, finish=True)
    assert tr.completions and tr.replay_completions
    blob = json.loads(json.dumps(tr.to_dict()))
    assert blob["completions"] == tr.completions
    assert blob["replay_completions"] == tr.replay_completions
    assert len(blob["actual_latencies"]) == len(tr.actual_latencies())
    assert "p99_actual_s" in blob and "p50_actual_s" in blob
    # names serialize alongside, so actuals stay alignable after a reload
    assert blob["names"] == [list(r.names) for r in tr.records]


def test_advance_to_guard_is_relative_at_large_clocks():
    """The backwards-clock guard must scale with the clock (time_eps): at
    t ~ 1e12 an absolute 1e-9 slack is below one ulp, so float-accumulation
    jitter on a legitimate same-instant event would be rejected."""
    from repro.core import schedule

    _, sched = _edge_cloud_sched()
    big = 1e12
    sched.advance_to(big)
    # within tolerance: one ulp of slack at this magnitude is ~0.000122 s,
    # far above the old absolute 1e-9 guard
    jitter = big - 0.25 * schedule.time_eps(big)
    assert jitter < big  # representable below the clock
    sched.advance_to(jitter)           # must not raise
    assert sched.now == big            # ...and the clock never rolls back
    with pytest.raises(ValueError, match="backwards"):
        sched.advance_to(big - 10 * schedule.time_eps(big))
