"""Pallas tropical-matmul kernel vs. pure-jnp oracle (shape/dtype sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.minplus import minplus_matmul_pallas


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 128, 384), (128, 256, 128), (384, 384, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(m * k + n))
    a = (jax.random.uniform(ka, (m, k)) * 10).astype(dtype)
    b = (jax.random.uniform(kb, (k, n)) * 10).astype(dtype)
    out = minplus_matmul_pallas(a, b, interpret=True)
    want = ref.minplus_matmul_ref(a.astype(jnp.float32),
                                  b.astype(jnp.float32))
    tol = 1e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), want, atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (5, 7, 3), (130, 250, 90),
                                   (300, 300, 300)])
def test_padded_wrapper(m, k, n):
    ka, kb = jax.random.split(jax.random.PRNGKey(7))
    a = jax.random.uniform(ka, (m, k)) * 5
    b = jax.random.uniform(kb, (k, n)) * 5
    out = ops.minplus_matmul(a, b, use_pallas=True)
    np.testing.assert_allclose(out, ref.minplus_matmul_ref(a, b), rtol=1e-6)


def test_inf_padding_is_absorbing():
    a = jnp.full((4, 4), 1e30)
    b = jnp.ones((4, 4))
    out = ops.minplus_matmul(a, b, use_pallas=True)
    assert np.all(np.asarray(out) >= 1e29)


def test_closure_vs_dijkstra():
    import networkx as nx
    rng = np.random.default_rng(0)
    n = 17
    W = np.full((n, n), 1e30, np.float32)
    g = nx.gnp_random_graph(n, 0.3, seed=5, directed=True)
    for u, v in g.edges:
        W[u, v] = rng.uniform(0.1, 4)
    D = np.asarray(ops.minplus_closure(jnp.asarray(W)))
    gg = nx.DiGraph()
    gg.add_nodes_from(range(n))
    for u, v in g.edges:
        gg.add_edge(u, v, weight=float(W[u, v]))
    lens = dict(nx.all_pairs_dijkstra_path_length(gg))
    for u in range(n):
        for v in range(n):
            want = lens[u].get(v)
            if want is None:
                assert D[u, v] > 1e29
            elif u == v:
                assert D[u, v] == 0.0
            else:
                np.testing.assert_allclose(D[u, v], want, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_closure_properties(seed):
    """closure is idempotent and satisfies the triangle inequality."""
    rng = np.random.default_rng(seed)
    n = 8
    W = np.where(rng.random((n, n)) < 0.4,
                 rng.uniform(0.1, 5, (n, n)), 1e30).astype(np.float32)
    D = np.asarray(ops.minplus_closure(jnp.asarray(W)))
    D2 = np.asarray(ops.minplus_closure(jnp.asarray(D)))
    np.testing.assert_allclose(D, D2, rtol=1e-5)   # idempotent
    via = np.min(D[:, :, None] + D[None, :, :], axis=1)
    assert np.all(D <= via + 1e-3 * np.abs(via))    # triangle inequality


def test_batched_ref():
    a = jax.random.uniform(jax.random.PRNGKey(0), (3, 8, 8))
    b = jax.random.uniform(jax.random.PRNGKey(1), (3, 8, 8))
    out = ref.minplus_matmul_ref(a, b)
    for i in range(3):
        np.testing.assert_allclose(out[i],
                                   ref.minplus_matmul_ref(a[i], b[i]),
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# Batched Pallas kernel (leading batch grid dimension) + early-exit closure
# ---------------------------------------------------------------------------

def _inf_sparse(rng, shape, density=0.4):
    return np.where(rng.random(shape) < density,
                    rng.uniform(0.1, 5.0, shape), 1e30).astype(np.float32)


@pytest.mark.parametrize("b,m,k,n", [(3, 128, 128, 128), (2, 128, 256, 128)])
def test_batched_kernel_matches_ref(b, m, k, n):
    from repro.kernels.minplus import minplus_matmul_pallas_batched
    rng = np.random.default_rng(b * m + n)
    a = jnp.asarray(_inf_sparse(rng, (b, m, k)))
    bb = jnp.asarray(_inf_sparse(rng, (b, k, n)))
    out = minplus_matmul_pallas_batched(a, bb, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.minplus_matmul_ref(a, bb)),
                               rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_batched_wrapper_random_sparse(seed):
    """Batched Pallas (forced) == broadcast oracle on INF-sparse stacks with
    non-multiple-of-block shapes."""
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 4))
    m, k, n = (int(rng.integers(1, 140)) for _ in range(3))
    a = jnp.asarray(_inf_sparse(rng, (b, m, k)))
    bb = jnp.asarray(_inf_sparse(rng, (b, k, n)))
    out = ops.minplus_matmul(a, bb, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.minplus_matmul_ref(a, bb)),
                               rtol=1e-6)


def test_batched_wrapper_multi_lead_dims():
    """[J, L+1, V, V] stacks flatten to one batch axis and round-trip."""
    rng = np.random.default_rng(7)
    a = jnp.asarray(_inf_sparse(rng, (2, 3, 36, 36)))
    out = ops.minplus_matmul(a, a, use_pallas=True)
    assert out.shape == a.shape
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.minplus_matmul_ref(a, a)),
                               rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_closure_early_exit_matches_unconditional(seed, batched):
    """The while_loop early exit returns the same fixed point, bit for bit,
    as the unconditional (n-1).bit_length() squaring loop: the exit only
    skips squarings that provably reproduce d, so the sequences coincide."""
    rng = np.random.default_rng(seed)
    n = 9
    shape = (3, n, n) if batched else (n, n)
    w = jnp.asarray(_inf_sparse(rng, shape, density=0.3))
    got = np.asarray(ops.minplus_closure(w))
    eye = jnp.arange(n)
    d = w.at[..., eye, eye].min(0.0)
    for _ in range((n - 1).bit_length()):
        d = ops.minplus_matmul(d, d)
    assert np.array_equal(got, np.asarray(d))
    # and the fixed point is semantically the true closure
    np.testing.assert_allclose(got, np.asarray(ref.minplus_closure_ref(w)),
                               rtol=1e-5)


def test_minplus_dispatch_decisions():
    """Shape -> kernel-path decision table (dispatch introspection)."""
    # batched [L+1, V, V] stacks with V >= the threshold hit the batched kernel
    assert ops.minplus_dispatch((9, 256, 256)) == "pallas_batched"
    assert ops.minplus_dispatch((33, 512, 512)) == "pallas_batched"
    assert ops.minplus_dispatch((4, 9, 256, 256)) == "pallas_batched"
    # 2-D operands keep the 2-D kernel
    assert ops.minplus_dispatch((256, 256)) == "pallas_2d"
    # small problems stay on the broadcast oracle
    assert ops.minplus_dispatch((9, 64, 64)) == "oracle"
    assert ops.minplus_dispatch((64, 64)) == "oracle"
    # mismatched leading batch dims always fall back to the oracle
    assert ops.minplus_dispatch((2, 256, 256), (3, 256, 256)) == "oracle"
    # forcing overrides the size threshold, not the structure
    assert ops.minplus_dispatch((3, 8, 8), use_pallas=True) == "pallas_batched"
    assert ops.minplus_dispatch((256, 256), use_pallas=False) == "oracle"


def test_closure_traces_through_batched_kernel():
    """A batched closure actually reaches the batched Pallas kernel (counted
    at trace time via the dispatch tally)."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(_inf_sparse(rng, (3, 40, 40)))
    ops.reset_dispatch_counts()
    got = ops.minplus_closure(w, use_pallas=True)
    assert ops.dispatch_counts().get("pallas_batched", 0) >= 1
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.minplus_closure_ref(w)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Flash attention kernels (kernels/flash.py)
# ---------------------------------------------------------------------------

def _attn_ref(q, k, v, scale):
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    n = q.shape[1]
    m = jnp.arange(n)[:, None] >= jnp.arange(n)[None, :]
    s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bst,btd->bsd", p.astype(q.dtype), v)


@pytest.mark.parametrize("bh,S,d,dv,bq,bk", [
    (2, 256, 64, 64, 128, 128), (3, 512, 128, 96, 128, 256),
    (1, 256, 192, 128, 64, 64), (2, 128, 64, 64, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_forward_matches_ref(bh, S, d, dv, bq, bk, dtype):
    import math
    from repro.kernels.flash import flash_attention_bhsd
    ks = jax.random.split(jax.random.PRNGKey(S + d), 3)
    q = jax.random.normal(ks[0], (bh, S, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (bh, S, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (bh, S, dv), jnp.float32).astype(dtype)
    scale = 1 / math.sqrt(d)
    out = flash_attention_bhsd(q, k, v, scale=scale, bq=min(bq, S),
                               bk=min(bk, S), interpret=True)
    want = _attn_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32), scale)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


def test_flash_grads_match_autodiff():
    import math
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    bh, S, d, dv = 2, 256, 64, 64
    q = jax.random.normal(ks[0], (bh, S, d))
    k = jax.random.normal(ks[1], (bh, S, d))
    v = jax.random.normal(ks[2], (bh, S, dv))
    g = jax.random.normal(ks[3], (bh, S, dv))
    scale = 1 / math.sqrt(d)
    f = lambda *a: jnp.sum(ops.flash_attention(*a, scale=scale, bq=128,
                                               bk=128) * g)
    fr = lambda *a: jnp.sum(_attn_ref(*a, scale) * g)
    va, ga = jax.value_and_grad(f, argnums=(0, 1, 2))(q, k, v)
    vb, gb = jax.value_and_grad(fr, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(va, vb, rtol=1e-4)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


def test_flash_logsumexp_output():
    import math
    from repro.kernels.flash import flash_fwd_lse
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    bh, S, d = 1, 128, 64
    q = jax.random.normal(ks[0], (bh, S, d))
    k = jax.random.normal(ks[1], (bh, S, d))
    v = jax.random.normal(ks[2], (bh, S, d))
    scale = 1 / math.sqrt(d)
    o, lse = flash_fwd_lse(q, k, v, scale=scale, bq=64, bk=64,
                           interpret=True)
    s = jnp.einsum("bsd,btd->bst", q, k) * scale
    mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
    s = jnp.where(mask[None], s, -jnp.inf)
    want = jax.scipy.special.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_dispatch_counts_raises_under_active_trace():
    """dispatch_counts() is a host-side, trace-time tally: reading it while
    a trace is in flight would mix finished and in-progress tracings (and a
    traced reader would bake the stale snapshot into the compiled program),
    so the guarded reader refuses instead of silently over/under-counting."""
    ops.reset_dispatch_counts()
    seen = []

    @jax.jit
    def traced(x):
        with pytest.raises(RuntimeError, match="active jax trace"):
            ops.dispatch_counts()
        seen.append(True)
        return ops.minplus_matmul(x, x)

    w = jnp.zeros((4, 4), jnp.float32)
    traced(w)
    assert seen  # the traced body really ran (and really raised)
    # outside the trace the tally reads fine and saw the traced call above
    assert ops.dispatch_counts().get("oracle", 0) == 1
