"""Unified Plan/Solver API: registry, serialization, cross-solver invariants."""
import json

import numpy as np
import pytest

from repro.core import (Plan, available_solvers, jobs as J, network as N,
                        register_solver, solve, solvers)
from util import random_instance


def _instance(seed, num_jobs=4):
    rng = np.random.default_rng(seed)
    net, jobs = random_instance(rng, num_jobs=num_jobs)
    return net, J.batch_jobs(jobs)


def test_builtin_solvers_registered():
    assert set(available_solvers()) >= {"greedy", "lazy", "sa", "exact"}


@pytest.mark.parametrize("method", ["greedy", "lazy", "sa", "exact"])
def test_solve_returns_plan_for_every_method(method):
    net, batch = _instance(0, num_jobs=3)
    opts = {"d": 0.9, "num_chains": 1} if method == "sa" else {}
    plan = solve(net, batch, method=method, **opts)
    assert isinstance(plan, Plan)
    assert plan.solver == method
    assert plan.meta["method"] == method
    assert plan.meta["solve_s"] >= 0
    assert plan.assign.shape == (batch.num_jobs, batch.max_layers)
    assert sorted(plan.priority.tolist()) == list(range(batch.num_jobs))
    assert np.all(plan.bounds > 0)


def test_unknown_method_raises():
    net, batch = _instance(1)
    with pytest.raises(ValueError, match="unknown solver"):
        solve(net, batch, method="nope")


def test_custom_solver_registration():
    @register_solver("_const_test")
    def const(net, batch, **opts):
        base = solvers.get("greedy")(net, batch)
        return Plan(assign=base.assign, priority=base.priority,
                    bounds=base.bounds, solver="_const_test")

    try:
        net, batch = _instance(2)
        plan = solve(net, batch, method="_const_test")
        assert plan.solver == "_const_test"
    finally:
        solvers._REGISTRY.pop("_const_test", None)


def test_json_round_trip_lossless():
    net, batch = _instance(3)
    for method in ("greedy", "sa"):
        opts = {"d": 0.9, "num_chains": 1} if method == "sa" else {}
        plan = solve(net, batch, method=method, **opts)
        rt = Plan.from_dict(json.loads(json.dumps(plan.to_dict())))
        np.testing.assert_array_equal(rt.assign, plan.assign)
        np.testing.assert_array_equal(rt.priority, plan.priority)
        assert rt.bounds.tolist() == plan.bounds.tolist()  # bit-exact f64
        assert rt.solver == plan.solver
        if plan.net is not None:
            np.testing.assert_array_equal(np.asarray(rt.net.q_node),
                                          np.asarray(plan.net.q_node))
            np.testing.assert_array_equal(np.asarray(rt.net.q_link),
                                          np.asarray(plan.net.q_link))
        if plan.paths is not None:
            assert rt.paths == plan.paths


def test_greedy_and_lazy_bounds_identical():
    """Lazy greedy IS Algorithm 1 (up to ties): same bounds, fewer routings."""
    for seed in range(3):
        net, batch = _instance(seed + 10, num_jobs=5)
        g = solve(net, batch, method="greedy")
        l = solve(net, batch, method="lazy")
        np.testing.assert_allclose(l.bound(), g.bound(), rtol=1e-6)
        assert l.meta["n_routings"] <= g.meta["n_routings"]


def test_simulate_le_bound_randomized():
    """Plan.simulate <= Plan.bound on randomized instances (§III-B)."""
    for seed in range(8):
        net, batch = _instance(seed + 50, num_jobs=3)
        plan = solve(net, batch, method="greedy")
        if plan.bound() >= 1e29:
            continue
        sim = plan.simulate(net, batch)
        assert sim.makespan <= plan.bound() * (1 + 1e-5)


def test_exact_never_worse_than_greedy():
    for seed in range(2):
        net, batch = _instance(seed + 80, num_jobs=3)
        g = solve(net, batch, method="greedy")
        e = solve(net, batch, method="exact")
        assert e.bound() <= g.bound() * (1 + 1e-5)


def test_plan_order_priority_inverse():
    net, batch = _instance(7)
    plan = solve(net, batch, method="greedy")
    order = plan.order
    assert sorted(order.tolist()) == list(range(batch.num_jobs))
    np.testing.assert_array_equal(plan.priority[order],
                                  np.arange(batch.num_jobs))


def test_replay_reproduces_bounds_and_enriches():
    net, batch = _instance(8)
    plan = solve(net, batch, method="greedy")
    rp = plan.replay(net, batch)
    np.testing.assert_allclose(rp.bounds, plan.bounds, rtol=1e-4)
    assert rp.paths is not None and len(rp.paths) == batch.num_jobs
    # simulate() picks up the stored paths
    sim = rp.simulate(net, batch)
    assert sim.makespan <= rp.bound() * (1 + 1e-5)


def test_plan_validates_priority_permutation():
    with pytest.raises(ValueError, match="permutation"):
        Plan(assign=np.zeros((2, 1), np.int32),
             priority=np.array([0, 0], np.int32),
             bounds=np.ones((2,)))


def test_commit_matches_stored_net():
    net, batch = _instance(9)
    plan = solve(net, batch, method="greedy")
    final = plan.commit(net, batch)
    np.testing.assert_allclose(np.asarray(final.q_node),
                               np.asarray(plan.net.q_node), rtol=1e-4)
