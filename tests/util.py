"""Shared test helpers: fixed-shape random routing instances."""
from __future__ import annotations

import numpy as np

from repro.core import jobs as J, network as N

V = 6       # fixed sizes keep jit caches warm across hypothesis examples
LMAX = 4


def random_instance(rng: np.random.Generator, *, num_jobs: int = 1,
                    with_queues: bool = False):
    """Connected random network + random jobs (fixed V / Lmax shapes)."""
    # ring + random chords => always connected
    edges = [(i, (i + 1) % V, float(rng.uniform(0.5, 5.0))) for i in range(V)]
    for _ in range(rng.integers(0, 5)):
        u, v = rng.choice(V, 2, replace=False)
        edges.append((int(u), int(v), float(rng.uniform(0.5, 5.0))))
    caps = rng.uniform(0.0, 4.0, V)
    caps[caps < 0.8] = 0.0            # some nodes have no compute
    if (caps > 0).sum() == 0:
        caps[0] = 2.0
    net = N.make_network(V, edges, caps.astype(float))
    if with_queues:
        qn = rng.uniform(0, 3, V) * (caps > 0)
        mu = np.asarray(net.mu_link)
        ql = rng.uniform(0, 3, (V, V)) * (mu > 0)
        import jax.numpy as jnp
        net = net.with_queues(jnp.asarray(qn, jnp.float32),
                              jnp.asarray(ql, jnp.float32))
    jobs = []
    for i in range(num_jobs):
        L = int(rng.integers(1, LMAX + 1))
        comp = rng.uniform(0.3, 3.0, L).astype(np.float32)
        data = rng.uniform(0.1, 2.0, L + 1).astype(np.float32)
        # pad to LMAX via batch_jobs later; keep job at its own length
        src, dst = rng.choice(V, 2, replace=False)
        jobs.append(J.InferenceJob(f"job{i}", int(src), int(dst), comp, data))
    return net, jobs
