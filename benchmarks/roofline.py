"""Roofline table: derive compute / memory / collective terms per cell from
the dry-run JSON records (deliverable g).

Hardware model (TPU v5e target):
    peak bf16 compute  197 TFLOP/s per chip
    HBM bandwidth      819 GB/s per chip
    ICI link bandwidth ~50 GB/s per link

Terms (seconds, per step, all per-chip — the dry-run records per-device
HLO stats for the partitioned module):
    compute    = HLO_FLOPs_per_dev / 197e12
    memory     = HLO_bytes_per_dev / 819e9
    collective = effective_collective_bytes_per_dev / 50e9

For ssm/hybrid train+prefill cells the layer stacks contain time-loops whose
bodies XLA's cost analysis visits once; those cells use ANALYTIC flops from
the architecture cost model (flops_source = 'analytic') — memory/collective
stay HLO-sourced and are flagged as lower bounds.
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def analytic_flops_per_dev(rec: dict) -> float:
    from repro.configs import registry
    from repro.costs.lm import cost_profile
    cfg = registry.config(rec["arch"])
    comp, _ = cost_profile(cfg, seq_len=rec["seq_len"],
                           batch=rec["global_batch"])
    fwd = comp.sum()
    mult = {"train": 4.0, "prefill": 1.0, "decode": 1.0}[rec["kind"]]
    chips = 512 if "2x16" in rec["mesh"] else 256
    return fwd * mult / chips


def model_flops(rec: dict) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    from repro.configs import registry
    from repro.models import model as M

    cfg = registry.config(rec["arch"])
    n = rec["params"]
    if cfg.moe_num_experts > 0:
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        n = n - cfg.num_layers * cfg.moe_num_experts * per_expert \
            + cfg.num_layers * (cfg.moe_top_k + cfg.moe_num_shared) * per_expert
    d = rec["tokens"]
    return (6.0 if rec["kind"] == "train" else 2.0) * n * d


def row_terms(rec: dict) -> dict:
    chips = 512 if "2x16" in rec["mesh"] else 256
    if rec.get("status") != "ok":
        return {"status": rec.get("status"), "reason": rec.get("reason", rec.get("error", ""))}
    flops = rec["flops_per_device"]
    src = rec.get("flops_source", "hlo")
    if src == "analytic":
        flops = analytic_flops_per_dev(rec)
    bytes_dev = rec["bytes_accessed_per_device"]
    hidden = rec.get("flash_hidden")
    if hidden:  # pallas kernels are custom calls: add their work back
        flops += hidden["flops_per_device"]
        bytes_dev += hidden["bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = rec["collectives"]["effective_bytes"] / LINK_BW
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_x), key=lambda kv: kv[1])[0]
    mf = model_flops(rec)
    useful = mf / max(flops * chips, 1e-30)
    return {
        "status": "ok", "chips": chips, "flops_source": src,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant, "model_flops": mf,
        "useful_ratio": useful,
        "step_bound_s": max(t_c, t_m, t_x),
        "roofline_fraction": t_c / max(t_c, t_m, t_x, 1e-30),
        "temp_gb": (rec.get("memory") or {}).get("temp_bytes", 0) / 1e9,
    }


def build_table(dryrun_dir: str, verbose: bool = True) -> list[dict]:
    rows = []
    for path in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(path.read_text())
        t = row_terms(rec)
        t.update(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"])
        rows.append(t)
        if verbose and t["status"] == "ok":
            print(f"  {rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:10s} "
                  f"c={t['compute_s']:9.2e} m={t['memory_s']:9.2e} "
                  f"x={t['collective_s']:9.2e} dom={t['dominant']:10s} "
                  f"useful={t['useful_ratio']:6.3f} [{t['flops_source']}]")
        elif verbose:
            print(f"  {rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:10s} "
                  f"{t['status']}: {str(t.get('reason'))[:60]}")
    return rows


def run(verbose: bool = True):
    base = pathlib.Path("results/dryrun_baseline")
    if not base.exists():
        print("  (no dry-run results yet — run repro.launch.dryrun first)")
        return []
    return build_table(str(base), verbose=verbose)
