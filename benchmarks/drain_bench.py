"""Exact-drain throughput: indexed event engine vs the reference loop.

  PYTHONPATH=src python benchmarks/drain_bench.py [--smoke] [--out PATH]

For each scenario the full online serving loop runs twice in exact-drain
mode — once on the persistent indexed engine (``sim_engine="indexed"``,
the default), once on the seed linear-scan loop (``"ref"``) — with
identical arrivals, jobs, and plans.  The loop is the deployment pipeline
end to end: per-arrival drain + solve + ledger commit, then the end-of-run
accounting exact mode exists for (``finish()`` serves the residual ledger,
``replay_ground_truth()`` replays the full commit log).  The replay phase
doubles as the drain-only measurement (pure event machinery, solver
excluded); a separate micro-timing covers the per-epoch exact backlog
trace (single forward pass vs the seed's per-sample rescan).

``BENCH_drain.json`` records, per scenario:

  * ``loop``    — wall/arrivals-per-sec for both engines + ``speedup``,
  * ``replay``  — drain-only wall/events-per-sec + ``speedup`` (the pure
                  event-machinery ratio, solver excluded),
  * ``trace``   — exact_backlog_trace wall + ``speedup``,
  * ``indexed_matches_ref`` — same-ledger parity: the indexed engine's
    replay of the ref session's own commit log reproduces the ref loop's
    ground truth within the event-time tolerance discipline (rtol/atol
    1e-9 on completion instants; a rare bounded fraction of knife-edge
    preemption races is allowed — see ``RACE_MAX_FRAC`` — and reported as
    ``replay_races``), and both engines' backlog traces of one log agree
    (the ref trace rounds queues through float32, hence its atol).  The
    two sessions' end-to-end completions are reported as an informational
    ``trajectory_max_diff_s`` only — independent solves may flip a solver
    argmin tie when materialized queues differ in the last ulp, and
    everything downstream of a flipped plan legitimately differs.

plus global flags:

  * ``all_indexed_match_ref`` — parity on every scenario (CI gates on it),
  * ``simulate_unchanged``    — one-shot ``schedule.simulate`` still runs
    the reference loop by default, bit-identical results.

``--smoke`` (2 small scenarios, short streams) is the CI regression gate:
it fails on any parity regression.  Full mode adds ``us-backbone:lm`` at
peak (overloaded-burst) traffic — the scale the paper's §V evaluations
target — where the headline ``loop``/``replay`` speedups are measured.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import numpy as np

# (scenario, arrivals, batch, load): load > 1 is a sustained burst — the
# regime that builds a deep committed backlog, which is exactly what makes
# the reference loop's per-event task rescan quadratic in practice.
SMOKE_CASES = [
    ("paper-small", 10, 2, 1.2),
    ("star", 10, 2, 1.2),
]
FULL_CASES = [
    ("paper-small", 40, 4, 1.2),
    ("star", 40, 4, 1.2),
    ("edge-cloud:synthetic", 40, 4, 1.2),
    ("random-geometric", 40, 4, 1.2),
    ("us-backbone:lm", 160, 32, 1.5),
]

COMPLETION_RTOL = 1e-9
COMPLETION_ATOL = 1e-9
# Preempt-resume with strict priorities is *discontinuous* at event-time
# coincidences: when two independent events land within float-accumulation
# distance (~1e-10 relative), which fires first depends on the engine's
# arithmetic order, and the losing side shifts by a whole preemption
# quantum — the reference loop itself flips the same way under a one-ulp
# input perturbation.  Parity therefore requires the *bulk* of completions
# at float precision and allows a rare bounded fraction of such races.
RACE_MAX_FRAC = 0.005
TRACE_RTOL = 1e-6
TRACE_ATOL = 1e-4   # ref trace rounds queues through float32; indexed is f64


def _drive(name: str, engine: str, *, arrivals: int, batch: int, load: float,
           seed: int) -> dict:
    """One full exact-drain serving session; phase walls + artifacts."""
    from repro.core import arrivals as A
    from repro.scenarios import make_scenario
    from repro.serving.online import OnlineScheduler

    # Fresh scenario per drive: identical rng stream => identical jobs AND
    # identical job names across engines (names key the completions).
    sc = make_scenario(name, seed=0)
    rate = sc.nominal_rate(load)
    rng = np.random.default_rng(seed)
    times = A.make_process("poisson", rate=rate)(rng, arrivals / rate)
    sched = OnlineScheduler(sc.topology, drain="exact", sim_engine=engine,
                            track_commits=True)
    t0 = time.time()
    for t in times:
        sched.submit_jobs(float(t), sc.sample_jobs(rng, batch),
                          pad_to=sc.max_layers)
    t_submit = time.time() - t0
    t0 = time.time()
    completions = sched.finish()
    t_finish = time.time() - t0
    t0 = time.time()
    replay = sched.replay_ground_truth()
    t_replay_gt = time.time() - t0
    return {
        "arrivals": len(times),
        "jobs": len(completions),
        "wall_s": t_submit + t_finish + t_replay_gt,
        "submit_s": t_submit,
        "finish_s": t_finish,
        "replay_s": t_replay_gt,
        "completions": completions,
        "replay_completions": replay,
        "commit_log": sched.commit_log,
        "trace": sched.trace,
        "topology": sc.topology,
    }


def _max_diff(a: dict, b: dict) -> float:
    if a.keys() != b.keys():
        return float("inf")
    return max((abs(a[k] - b[k]) for k in a), default=0.0)


def _completion_parity(a: dict, b: dict) -> dict:
    """Per-job agreement stats + the race-tolerant verdict (see
    RACE_MAX_FRAC)."""
    if a.keys() != b.keys():
        return {"max_diff_s": float("inf"), "races": -1, "race_frac": 1.0,
                "ok": False}
    diffs = np.array([abs(a[k] - b[k]) for k in a], np.float64)
    tol = np.array([COMPLETION_ATOL + COMPLETION_RTOL * abs(b[k])
                    for k in a], np.float64)
    races = int((diffs > tol).sum())
    frac = races / max(diffs.size, 1)
    return {
        "max_diff_s": float(diffs.max(initial=0.0)),
        "races": races,
        "race_frac": frac,
        "ok": bool(frac <= RACE_MAX_FRAC),
    }


def _close_traces(a: np.ndarray, b: np.ndarray) -> bool:
    return bool(np.allclose(a, b, rtol=TRACE_RTOL, atol=TRACE_ATOL))


def _bench_case(name: str, arrivals: int, batch: int, load: float, *,
                seed: int, verbose: bool) -> dict:
    from repro.core import completions as C

    # Untimed warm-up over the *same* arrival stream: jit compilation is
    # keyed by data-dependent shapes (deduped closure rows, path segment
    # counts), so only an identical drive covers every shape the timed
    # runs will hit — compilation must not be charged to either engine.
    # The ref session's trajectory can diverge (solver argmin ties), so it
    # gets its own full warm-up at small scale; at large scale a short ref
    # drive covers the base shapes and any stray tie-flip compile (~1 s)
    # is noise against the multi-minute ref wall.
    _drive(name, "indexed", arrivals=arrivals, batch=batch, load=load,
           seed=seed)
    _drive(name, "ref", arrivals=arrivals if arrivals * batch <= 1000
           else 8, batch=batch, load=load, seed=seed)
    runs = {eng: _drive(name, eng, arrivals=arrivals, batch=batch,
                        load=load, seed=seed) for eng in ("indexed", "ref")}
    idx, ref = runs["indexed"], runs["ref"]
    log, topo = idx["commit_log"], idx["topology"]
    # total stage-completion + arrival events of the horizon — the common
    # work denominator for events/sec on either engine
    events = sum(len(j.stages) + 1 for j in log.jobs)

    # Drain-only: the per-epoch exact backlog trace (the loop's replay_s
    # phase already times the full-horizon ground-truth replay).  At large
    # scale the epochs are subsampled — the ref trace re-serves the whole
    # horizon regardless, so the comparison is unchanged.
    sample_times = idx["trace"].times
    if sample_times.size > 64:
        sample_times = sample_times[:: int(np.ceil(sample_times.size / 64))]
    trace_wall, traces = {}, {}
    for eng in ("indexed", "ref"):
        t0 = time.time()
        traces[eng] = C.exact_backlog_trace(topo, log, sample_times,
                                            engine=eng)
        trace_wall[eng] = time.time() - t0

    # Parity is judged on the *same ledger*: the indexed engine replays the
    # ref run's own commit log and must reproduce the ref loop's recorded
    # ground truth; ditto the backlog traces above (both engines, one log).
    # The two *sessions'* completions are reported too, but only as an
    # informational trajectory diff — independent solves can flip an
    # argmin tie when the materialized queues differ in the last ulp, and
    # everything downstream of a flipped plan legitimately differs.
    idx_replay_of_ref_log, _ = C.run_to_completion(
        ref["topology"], ref["commit_log"], engine="indexed")
    parity = _completion_parity(idx_replay_of_ref_log,
                                ref["replay_completions"])
    trajectory_diff = _max_diff(idx["completions"], ref["completions"])
    trace_diff = float(np.abs(traces["indexed"] - traces["ref"]).max())
    matches = parity["ok"] and _close_traces(traces["indexed"],
                                             traces["ref"])
    row = {
        "scenario": name,
        "arrivals": idx["arrivals"],
        "batch": batch,
        "jobs": idx["jobs"],
        "load": load,
        "events": events,
        "loop": {
            eng: {"wall_s": r["wall_s"], "submit_s": r["submit_s"],
                  "finish_s": r["finish_s"], "replay_s": r["replay_s"],
                  "arrivals_per_s": r["arrivals"] / r["wall_s"]}
            for eng, r in runs.items()
        },
        "loop_speedup": ref["wall_s"] / idx["wall_s"],
        "replay": {eng: {"wall_s": r["replay_s"],
                         "events_per_s": events / r["replay_s"]}
                   for eng, r in runs.items()},
        "replay_speedup": ref["replay_s"] / idx["replay_s"],
        "trace_samples": int(sample_times.size),
        "trace": {eng: {"wall_s": w} for eng, w in trace_wall.items()},
        "trace_speedup": trace_wall["ref"] / trace_wall["indexed"],
        "replay_max_diff_s": parity["max_diff_s"],
        "replay_races": parity["races"],
        "replay_race_frac": parity["race_frac"],
        "trace_max_diff_s": trace_diff,
        "trajectory_max_diff_s": trajectory_diff,
        "indexed_matches_ref": bool(matches),
    }
    if verbose:
        print(f"{name:24s} jobs={row['jobs']:5d} events={events:6d}  "
              f"loop {row['loop_speedup']:5.2f}x "
              f"({row['loop']['ref']['wall_s']:7.2f}s -> "
              f"{row['loop']['indexed']['wall_s']:7.2f}s, "
              f"{row['loop']['indexed']['arrivals_per_s']:6.1f} arr/s)  "
              f"replay {row['replay_speedup']:6.1f}x "
              f"({row['replay']['indexed']['events_per_s']:9.0f} ev/s)  "
              f"trace {row['trace_speedup']:6.1f}x  "
              f"match={row['indexed_matches_ref']} "
              f"races={row['replay_races']}/{row['jobs']}", flush=True)
    return row


def _simulate_unchanged() -> bool:
    """One-shot simulate still runs the reference loop by default (bitwise),
    and the indexed engine agrees within tolerance."""
    from repro.core import jobs as J, schedule, solve
    from repro.scenarios import make_scenario

    sc = make_scenario("paper-small", seed=0)
    rng = np.random.default_rng(0)
    batch = J.batch_jobs(sc.sample_jobs(rng, 6), pad_to=sc.max_layers)
    net = sc.topology.view()
    plan = solve(net, batch, method="greedy")
    default = schedule.simulate(net, batch, plan)
    ref = schedule.simulate(net, batch, plan, engine="ref")
    idx = schedule.simulate(net, batch, plan, engine="indexed")
    bitwise = default.completion.tolist() == ref.completion.tolist()
    close = np.allclose(idx.completion, ref.completion, rtol=1e-9, atol=1e-9)
    return bool(bitwise and close)


def run(*, smoke: bool = False, seed: int = 5, verbose: bool = True) -> dict:
    cases = SMOKE_CASES if smoke else FULL_CASES
    rows = [_bench_case(name, arrivals, batch, load, seed=seed,
                        verbose=verbose)
            for name, arrivals, batch, load in cases]
    big = rows[-1]
    out = {
        "benchmark": "drain",
        "smoke": smoke,
        "rows": rows,
        "all_indexed_match_ref": all(r["indexed_matches_ref"] for r in rows),
        "simulate_unchanged": _simulate_unchanged(),
        "headline": {
            "scenario": big["scenario"],
            "loop_speedup": big["loop_speedup"],
            "replay_speedup": big["replay_speedup"],
            "arrivals_per_s_indexed":
                big["loop"]["indexed"]["arrivals_per_s"],
            "arrivals_per_s_ref": big["loop"]["ref"]["arrivals_per_s"],
            "events_per_s_indexed":
                big["replay"]["indexed"]["events_per_s"],
        },
    }
    if verbose:
        h = out["headline"]
        print(f"all_indexed_match_ref={out['all_indexed_match_ref']} "
              f"simulate_unchanged={out['simulate_unchanged']} "
              f"headline[{h['scenario']}]: loop {h['loop_speedup']:.2f}x, "
              f"replay {h['replay_speedup']:.1f}x, "
              f"{h['arrivals_per_s_indexed']:.1f} arr/s", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny streams, 2 scenarios (the CI parity gate)")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_drain.json"))
    args = ap.parse_args()
    record = run(smoke=args.smoke, seed=args.seed)
    pathlib.Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"wrote {args.out}")
    if not record["all_indexed_match_ref"]:
        raise SystemExit("indexed engine diverged from the reference loop")
    if not record["simulate_unchanged"]:
        raise SystemExit("one-shot simulate results changed vs the "
                         "reference loop")


if __name__ == "__main__":
    main()
