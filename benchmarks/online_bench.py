"""Online serving benchmark: latency/backlog vs offered load, drain vs no-drain.

  PYTHONPATH=src python benchmarks/online_bench.py [--smoke] [--out PATH]

For each scenario x offered-load factor, drives a sub-capacity Poisson
arrival stream through the online loop twice — with queue draining (the
time-aware scheduler) and without (the legacy commit-only loop) — and
records p50/p99 latency bounds and the backlog trajectory.  The headline
flags in ``BENCH_online.json``:

  * ``drain_bounded``    — the draining run's peak backlog is flat over the
                           run's second half (growth <= 1.3x),
  * ``nodrain_diverges`` — the no-drain run keeps climbing (>= 1.5x),
  * ``static_bounds_match`` — the static greedy path still reproduces the
                           pre-split quickstart bounds bit-for-bit.

``--smoke`` (2 scenarios, short streams) is the CI regression gate.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import numpy as np

SMOKE_SCENARIOS = ["star", "edge-cloud:synthetic"]
FULL_SCENARIOS = ["star", "random-geometric", "edge-cloud:synthetic",
                  "paper-small"]

DRAIN_BOUNDED_MAX_GROWTH = 1.3
NODRAIN_MIN_GROWTH = 1.5


def _static_bounds_match() -> bool:
    """Quickstart greedy bounds, bit-compared against the pre-split record."""
    from repro.core import solve
    from benchmarks.common import (QUICKSTART_BOUNDS, QUICKSTART_ORDER,
                                   quickstart_instance)

    net, batch = quickstart_instance()
    plan = solve(net, batch, method="greedy")
    return (plan.bounds.tolist() == QUICKSTART_BOUNDS
            and plan.order.tolist() == QUICKSTART_ORDER)


def run(*, smoke: bool = False, arrivals: int = 80, seed: int = 1,
        loads: tuple[float, ...] = (0.3, 0.6, 0.9),
        verbose: bool = True) -> list[dict]:
    from repro.scenarios import make_scenario
    from repro.serving.online import run_online

    scenarios = SMOKE_SCENARIOS if smoke else FULL_SCENARIOS
    if smoke:
        arrivals = min(arrivals, 40)
        loads = (0.4, 0.8)
    rows = []
    for name in scenarios:
        sc = make_scenario(name, seed=0)
        for load in loads:
            rate = sc.nominal_rate(load)
            horizon = arrivals / rate
            row = {"scenario": sc.name, "load": load, "rate_per_s": rate,
                   "mean_service_s": sc.mean_service_s}
            for mode, drain in (("drain", True), ("nodrain", False)):
                tr = run_online(sc, horizon=horizon, seed=seed, rate=rate,
                                drain_queues=drain)
                s = tr.summary()
                row[mode] = s
            row["drain_bounded"] = (
                row["drain"]["backlog_growth"] <= DRAIN_BOUNDED_MAX_GROWTH)
            row["nodrain_diverges"] = (
                row["nodrain"]["backlog_growth"] >= NODRAIN_MIN_GROWTH)
            rows.append(row)
            if verbose:
                d, nd = row["drain"], row["nodrain"]
                print(f"{sc.name:28s} load {load:.1f}: "
                      f"p99 {d['p99_latency_s']:8.3f}s vs {nd['p99_latency_s']:8.3f}s  "
                      f"backlog growth {d['backlog_growth']:.2f} vs "
                      f"{nd['backlog_growth']:.2f}  "
                      f"bounded={row['drain_bounded']} "
                      f"diverges={row['nodrain_diverges']}", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny streams, 2 scenarios (the CI gate)")
    ap.add_argument("--arrivals", type=int, default=80)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_online.json"))
    args = ap.parse_args()

    rows = run(smoke=args.smoke, arrivals=args.arrivals, seed=args.seed)
    record = {
        "benchmark": "online_serving",
        "smoke": args.smoke,
        "static_bounds_match": _static_bounds_match(),
        "rows": rows,
        "all_drain_bounded": all(r["drain_bounded"] for r in rows),
        "all_nodrain_diverge": all(r["nodrain_diverges"] for r in rows),
    }
    pathlib.Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"wrote {args.out}")
    print(f"static_bounds_match={record['static_bounds_match']} "
          f"all_drain_bounded={record['all_drain_bounded']} "
          f"all_nodrain_diverge={record['all_nodrain_diverge']}")
    if not record["static_bounds_match"]:
        raise SystemExit("static greedy path no longer bit-identical to seed")
    if args.smoke and not record["all_drain_bounded"]:
        raise SystemExit("draining scheduler failed to keep backlog bounded")
    if args.smoke and not record["all_nodrain_diverge"]:
        raise SystemExit("no-drain baseline unexpectedly stayed bounded")


if __name__ == "__main__":
    main()
