"""Online serving benchmark: latency/backlog vs offered load, drain vs
no-drain, and the fluid-vs-exact drain fidelity gap.

  PYTHONPATH=src python benchmarks/online_bench.py [--smoke] [--out PATH]

For each scenario x offered-load factor, drives a sub-capacity Poisson
arrival stream through the online loop twice — with queue draining (the
time-aware scheduler) and without (the legacy commit-only loop) — and
records p50/p99 latency bounds and the backlog trajectory.  The headline
flags in ``BENCH_online.json``:

  * ``drain_bounded``    — the draining run's peak backlog is flat over the
                           run's second half (growth <= 1.3x),
  * ``nodrain_diverges`` — the no-drain run keeps climbing (>= 1.5x),
  * ``static_bounds_match`` — the static greedy path still reproduces the
                           pre-split quickstart bounds bit-for-bit.

The **fidelity** section measures how honest each drain model's numbers
are, per arrival, against the event simulator's ground truth:

  * the same plans the fluid run committed are replayed under exact
    (committed-work) accounting — the backlog gap is the fluid model's
    optimism, with policy decisions held fixed;
  * each run's claimed latency bounds are compared with the actual
    completion times of a full-horizon event replay: fluid bounds can be
    *violated* (it under-counts residual work); exact-drain bounds must
    dominate actuals (``all_exact_bounds_hold``);
  * the exact run's incrementally recorded completions must equal the
    one-shot replay (``all_exact_match_replay``) — the chunked drain is
    event-exact, not an approximation;
  * ``fluid_matches_seed`` — the default fluid trajectory is bit-identical
    to the pre-ledger capture (the exact drain is strictly opt-in).

``--smoke`` (2 scenarios, short streams, fidelity on paper-small) is the
CI regression gate: it fails on ``fluid_matches_seed``,
``all_exact_bounds_hold``, or ``all_exact_match_replay`` regressions.
Full mode includes the ``us-backbone:lm`` scale sweep (24-node USNET,
LM-profile traffic) in both the load sweep and the fidelity section — the
exact drain there runs on the indexed event engine
(:mod:`repro.core.eventsim`); ``benchmarks/drain_bench.py`` measures that
engine's throughput against the reference loop.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import numpy as np

SMOKE_SCENARIOS = ["star", "edge-cloud:synthetic"]
FULL_SCENARIOS = ["star", "random-geometric", "edge-cloud:synthetic",
                  "paper-small", "us-backbone:lm"]
FIDELITY_SMOKE_SCENARIOS = ["paper-small"]
FIDELITY_FULL_SCENARIOS = ["paper-small", "star", "edge-cloud:synthetic",
                           "random-geometric", "us-backbone:lm"]

DRAIN_BOUNDED_MAX_GROWTH = 1.3
NODRAIN_MIN_GROWTH = 1.5
FIDELITY_LOAD = 0.9          # high enough that the optimism gap shows
BOUND_TOL = 1e-6             # relative slack for bound >= actual checks


def _static_bounds_match() -> bool:
    """Quickstart greedy bounds, bit-compared against the pre-split record."""
    from repro.core import solve
    from benchmarks.common import (QUICKSTART_BOUNDS, QUICKSTART_ORDER,
                                   quickstart_instance)

    net, batch = quickstart_instance()
    plan = solve(net, batch, method="greedy")
    return (plan.bounds.tolist() == QUICKSTART_BOUNDS
            and plan.order.tolist() == QUICKSTART_ORDER)


def run(*, smoke: bool = False, arrivals: int = 80, seed: int = 1,
        loads: tuple[float, ...] = (0.3, 0.6, 0.9),
        verbose: bool = True) -> list[dict]:
    from repro.scenarios import make_scenario
    from repro.serving.online import run_online

    scenarios = SMOKE_SCENARIOS if smoke else FULL_SCENARIOS
    if smoke:
        arrivals = min(arrivals, 40)
        loads = (0.4, 0.8)
    rows = []
    for name in scenarios:
        sc = make_scenario(name, seed=0)
        # The LM mix's mean service time (~6.5 s) is huge next to its
        # nominal inter-arrival gap, so the no-drain loop's backlog-growth
        # signal needs a longer stream to clear the divergence threshold.
        n_arr = arrivals * 2 if name.startswith("us-backbone") else arrivals
        for load in loads:
            rate = sc.nominal_rate(load)
            horizon = n_arr / rate
            row = {"scenario": sc.name, "load": load, "rate_per_s": rate,
                   "mean_service_s": sc.mean_service_s}
            for mode, drain in (("drain", True), ("nodrain", False)):
                tr = run_online(sc, horizon=horizon, seed=seed, rate=rate,
                                drain_queues=drain)
                s = tr.summary()
                row[mode] = s
            row["drain_bounded"] = (
                row["drain"]["backlog_growth"] <= DRAIN_BOUNDED_MAX_GROWTH)
            row["nodrain_diverges"] = (
                row["nodrain"]["backlog_growth"] >= NODRAIN_MIN_GROWTH)
            rows.append(row)
            if verbose:
                d, nd = row["drain"], row["nodrain"]
                print(f"{sc.name:28s} load {load:.1f}: "
                      f"p99 {d['p99_latency_s']:8.3f}s vs {nd['p99_latency_s']:8.3f}s  "
                      f"backlog growth {d['backlog_growth']:.2f} vs "
                      f"{nd['backlog_growth']:.2f}  "
                      f"bounded={row['drain_bounded']} "
                      f"diverges={row['nodrain_diverges']}", flush=True)
    return rows


def _fluid_matches_seed() -> bool:
    """Default-mode (fluid) online trajectory, bit-compared against the
    pre-ledger capture on paper-small."""
    from benchmarks.common import (FLUID_GOLD_ARRIVALS, FLUID_GOLD_BACKLOGS,
                                   FLUID_GOLD_LATENCIES, FLUID_GOLD_LOAD,
                                   FLUID_GOLD_SCENARIO, FLUID_GOLD_SEED)
    from repro.scenarios import make_scenario
    from repro.serving.online import run_online

    sc = make_scenario(FLUID_GOLD_SCENARIO, seed=0)
    rate = sc.nominal_rate(FLUID_GOLD_LOAD)
    tr = run_online(sc, horizon=FLUID_GOLD_ARRIVALS / rate,
                    seed=FLUID_GOLD_SEED, rate=rate)
    return (tr.backlogs.tolist() == FLUID_GOLD_BACKLOGS
            and tr.latencies.tolist() == FLUID_GOLD_LATENCIES)


def _bound_violations(actual: np.ndarray, bound: np.ndarray) -> dict:
    excess = actual - bound
    viol = actual > bound * (1 + BOUND_TOL) + 1e-9
    return {
        "requests": int(bound.size),
        "violations": int(viol.sum()),
        "max_excess_s": float(excess.max()) if excess.size else 0.0,
        "mean_headroom_s": float(np.maximum(bound - actual, 0.0).mean())
        if excess.size else 0.0,
    }


def run_fidelity(*, smoke: bool = False, arrivals: int = 40, seed: int = 7,
                 verbose: bool = True) -> dict:
    """Fluid vs exact drain vs ground-truth replay, per scenario."""
    from repro.core import completions as C
    from repro.scenarios import make_scenario
    from repro.serving.online import run_online

    scenarios = FIDELITY_SMOKE_SCENARIOS if smoke else FIDELITY_FULL_SCENARIOS
    if smoke:
        arrivals = min(arrivals, 30)
    rows = []
    for name in scenarios:
        sc = make_scenario(name, seed=0)
        rate = sc.nominal_rate(FIDELITY_LOAD)
        horizon = arrivals / rate
        kw = dict(horizon=horizon, seed=seed, rate=rate,
                  track_commits=True, finish=True)
        fluid = run_online(sc, drain="fluid", **kw)
        exact = run_online(sc, drain="exact", **kw)
        # Same plans, exact accounting: the drain-semantics gap in isolation.
        exact_backlogs = C.exact_backlog_trace(sc.topology, fluid.commit_log,
                                               fluid.times)
        fluid_backlogs = np.array([r.backlog_before for r in fluid.records])
        gap = exact_backlogs - fluid_backlogs
        # Claimed bounds vs actual completions (full-horizon event replay).
        fluid_gt = _bound_violations(fluid.actual_latencies(),
                                     fluid.latencies)
        exact_gt = _bound_violations(exact.actual_latencies(),
                                     exact.latencies)
        # Incremental exact drain vs one-shot replay of the same commits.
        replay_diff = max((abs(exact.completions[n]
                               - exact.replay_completions[n])
                           for n in exact.completions), default=0.0)
        row = {
            "scenario": sc.name,
            "load": FIDELITY_LOAD,
            "arrivals": len(fluid.records),
            "fluid": fluid.summary(),
            "exact": exact.summary(),
            "backlog_gap_mean_s": float(gap.mean()),
            "backlog_gap_max_s": float(gap.max()),
            "backlog_gap_vs_fluid_mean": float(
                gap.mean() / max(fluid_backlogs.mean(), 1e-12)),
            "fluid_never_pessimistic": bool((gap >= -1e-6).all()),
            "fluid_vs_ground_truth": fluid_gt,
            "exact_vs_ground_truth": exact_gt,
            "exact_bounds_hold": exact_gt["violations"] == 0,
            "exact_replay_max_diff_s": float(replay_diff),
            "exact_matches_replay": bool(replay_diff <= 1e-6),
        }
        rows.append(row)
        if verbose:
            print(f"fidelity {sc.name:28s}: backlog gap mean "
                  f"{row['backlog_gap_mean_s']:.4f}s "
                  f"({100 * row['backlog_gap_vs_fluid_mean']:.0f}% of fluid) "
                  f"fluid bound violations "
                  f"{fluid_gt['violations']}/{fluid_gt['requests']} "
                  f"(max excess {fluid_gt['max_excess_s']:.4f}s)  "
                  f"exact holds={row['exact_bounds_hold']} "
                  f"replay diff {replay_diff:.2e}", flush=True)
    out = {
        "load": FIDELITY_LOAD,
        "rows": rows,
        "fluid_matches_seed": _fluid_matches_seed(),
        "all_exact_bounds_hold": all(r["exact_bounds_hold"] for r in rows),
        "all_exact_match_replay": all(r["exact_matches_replay"]
                                      for r in rows),
        "any_fluid_bound_violation": any(
            r["fluid_vs_ground_truth"]["violations"] > 0 for r in rows),
    }
    if verbose:
        print(f"fluid_matches_seed={out['fluid_matches_seed']} "
              f"all_exact_bounds_hold={out['all_exact_bounds_hold']} "
              f"all_exact_match_replay={out['all_exact_match_replay']}",
              flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny streams, 2 scenarios (the CI gate)")
    ap.add_argument("--arrivals", type=int, default=80)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_online.json"))
    args = ap.parse_args()

    rows = run(smoke=args.smoke, arrivals=args.arrivals, seed=args.seed)
    fidelity = run_fidelity(smoke=args.smoke, seed=args.seed + 6)
    record = {
        "benchmark": "online_serving",
        "smoke": args.smoke,
        "static_bounds_match": _static_bounds_match(),
        "rows": rows,
        "all_drain_bounded": all(r["drain_bounded"] for r in rows),
        "all_nodrain_diverge": all(r["nodrain_diverges"] for r in rows),
        "fidelity": fidelity,
    }
    pathlib.Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"wrote {args.out}")
    print(f"static_bounds_match={record['static_bounds_match']} "
          f"all_drain_bounded={record['all_drain_bounded']} "
          f"all_nodrain_diverge={record['all_nodrain_diverge']}")
    if not record["static_bounds_match"]:
        raise SystemExit("static greedy path no longer bit-identical to seed")
    if not fidelity["fluid_matches_seed"]:
        raise SystemExit("default (fluid) online trajectory no longer "
                         "bit-identical to the pre-ledger capture")
    if args.smoke and not record["all_drain_bounded"]:
        raise SystemExit("draining scheduler failed to keep backlog bounded")
    if args.smoke and not record["all_nodrain_diverge"]:
        raise SystemExit("no-drain baseline unexpectedly stayed bounded")
    if args.smoke and not fidelity["all_exact_bounds_hold"]:
        raise SystemExit("exact-drain bounds were violated by the ground-"
                         "truth replay")
    if args.smoke and not fidelity["all_exact_match_replay"]:
        raise SystemExit("incremental exact drain diverged from the one-"
                         "shot full-horizon replay")


if __name__ == "__main__":
    main()
