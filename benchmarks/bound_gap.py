"""Fictitious-system tightness: bound / simulated-completion ratio across
random instances (the paper's §III-B upper-bound claim, quantified)."""
from __future__ import annotations

import numpy as np

from repro.core import jobs as J, solve
from .runtime_scaling import synthetic_network, jobs_for


def run(verbose: bool = True, n_instances: int = 5) -> dict:
    ratios = []
    for seed in range(n_instances):
        net = synthetic_network(16, seed)
        batch = J.batch_jobs(jobs_for(16, 6, seed))
        plan = solve(net, batch, method="greedy")
        sim = plan.simulate(net, batch)
        assert sim.makespan <= plan.bound() * (1 + 1e-6)
        ratios.append(plan.bound() / sim.makespan)
    out = dict(mean_ratio=float(np.mean(ratios)),
               max_ratio=float(np.max(ratios)),
               min_ratio=float(np.min(ratios)))
    if verbose:
        print(f"  bound/simulated: mean {out['mean_ratio']:.3f} "
              f"min {out['min_ratio']:.3f} max {out['max_ratio']:.3f}")
    return out
