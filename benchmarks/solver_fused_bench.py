"""Fused single-dispatch greedy solver: parity, dispatch accounting, scaling.

  PYTHONPATH=src python benchmarks/solver_fused_bench.py [--smoke] [--out PATH]

Four measurements around ``greedy_route`` (the on-device ``lax.scan`` round
loop) against ``greedy_route_ref`` (the host-driven loop it replaced, kept
as the parity reference):

  * ``parity``  — over a seeded scenario catalog, the fused solver must
    reproduce the reference **bit-for-bit**: round order, assignments,
    bounds, committed queues, and extracted paths — at the fresh state AND
    at the queued state left by committing the first plan (queued edge
    weights are where an FMA-contraction ulp would flip argmin ties).
    ``fused_matches_ref`` is the global flag CI gates on.
  * ``solve_scaling`` — warm per-solve wall vs batch width J, fused vs
    reference, with honest dispatch accounting: the fused solve is one
    device program per solve (``meta["dispatches"] == 1``) regardless of
    J, while the reference pays J closure builds + J round dispatches.
  * ``window_scaling`` — cross-arrival batching: W queued windows solved
    by one ``solve_fused`` multi-window dispatch vs W sequential fused
    solves threading the committed queues by hand.
  * ``end_to_end`` — the full exact-drain online serving loop of
    ``drain_bench`` (same scenario, arrival process, seed and phases),
    now with the fused solver, against the arr/s its ``BENCH_drain.json``
    recorded for the identical drive with the pre-fused solver (the
    1.15 arr/s us-backbone:lm baseline).  ``end_to_end_5x`` is the
    headline acceptance flag: >= 5x sustained arrivals/sec.

``--smoke`` (tiny catalog + a short paper-small end-to-end pair driven
both ways) is the CI gate: it fails on any parity regression.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import numpy as np

# Parity catalog: (scenario, jobs-per-window).  Every entry is checked at
# the fresh state and at the queued state its own first commit produces.
SMOKE_PARITY = [("paper-small", 4), ("star", 4)]
FULL_PARITY = [("paper-small", 4), ("paper-small", 7),   # 7: odd-J pad path
               ("star", 4), ("edge-cloud:synthetic", 4),
               ("random-geometric", 4), ("us-backbone:lm", 8)]

SMOKE_JOBS = (2, 4)
FULL_JOBS = (4, 8, 16, 32)
SMOKE_WINDOWS = (1, 2)
FULL_WINDOWS = (1, 2, 4, 8)

# drain_bench's end-to-end cases: (scenario, arrivals, batch, load).  The
# full case is the BENCH_drain.json headline row (seed 5, poisson).
SMOKE_E2E = ("paper-small", 10, 2, 1.2)
FULL_E2E = ("us-backbone:lm", 160, 32, 1.5)
DRAIN_BASELINE_FALLBACK = 1.1453   # BENCH_drain.json us-backbone:lm arr/s
E2E_TARGET_SPEEDUP = 5.0


def _plans_bitwise(a, b) -> bool:
    return (a.order.tolist() == b.order.tolist()
            and np.array_equal(np.asarray(a.assign), np.asarray(b.assign))
            and (np.asarray(a.bounds).tolist()
                 == np.asarray(b.bounds).tolist())
            and np.array_equal(np.asarray(a.net.q_node),
                               np.asarray(b.net.q_node))
            and np.array_equal(np.asarray(a.net.q_link),
                               np.asarray(b.net.q_link))
            and a.paths == b.paths)


def _parity_case(name: str, jobs_per: int, *, seed: int) -> dict:
    from repro.core import greedy, jobs as J
    from repro.scenarios import make_scenario

    sc = make_scenario(name, seed=0)
    rng = np.random.default_rng(seed)
    net = sc.topology.view()
    row = {"scenario": name, "jobs": jobs_per}
    for state in ("fresh", "queued"):
        batch = J.batch_jobs(sc.sample_jobs(rng, jobs_per),
                             pad_to=sc.max_layers)
        fused = greedy.greedy_route(net, batch, extract_paths=True)
        ref = greedy.greedy_route_ref(net, batch, extract_paths=True)
        row[f"{state}_ok"] = _plans_bitwise(fused, ref)
        net = fused.net   # the committed queues seed the queued-state check
    row["ok"] = row["fresh_ok"] and row["queued_ok"]
    return row


def _time_best(fn, repeat: int) -> float:
    fn()   # warm: jit compilation keys on shapes, not values
    best = float("inf")
    for _ in range(max(repeat, 1)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _solve_scaling(name: str, sizes, *, seed: int, repeat: int,
                   verbose: bool) -> list[dict]:
    from repro.core import greedy, jobs as J
    from repro.core import shortest_path as SP
    from repro.scenarios import make_scenario

    sc = make_scenario(name, seed=0)
    rng = np.random.default_rng(seed)
    net = sc.topology.view()
    rows = []
    for n in sizes:
        batch = J.batch_jobs(sc.sample_jobs(rng, n), pad_to=sc.max_layers)
        fused_s = _time_best(
            lambda: np.asarray(greedy.greedy_route(net, batch).bounds),
            repeat)
        ref_s = _time_best(
            lambda: np.asarray(greedy.greedy_route_ref(net, batch).bounds),
            repeat)
        plan = greedy.greedy_route(net, batch)
        SP.reset_closure_build_count()
        greedy.greedy_route_ref(net, batch)
        row = {
            "scenario": name,
            "jobs": n,
            "fused_ms": fused_s * 1e3,
            "ref_ms": ref_s * 1e3,
            "speedup": ref_s / fused_s,
            "dispatches": plan.meta["dispatches"],
            "rounds_per_dispatch": plan.meta["rounds_per_dispatch"],
            "ref_closure_builds": SP.closure_build_count(),
        }
        rows.append(row)
        if verbose:
            print(f"  J={n:3d}: fused {row['fused_ms']:8.2f} ms "
                  f"(1 dispatch, {row['rounds_per_dispatch']} rounds)  "
                  f"ref {row['ref_ms']:8.2f} ms "
                  f"({row['ref_closure_builds']} closure builds)  "
                  f"{row['speedup']:5.2f}x", flush=True)
    return rows


def _window_scaling(name: str, widths, *, jobs_per: int, seed: int,
                    repeat: int, verbose: bool) -> list[dict]:
    from repro.core import greedy, jobs as J, solvers
    from repro.scenarios import make_scenario

    sc = make_scenario(name, seed=0)
    rng = np.random.default_rng(seed)
    net = sc.topology.view()
    windows = [J.batch_jobs(sc.sample_jobs(rng, jobs_per),
                            pad_to=sc.max_layers) for _ in range(max(widths))]
    rows = []
    for w in widths:
        batches = windows[:w]

        def fused():
            plans = solvers.solve_fused(net, batches, pad_to=sc.max_layers)
            np.asarray(plans[-1].bounds)

        def sequential():
            cur = net
            for b in batches:
                p = greedy.greedy_route(cur, b)
                cur = p.net
            np.asarray(p.bounds)

        fused_s = _time_best(fused, repeat)
        seq_s = _time_best(sequential, repeat)
        row = {
            "scenario": name,
            "windows": w,
            "jobs_per_window": jobs_per,
            "fused_ms": fused_s * 1e3,
            "sequential_ms": seq_s * 1e3,
            "speedup": seq_s / fused_s,
            "dispatches": 1,
            "sequential_dispatches": w,
        }
        rows.append(row)
        if verbose:
            print(f"  W={w}: fused {row['fused_ms']:8.2f} ms (1 dispatch)  "
                  f"sequential {row['sequential_ms']:8.2f} ms "
                  f"({w} dispatches)  {row['speedup']:5.2f}x", flush=True)
    return rows


def _e2e_drive(name: str, *, arrivals: int, batch: int, load: float,
               seed: int, method: str = "greedy") -> dict:
    """drain_bench's full exact-drain session, solver method selectable."""
    from repro.core import arrivals as A
    from repro.scenarios import make_scenario
    from repro.serving.online import OnlineScheduler

    sc = make_scenario(name, seed=0)
    rate = sc.nominal_rate(load)
    rng = np.random.default_rng(seed)
    times = A.make_process("poisson", rate=rate)(rng, arrivals / rate)
    sched = OnlineScheduler(sc.topology, drain="exact", sim_engine="indexed",
                            track_commits=True, method=method)
    t0 = time.time()
    for t in times:
        sched.submit_jobs(float(t), sc.sample_jobs(rng, batch),
                          pad_to=sc.max_layers)
    t_submit = time.time() - t0
    t0 = time.time()
    sched.finish()
    t_finish = time.time() - t0
    t0 = time.time()
    sched.replay_ground_truth()
    t_replay = time.time() - t0
    wall = t_submit + t_finish + t_replay
    return {
        "arrivals": len(times),
        "wall_s": wall,
        "submit_s": t_submit,
        "finish_s": t_finish,
        "replay_s": t_replay,
        "arrivals_per_s": len(times) / wall,
    }


def _drain_baseline(name: str) -> tuple[float, str]:
    """arr/s BENCH_drain.json recorded for this scenario's identical drive
    with the pre-fused solver (fallback: the committed headline number)."""
    path = pathlib.Path(__file__).parent / "BENCH_drain.json"
    try:
        for r in json.loads(path.read_text())["rows"]:
            if r["scenario"] == name:
                return (float(r["loop"]["indexed"]["arrivals_per_s"]),
                        "BENCH_drain.json")
    except (OSError, KeyError, ValueError):
        pass
    return DRAIN_BASELINE_FALLBACK, "fallback"


def _end_to_end(smoke: bool, *, seed: int, repeat: int,
                verbose: bool) -> dict:
    name, arrivals, batch, load = SMOKE_E2E if smoke else FULL_E2E
    kw = dict(arrivals=arrivals, batch=batch, load=load, seed=seed)
    # Untimed warm-up over the identical stream (jit shapes), then the
    # best of ``repeat`` timed drives (same discipline as the other
    # benches — a single ~30 s session carries scheduler noise).
    _e2e_drive(name, **kw)
    fused = max((_e2e_drive(name, **kw) for _ in range(max(repeat, 1))),
                key=lambda r: r["arrivals_per_s"])
    out = {"scenario": name, "arrivals": arrivals, "batch": batch,
           "load": load, "fused": fused}
    if smoke:
        # Small enough to drive the reference solver directly — the smoke
        # speedup is self-contained rather than vs a recorded baseline.
        _e2e_drive(name, method="greedy_ref", **kw)
        ref = _e2e_drive(name, method="greedy_ref", **kw)
        out["ref"] = ref
        out["baseline_arr_per_s"] = ref["arrivals_per_s"]
        out["baseline_source"] = "greedy_ref (same drive)"
    else:
        base, src = _drain_baseline(name)
        out["baseline_arr_per_s"] = base
        out["baseline_source"] = src
    out["speedup"] = fused["arrivals_per_s"] / out["baseline_arr_per_s"]
    out["end_to_end_5x"] = bool(out["speedup"] >= E2E_TARGET_SPEEDUP)
    if verbose:
        print(f"  end-to-end {name}: {fused['arrivals_per_s']:7.2f} arr/s "
              f"(submit {fused['submit_s']:.1f}s) vs baseline "
              f"{out['baseline_arr_per_s']:.2f} arr/s "
              f"[{out['baseline_source']}]  {out['speedup']:5.2f}x  "
              f">=5x: {out['end_to_end_5x']}", flush=True)
    return out


def run(*, smoke: bool = False, seed: int = 5, repeat: int = 3,
        verbose: bool = True) -> dict:
    parity_cases = SMOKE_PARITY if smoke else FULL_PARITY
    parity = [_parity_case(n, j, seed=seed + i)
              for i, (n, j) in enumerate(parity_cases)]
    matches = all(r["ok"] for r in parity)
    if verbose:
        for r in parity:
            print(f"  parity {r['scenario']:24s} J={r['jobs']:2d}: "
                  f"fresh={r['fresh_ok']} queued={r['queued_ok']}",
                  flush=True)
    scale_name = "paper-small" if smoke else "us-backbone:lm"
    solve_rows = _solve_scaling(scale_name, SMOKE_JOBS if smoke else FULL_JOBS,
                                seed=seed, repeat=repeat, verbose=verbose)
    window_rows = _window_scaling(scale_name,
                                  SMOKE_WINDOWS if smoke else FULL_WINDOWS,
                                  jobs_per=2 if smoke else 8, seed=seed,
                                  repeat=repeat, verbose=verbose)
    e2e = _end_to_end(smoke, seed=seed, repeat=repeat, verbose=verbose)
    out = {
        "benchmark": "solver_fused",
        "smoke": smoke,
        "parity": parity,
        "fused_matches_ref": matches,
        "solve_scaling": solve_rows,
        "window_scaling": window_rows,
        "end_to_end": e2e,
        "end_to_end_5x": e2e["end_to_end_5x"],
    }
    if verbose:
        print(f"fused_matches_ref={matches} "
              f"end_to_end {e2e['speedup']:.2f}x "
              f"(target >= {E2E_TARGET_SPEEDUP:.0f}x on the full case)",
              flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small catalog + short end-to-end pair (the CI "
                         "bit-parity gate)")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_solver.json"))
    args = ap.parse_args()
    record = run(smoke=args.smoke, seed=args.seed, repeat=args.repeat)
    pathlib.Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"wrote {args.out}")
    if not record["fused_matches_ref"]:
        raise SystemExit("fused solver diverged bitwise from "
                         "greedy_route_ref")


if __name__ == "__main__":
    main()
