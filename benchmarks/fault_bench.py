"""Fault recovery: requeue / migrate / lost vs a clairvoyant oracle.

  PYTHONPATH=src python benchmarks/fault_bench.py [--smoke] [--out PATH]

Each scenario is driven through the serial online loop (exact drain +
ground-truth commit log) under a ``transient-node`` fault schedule — the
highest-capacity interior compute node fails mid-horizon and recovers
later — once per recovery policy:

  * ``requeue``  — stranded jobs re-planned onto the surviving topology,
    re-transferring from the node holding their last completed layer;
  * ``migrate``  — stranded jobs' remaining layers moved to one chosen
    node (the ``"migrate"`` solver's argmin placement);
  * ``lost``     — stranded work shed and accounted.

The baseline is a **clairvoyant oracle**: the identical arrival stream
solved against the post-failure topology from t=0.  It knows the victim
will fail, never places work there, and therefore pays zero disruption —
but it also forgoes the victim's capacity for the whole horizon (even
after recovery), so a good reactive policy can beat it outside the
outage.  ``p99_vs_oracle`` is each policy's actual-latency p99 ratio
against it — the price of *not* knowing the future under that policy.

``BENCH_fault.json`` records, per scenario x policy: completed / requeued
/ lost counts (lost by reason), p50/p99 actual latency, max backlog and
realized backlog growth, plus two boolean gates CI enforces via
``--smoke``:

  * ``replay_match`` — the exact drain's completion times and the
    piecewise commit-log replay agree to ``REPLAY_EPS_S`` through the
    whole failure/recovery sequence (the tentpole's ground-truth
    contract);
  * ``bounded`` (every policy, sub-capacity) — after the recovery event
    the backlog is under control: either the per-entry backlog trend from
    the first to the last post-recovery commit is negative
    (``post_recovery_drain_s_per_s < 0`` — a real queue, draining) or the
    final post-recovery backlog sits under one mean service time (no
    queue ever formed; sub-mean-service wobble is arrival noise, not
    growth).  Either way a transient outage must not tip a stable system
    into divergence.  (The half-over-half ``backlog_growth`` of the
    stability benches is reported but not gated here — a mid-horizon
    outage puts its peak wherever the fault lands, which makes that
    ratio noisy by construction.)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import numpy as np

SMOKE_CASES = [
    dict(name="edge-cloud", arrivals=32, load=0.85),
]
FULL_CASES = [
    dict(name="edge-cloud", arrivals=48, load=0.85),
    dict(name="edge-cloud:synthetic", arrivals=48, load=0.75),
    dict(name="paper-small", arrivals=48, load=0.75),
]

POLICIES = ("requeue", "migrate", "lost")
REPLAY_EPS_S = 1e-6          # absolute agreement bar for replay parity


def _parity(tr) -> tuple[bool, float]:
    """Exact-drain completions vs piecewise commit-log replay."""
    cc, rr = tr.completions or {}, tr.replay_completions or {}
    if set(cc) != set(rr):
        return False, float("inf")
    gap = max((abs(cc[n] - rr[n]) for n in cc), default=0.0)
    return gap <= REPLAY_EPS_S, gap


def _metrics(tr, recover_t: float | None = None) -> dict:
    s = tr.summary()
    act = tr.actual_latencies()
    match, gap = _parity(tr)
    lost_by: dict[str, int] = {}
    for _, why in tr.lost:
        lost_by[why] = lost_by.get(why, 0) + 1
    requeued = sum(1 for r in tr.records for n in r.names if "#r" in n)
    drain, final_post = None, None
    if recover_t is not None:
        post = [(r.time, r.backlog_after) for r in tr.records
                if r.time >= recover_t]
        if post:
            final_post = post[-1][1]
        if len(post) >= 2:
            (t0, b0), (t1, b1) = post[0], post[-1]
            drain = (b1 - b0) / max(t1 - t0, 1e-9)
    return {
        "completed": len(tr.completions or {}),
        "requeued": requeued,
        "lost": len(tr.lost),
        "lost_by_reason": lost_by,
        "p50_actual_s": float(np.percentile(act, 50)) if act.size else None,
        "p99_actual_s": float(np.percentile(act, 99)) if act.size else None,
        "max_backlog_s": s["max_backlog_s"],
        "backlog_growth": s["backlog_growth"],
        "post_recovery_drain_s_per_s": drain,
        "post_recovery_final_backlog_s": final_post,
        "replay_match": match,
        "replay_gap_s": gap,
    }


def _drive(name: str, *, horizon: float, rate: float, seed: int,
           fault_schedule, recovery: str = "requeue"):
    """One fresh serial online session (identical rng => identical jobs)."""
    from repro.scenarios import make_scenario
    from repro.serving.online import run_online

    return run_online(make_scenario(name, seed=0), horizon=horizon,
                      rate=rate, seed=seed, drain="exact",
                      track_commits=True, finish=True,
                      fault_schedule=fault_schedule, recovery=recovery)


def _bench_case(case: dict, *, seed: int, verbose: bool) -> dict:
    from repro.scenarios import make_scenario
    from repro.serving import faults as F

    name, arrivals, load = case["name"], case["arrivals"], case["load"]
    sc = make_scenario(name, seed=0)
    rate = sc.nominal_rate(load)
    horizon = arrivals / rate
    schedule = F.make_fault_schedule("transient-node", sc, horizon,
                                     seed=seed)
    victim = schedule.events[0].node
    recover_t = max(e.time for e in schedule)

    # Clairvoyant oracle: victim down from t=0 — it avoids the node
    # entirely, so no work is ever stranded and no policy runs.
    oracle_tr = _drive(name, horizon=horizon, rate=rate, seed=seed,
                       fault_schedule=F.FaultSchedule(
                           (F.node_fail(0.0, victim),)), recovery="lost")
    oracle = _metrics(oracle_tr)

    rows = {}
    for policy in POLICIES:
        tr = _drive(name, horizon=horizon, rate=rate, seed=seed,
                    fault_schedule=schedule, recovery=policy)
        m = _metrics(tr, recover_t)
        if oracle["p99_actual_s"] and m["p99_actual_s"] is not None:
            m["p99_vs_oracle"] = m["p99_actual_s"] / oracle["p99_actual_s"]
        rows[policy] = m
        if verbose:
            print(f"  {policy:8s} done={m['completed']:3d} "
                  f"requeued={m['requeued']} lost={m['lost']} "
                  f"p99={m['p99_actual_s']:.2f}s "
                  f"(x{m.get('p99_vs_oracle', float('nan')):.2f} oracle) "
                  f"drain={m['post_recovery_drain_s_per_s']} "
                  f"replay={m['replay_match']}", flush=True)

    mean_service_s = load / rate

    def _ok(r: dict) -> bool:
        drain, final = (r["post_recovery_drain_s_per_s"],
                        r["post_recovery_final_backlog_s"])
        if drain is not None and drain < 0:
            return True          # a real queue, draining post-recovery
        return final is not None and final <= mean_service_s

    sub_capacity = load < 1.0
    bounded = all(_ok(r) for r in rows.values()) if sub_capacity else True
    out = {
        "scenario": name,
        "arrivals": arrivals,
        "load": load,
        "rate_per_s": rate,
        "horizon_s": horizon,
        "victim": int(victim),
        "fault_events": [(e.time, e.kind, e.node) for e in schedule],
        "oracle": oracle,
        "policies": rows,
        "all_replay_match": (oracle["replay_match"]
                             and all(r["replay_match"]
                                     for r in rows.values())),
        "requeue_bounded": bounded,
    }
    if verbose:
        print(f"{name:24s} oracle p99={oracle['p99_actual_s']:.2f}s "
              f"replay={out['all_replay_match']} "
              f"bounded={out['requeue_bounded']}", flush=True)
    return out


def run(*, smoke: bool = False, seed: int = 7,
        verbose: bool = True) -> dict:
    cases = SMOKE_CASES if smoke else FULL_CASES
    rows = [_bench_case(case, seed=seed, verbose=verbose)
            for case in cases]
    out = {
        "benchmark": "fault",
        "smoke": smoke,
        "replay_eps_s": REPLAY_EPS_S,
        "rows": rows,
        "all_replay_match": all(r["all_replay_match"] for r in rows),
        "all_requeue_bounded": all(r["requeue_bounded"] for r in rows),
    }
    if verbose:
        print(f"all_replay_match={out['all_replay_match']} "
              f"all_requeue_bounded={out['all_requeue_bounded']}",
              flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 small scenario (the CI gate: replay parity + "
                         "requeue bounded backlog)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_fault.json"))
    args = ap.parse_args()
    record = run(smoke=args.smoke, seed=args.seed)
    pathlib.Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"wrote {args.out}")
    if not record["all_replay_match"]:
        raise SystemExit("piecewise replay diverged from the exact drain "
                         "through a failure/recovery sequence")
    if not record["all_requeue_bounded"]:
        raise SystemExit("requeue backlog not bounded after a transient "
                         "failure at sub-capacity load")


if __name__ == "__main__":
    main()
