"""All registered solvers on one instance through the unified entry point.

One row per method: fictitious bound, simulated makespan, solve time —
greedy vs lazy must agree on the bound (same algorithm, different schedule
of routing calls), SA refines it, and the exact oracle (tiny instance only)
lower-bounds everything.
"""
from __future__ import annotations

import numpy as np

from repro.core import jobs as J, network as N, solve, solvers
from repro.configs import registry

_SA_OPTS = dict(seed=0, d=0.99, num_chains=2, block_move_prob=0.3)


def _instance():
    net, _ = N.small_topology(capacity_scale=1e-3)
    rng = np.random.default_rng(0)
    jobs = []
    for i, kind in enumerate(["vgg19"] + ["resnet34"] * 2):
        src, dst = rng.choice(5, size=2, replace=False)
        jobs.append(registry.get(kind).make_job(f"{kind}-{i}",
                                                int(src), int(dst)))
    return net, J.batch_jobs(jobs)


def run(verbose: bool = True) -> list[dict]:
    net, batch = _instance()
    rows = []
    for method in solvers.available():
        opts = _SA_OPTS if method == "sa" else {}
        plan = solve(net, batch, method=method, **opts)
        sim = plan.simulate(net, batch)
        rows.append(dict(method=method, bound=plan.bound(),
                         sim=sim.makespan, solve_s=plan.meta["solve_s"]))
        if verbose:
            print(f"  {method:8s} bound {plan.bound():8.3f}s "
                  f"sim {sim.makespan:8.3f}s "
                  f"({plan.meta['solve_s']:6.2f}s to solve)", flush=True)
    by = {r["method"]: r for r in rows}
    assert abs(by["greedy"]["bound"] - by["lazy"]["bound"]) \
        <= 1e-6 * by["greedy"]["bound"]
    assert by["exact"]["bound"] <= by["greedy"]["bound"] * (1 + 1e-6)
    return rows
