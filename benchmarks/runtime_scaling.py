"""Algorithm-runtime scaling (§V text): greedy is near-instant, SA scales
poorly with network size.  Synthetic random-regular topologies."""
from __future__ import annotations

import time

import numpy as np

from repro.core import jobs as J, network as N, solve


def synthetic_network(v: int, seed: int) -> N.ComputeNetwork:
    rng = np.random.default_rng(seed)
    edges = [(i, (i + 1) % v, float(rng.uniform(1e8, 4e8))) for i in range(v)]
    for _ in range(v):
        a, b = rng.choice(v, 2, replace=False)
        edges.append((int(a), int(b), float(rng.uniform(1e8, 4e8))))
    caps = rng.choice([30, 50, 70, 100, 200], v) * 1e9
    return N.make_network(v, edges, caps.astype(float))


def jobs_for(v: int, j: int, seed: int) -> list:
    rng = np.random.default_rng(seed + 1)
    out = []
    for i in range(j):
        s, d = rng.choice(v, 2, replace=False)
        out.append(J.synthetic_job(f"s{i}", int(s), int(d), num_layers=24,
                                   seed=seed + i, flops_scale=3e9,
                                   bytes_scale=3e6))
    return out


def run(verbose: bool = True, sizes=(8, 24, 48)) -> list[dict]:
    rows = []
    for v in sizes:
        net = synthetic_network(v, 0)
        batch = J.batch_jobs(jobs_for(v, 10, 0))
        t0 = time.time()
        sol = solve(net, batch, method="greedy")
        g_first = time.time() - t0          # includes jit for this shape
        g_warm = solve(net, batch, method="greedy").meta["solve_s"]
        solve(net, batch, method="lazy")    # warm the lazy kernels
        lazy_sol = solve(net, batch, method="lazy")
        g_lazy = lazy_sol.meta["solve_s"]
        sa_t = solve(net, batch, method="sa", seed=0, d=0.99,
                     num_chains=1).meta["solve_s"]
        rows.append(dict(V=v, greedy_cold_s=g_first, greedy_warm_s=g_warm,
                         greedy_lazy_s=g_lazy,
                         lazy_routings=lazy_sol.meta.get("n_routings", -1),
                         sa_s=sa_t, bound=sol.bound()))
        if verbose:
            print(f"  V={v:4d}: greedy {g_warm:7.3f}s (cold {g_first:6.1f}s) "
                  f"lazy {g_lazy:7.3f}s "
                  f"({rows[-1]['lazy_routings']} routings vs 100) "
                  f"sa(690 iters) {sa_t:7.1f}s", flush=True)
    return rows
