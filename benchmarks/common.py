"""Shared benchmark utilities + the paper's job mixes."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import registry
from repro.core import jobs as J


def timed(fn, *args, repeat: int = 1, **kw):
    fn(*args, **kw)  # warm (jit)
    t0 = time.time()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.time() - t0) / repeat


# Pre-change reference: greedy bounds/order on examples/quickstart.py's
# instance (small_topology(1e-3), 2 VGG19 + 6 ResNet34, rng(0)), captured
# from the seed solver.  Every refactor of the static path must reproduce
# these bit-for-bit (closure_bench + online_bench gate on them).
QUICKSTART_BOUNDS = [
    0.9737289547920227, 2.1123697757720947, 0.7822328209877014,
    0.17777971923351288, 0.17777971923351288, 0.334226131439209,
    0.25363287329673767, 0.5179324150085449,
]
QUICKSTART_ORDER = [3, 4, 6, 5, 7, 2, 0, 1]


# Pre-ledger reference: the default (fluid-drain) online trajectory on
# paper-small — run_online(make_scenario("paper-small", seed=0),
# horizon=24/rate, seed=7, rate=nominal_rate(0.6)) — captured before the
# committed-work ledger landed.  The fluid path must stay bit-identical
# (the exact drain is opt-in); online_bench's fidelity section gates on it
# as ``fluid_matches_seed``.
FLUID_GOLD_SCENARIO = "paper-small"
FLUID_GOLD_LOAD = 0.6
FLUID_GOLD_ARRIVALS = 24
FLUID_GOLD_SEED = 7
FLUID_GOLD_BACKLOGS = [
    0.03644493898639235, 0.03644493898639235, 0.03644493898639235,
    0.19632062866064648, 0.19005575557234186, 0.19632062866064648,
    0.23074857432573082, 0.03644493898639235, 0.03644493898639235,
    0.03644493898639235, 0.19632062866064648, 0.16821560664505564,
    0.03644493898639235, 0.19632062866064648, 0.19632062866064648,
    0.1868424844665341, 0.03644493898639235, 0.03644493898639235,
    0.03644493898639235, 0.03644493898639235, 0.05736757877488801,
    0.24127095278122912, 0.03644493898639235, 0.03644493898639235,
]
FLUID_GOLD_LATENCIES = [
    0.07911159098148346, 0.07911159098148346, 0.07911159098148346,
    0.2389872968196869, 0.23272264003753662, 0.2389872968196869,
    0.2840821146965027, 0.07911159098148346, 0.07911159098148346,
    0.07911159098148346, 0.2389872968196869, 0.21088248491287231,
    0.07911159098148346, 0.2389872968196869, 0.2389872968196869,
    0.2295093536376953, 0.07911159098148346, 0.07911159098148346,
    0.07911159098148346, 0.07911159098148346, 0.11070089042186737,
    0.2879980802536011, 0.07911159098148346, 0.07911159098148346,
]


def quickstart_instance():
    """(net, batch) of the quickstart reference instance."""
    from repro.core import network as N

    net, _ = N.small_topology(capacity_scale=1e-3)
    return net, J.batch_jobs(paper_jobs_small(seed=0))


def paper_jobs_small(seed: int) -> list:
    """§V small topology: 2 VGG19 + 6 ResNet34, random src-dst."""
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(2):
        s, d = rng.choice(5, 2, replace=False)
        jobs.append(registry.get("vgg19").make_job(f"v{i}", int(s), int(d)))
    for i in range(6):
        s, d = rng.choice(5, 2, replace=False)
        jobs.append(registry.get("resnet34").make_job(f"r{i}", int(s), int(d)))
    return jobs


def paper_jobs_large(seed: int) -> list:
    """§V US backbone: 6 VGG19 + 2 ResNet34 + 2 hand-made models."""
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(6):
        s, d = rng.choice(24, 2, replace=False)
        jobs.append(registry.get("vgg19").make_job(f"v{i}", int(s), int(d)))
    for i in range(2):
        s, d = rng.choice(24, 2, replace=False)
        jobs.append(registry.get("resnet34").make_job(f"r{i}", int(s), int(d)))
    for i in range(2):
        s, d = rng.choice(24, 2, replace=False)
        jobs.append(J.synthetic_job(f"syn{i}", int(s), int(d), num_layers=24,
                                    seed=seed + i, flops_scale=3e9,
                                    bytes_scale=3e6))
    return jobs
