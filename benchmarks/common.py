"""Shared benchmark utilities + the paper's job mixes."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import registry
from repro.core import jobs as J


def timed(fn, *args, repeat: int = 1, **kw):
    fn(*args, **kw)  # warm (jit)
    t0 = time.time()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.time() - t0) / repeat


def paper_jobs_small(seed: int) -> list:
    """§V small topology: 2 VGG19 + 6 ResNet34, random src-dst."""
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(2):
        s, d = rng.choice(5, 2, replace=False)
        jobs.append(registry.get("vgg19").make_job(f"v{i}", int(s), int(d)))
    for i in range(6):
        s, d = rng.choice(5, 2, replace=False)
        jobs.append(registry.get("resnet34").make_job(f"r{i}", int(s), int(d)))
    return jobs


def paper_jobs_large(seed: int) -> list:
    """§V US backbone: 6 VGG19 + 2 ResNet34 + 2 hand-made models."""
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(6):
        s, d = rng.choice(24, 2, replace=False)
        jobs.append(registry.get("vgg19").make_job(f"v{i}", int(s), int(d)))
    for i in range(2):
        s, d = rng.choice(24, 2, replace=False)
        jobs.append(registry.get("resnet34").make_job(f"r{i}", int(s), int(d)))
    for i in range(2):
        s, d = rng.choice(24, 2, replace=False)
        jobs.append(J.synthetic_job(f"syn{i}", int(s), int(d), num_layers=24,
                                    seed=seed + i, flops_scale=3e9,
                                    bytes_scale=3e6))
    return jobs
