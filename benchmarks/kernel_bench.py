"""Min-plus closure micro-benchmark (the routing hot-spot).

Wall-clock numbers are CPU (the Pallas kernel runs in interpret mode on CPU
and is validated for semantics, not speed); ``derived`` projects the TPU
kernel time from the roofline model in DESIGN.md §3.3: the (min,+)
contraction is VPU work at ~1 op/lane/cycle.  v5e VPU: 8 lanes x 128 sublanes
x 4 MXU-adjacent ALUs ~ 4 TOP/s fp32; closure of a V-node graph needs
ceil(log2 V) squarings of 2*V^3 ops each.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

VPU_OPS = 4e12
HBM_BW = 819e9


def run(verbose: bool = True, sizes=(64, 128, 256, 512)) -> list[dict]:
    rows = []
    for v in sizes:
        w = jnp.where(jax.random.uniform(jax.random.PRNGKey(0), (v, v)) < 0.2,
                      jax.random.uniform(jax.random.PRNGKey(1), (v, v)) * 5,
                      jnp.float32(1e30))
        closure = jax.jit(lambda x: ops.minplus_closure(x, use_pallas=False))
        closure(w).block_until_ready()
        t0 = time.time()
        n_rep = 3
        for _ in range(n_rep):
            closure(w).block_until_ready()
        cpu_s = (time.time() - t0) / n_rep
        squarings = max(1, (v - 1).bit_length())
        ops_total = squarings * 2 * v ** 3
        bytes_total = squarings * 3 * v * v * 4
        tpu_proj = max(ops_total / VPU_OPS, bytes_total / HBM_BW)
        rows.append(dict(V=v, cpu_s=cpu_s, tpu_projected_s=tpu_proj,
                         ops=ops_total))
        if verbose:
            print(f"  V={v:4d}: cpu {cpu_s*1e3:8.2f} ms   "
                  f"tpu-roofline {tpu_proj*1e6:8.1f} us "
                  f"({ops_total/1e9:.2f} Gop)")
    return rows
