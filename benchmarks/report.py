"""Generate the final EXPERIMENTS.md tables from the results JSONs.

  PYTHONPATH=src python -m benchmarks.report   # rewrites the tail of
                                               # EXPERIMENTS.md in place
"""
from __future__ import annotations

import json
import pathlib

from .roofline import row_terms

MARK = "<!-- TABLES -->"


def _fmt(x, digits=3):
    if isinstance(x, float):
        return f"{x:.{digits}g}"
    return str(x)


def roofline_table(dirpath: str, mesh_filter: str) -> str:
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) |"
        " dominant | roofline frac | useful ratio | temp GB/dev | src |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for path in sorted(pathlib.Path(dirpath).glob("*.json")):
        rec = json.loads(path.read_text())
        if mesh_filter not in path.stem:
            continue
        t = row_terms(rec)
        if t.get("status") != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"SKIP | — | — | — | {str(t.get('reason'))[:70]} |")
            continue
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {_fmt(t['compute_s'])} | "
            f"{_fmt(t['memory_s'])} | {_fmt(t['collective_s'])} | "
            f"{t['dominant']} | {_fmt(t['roofline_fraction'])} | "
            f"{_fmt(t['useful_ratio'])} | {_fmt(t['temp_gb'], 4)} | "
            f"{t['flops_source']} |")
    return "\n".join(lines)


def hillclimb_table() -> str:
    cells = {
        "olmoe_1b_7b.train_4k": "results/dryrun_baseline/olmoe_1b_7b.train_4k.single.json",
        "gemma3_1b.decode_32k": "results/dryrun_baseline/gemma3_1b.decode_32k.single.json",
        "deepseek_v2_236b.train_4k": "results/dryrun_baseline/deepseek_v2_236b.train_4k.single.json",
    }
    lines = [
        "| cell | iteration | t_compute | t_memory | t_collective | "
        "step bound (s) | temp GB/dev | verdict |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cell, basepath in cells.items():
        recs = [("baseline (paper-faithful)", json.loads(
            pathlib.Path(basepath).read_text()))]
        for p in sorted(pathlib.Path("results/hillclimb").glob(f"{cell}.*.json")):
            recs.append((p.stem.split(".")[-1], json.loads(p.read_text())))
        prev_bound = None
        for tag, rec in recs:
            t = row_terms(rec)
            if t.get("status") != "ok":
                lines.append(f"| {cell} | {tag} | — | — | — | FAIL | — | "
                             f"{str(rec.get('error'))[:60]} |")
                continue
            bound = t["step_bound_s"]
            verdict = ""
            if prev_bound is not None:
                verdict = ("improved "
                           f"{prev_bound / bound:.2f}x" if bound < prev_bound
                           else f"regressed {bound / prev_bound:.2f}x")
            if tag == "it1_ep_shard":
                verdict += " (hypothesis refuted; reverted)"
            prev_bound = min(bound, prev_bound) if prev_bound else bound
            lines.append(
                f"| {cell} | {tag} | {_fmt(t['compute_s'])} | "
                f"{_fmt(t['memory_s'])} | {_fmt(t['collective_s'])} | "
                f"{_fmt(bound)} | {_fmt(t['temp_gb'], 4)} | {verdict} |")
    return "\n".join(lines)


def compile_stats(dirpath: str) -> str:
    tot = {"single": [0, 0.0], "multi": [0, 0.0]}
    fails = []
    for path in pathlib.Path(dirpath).glob("*.json"):
        rec = json.loads(path.read_text())
        mesh = "multi" if path.stem.endswith("multi") else "single"
        if rec["status"] == "ok":
            tot[mesh][0] += 1
            tot[mesh][1] += rec["compile_s"]
        elif rec["status"] == "fail":
            fails.append(path.stem)
    out = [f"* single-pod (16,16): {tot['single'][0]} cells compiled "
           f"({tot['single'][1]:.0f}s total compile)",
           f"* multi-pod (2,16,16): {tot['multi'][0]} cells compiled "
           f"({tot['multi'][1]:.0f}s total compile)"]
    out.append(f"* failures: {fails if fails else 'none'}")
    return "\n".join(out)


def main():
    md = pathlib.Path("EXPERIMENTS.md")
    text = md.read_text().split(MARK)[0] + MARK + "\n\n"
    text += "### Dry-run compile summary\n\n"
    text += compile_stats("results/dryrun_baseline") + "\n\n"
    text += "### Roofline — single-pod baseline (all 40 cells)\n\n"
    text += roofline_table("results/dryrun_baseline", "single") + "\n\n"
    text += "### Roofline — multi-pod (2 x 16 x 16)\n\n"
    text += roofline_table("results/dryrun_baseline", "multi") + "\n\n"
    text += "### §Perf hillclimb — before/after\n\n"
    text += hillclimb_table() + "\n"
    md.write_text(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
