"""Deadline-aware admission & SLO-guarded auto re-planning, A/B'd.

  PYTHONPATH=src python benchmarks/admission_bench.py [--smoke] [--out PATH]

Two experiments, both driven by the exact-drain fork
(:func:`repro.core.completions.predict_completions`):

  * **Admission sweep** — per scenario x offered load, the identical
    arrival stream (every request carrying a relative deadline) is run
    under each admission policy: ``admit_all`` (baseline, no gating),
    ``reject`` (predicted misses shed on arrival) and ``defer``
    (predicted misses parked and re-assessed until they expire).
    ``BENCH_admission.json`` records SLO-miss rate, goodput (met
    deadlines) and shed counts per cell.  At every overload point
    (load >= ``OVERLOAD``) CI gates that *each* gated policy beats
    admit-all: strictly lower SLO-miss rate at equal-or-better goodput —
    the point of predictive admission is refusing work you'd have missed
    anyway, not refusing goodput.
  * **Re-planning under faults** — per fault family, the same faulted
    stream runs with re-planning off (``none``), with the hysteresis
    monitor (``auto``: threshold + cooldown + exponential backoff +
    budget), and eagerly (``eager``: threshold 0, no cooldown — the
    replan-on-every-observation strawman); a clairvoyant **oracle**
    (degraded topology known from t=0) anchors the latency scale.  CI
    gates that ``auto`` stays within its trigger budget and never
    re-plans more often than ``eager``.

Both experiments are only meaningful if the fork is honest, so the run
opens with a **prediction-exactness gate**: on every benchmarked
scenario, predictions taken at a queued mid-run state must match the
completions the live drain then realizes to ``EXACT_RTOL`` — if that
fails the whole benchmark exits non-zero before reporting numbers.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

import numpy as np

POLICIES = ("admit_all", "reject", "defer")
OVERLOAD = 1.5               # loads >= this must show the admission win
EXACT_RTOL = 1e-9            # fork honesty bar (the tentpole invariant)
DEADLINE_FACTOR = 1.2        # SLO = factor x mean service time

SMOKE_CASES = [
    dict(name="paper-small", arrivals=20, loads=(1.75,), batch=2),
]
FULL_CASES = [
    dict(name="paper-small", arrivals=32, loads=(0.8, 1.75, 2.5), batch=2),
    dict(name="edge-cloud", arrivals=32, loads=(1.75,), batch=2),
]

SMOKE_FAMILIES = ("transient-node",)
FULL_FAMILIES = ("transient-node", "elastic", "cascade")
REPLAN_BUDGET = 4


# -- gate 0: the fork is honest ----------------------------------------------

def _prediction_gap(name: str, *, windows: int = 3, batch: int = 2) -> float:
    """Worst relative gap between a queued-state prediction and the
    realized completions on one scenario (the test_predict invariant,
    re-checked in situ on the benchmark's own catalog)."""
    from repro.core import completions as C
    from repro.scenarios import make_scenario
    from repro.serving.online import OnlineScheduler

    sc = make_scenario(name, seed=0)
    rng = np.random.default_rng(13)
    sched = OnlineScheduler(sc.topology, drain="exact")
    t = 0.0
    for _ in range(windows):
        sched.submit_jobs(t, sc.sample_jobs(rng, batch),
                          pad_to=sc.max_layers)
        t += 0.05
    preds = C.predict_completions(sched._effective_topology(), sched.ledger)
    realized = sched.finish()
    gap = 0.0
    for name_, t_done in realized.items():
        denom = max(abs(t_done), 1e-12)
        gap = max(gap, abs(preds[name_] - t_done) / denom)
    return gap


# -- experiment 1: admission sweep -------------------------------------------

def _admission_cell(sc, *, load: float, arrivals: int, batch: int,
                    policy: str, seed: int) -> dict:
    from repro.serving.online import run_online

    rate = sc.nominal_rate(load)
    tr = run_online(sc, horizon=arrivals / (rate * batch), seed=seed,
                    rate=rate * batch, batch_size=batch, drain="exact",
                    finish=True, admission=policy,
                    deadline_s=DEADLINE_FACTOR * sc.mean_service_s)
    s = tr.summary()
    slo = s["slo"]
    return {
        "policy": policy,
        "slo_miss_rate": slo.get("slo_miss_rate"),
        "goodput": slo["goodput"],
        "offered": slo["offered"],
        "met": slo["met"],
        "late": slo["late"],
        "shed_by_reason": s.get("shed_by_reason", {}),
        "admission": s.get("admission", {}),
    }


def _admission_case(case: dict, *, seed: int, verbose: bool) -> dict:
    from repro.scenarios import make_scenario

    sc = make_scenario(case["name"], seed=0)
    points = []
    for load in case["loads"]:
        cells = {p: _admission_cell(sc, load=load,
                                    arrivals=case["arrivals"],
                                    batch=case["batch"], policy=p,
                                    seed=seed) for p in POLICIES}
        base = cells["admit_all"]
        gated_wins = True
        if load >= OVERLOAD:
            for p in ("reject", "defer"):
                g = cells[p]
                gated_wins &= (g["slo_miss_rate"] < base["slo_miss_rate"]
                               and g["goodput"] >= base["goodput"])
        points.append({"load": load, "cells": cells,
                       "overload": load >= OVERLOAD,
                       "gated_beats_admit_all": gated_wins})
        if verbose:
            row = " ".join(
                f"{p}:miss={cells[p]['slo_miss_rate']:.2f}/"
                f"good={cells[p]['goodput']}" for p in POLICIES)
            print(f"  {case['name']:12s} load={load:<4} {row} "
                  f"win={gated_wins}", flush=True)
    return {"scenario": case["name"], "arrivals": case["arrivals"],
            "deadline_factor": DEADLINE_FACTOR, "points": points}


# -- experiment 2: auto-replan vs eager vs oracle under faults ----------------

def _replan_arm(sc, *, schedule, load: float, arrivals: int, seed: int,
                auto_replan) -> dict:
    from repro.serving.online import run_online

    rate = sc.nominal_rate(load)
    tr = run_online(sc, horizon=arrivals / rate, seed=seed, rate=rate,
                    batch_size=2, drain="exact", finish=True,
                    fault_schedule=schedule, auto_replan=auto_replan)
    s = tr.summary()
    act = tr.actual_latencies()
    return {
        "p50_actual_s": float(np.percentile(act, 50)) if act.size else None,
        "p99_actual_s": float(np.percentile(act, 99)) if act.size else None,
        "max_backlog_s": s["max_backlog_s"],
        "replans": s.get("replans", 0),
        "triggers": s.get("auto_replan_triggers", 0),
        "skipped": s.get("replans_skipped", {}),
    }


def _replan_case(family: str, *, seed: int, verbose: bool) -> dict:
    from repro.scenarios import make_scenario
    from repro.serving import faults as F
    from repro.serving.admission import ReplanPolicy

    sc = make_scenario("paper-small", seed=0)
    load, arrivals = 1.5, 20
    horizon = arrivals / sc.nominal_rate(load)
    schedule = F.make_fault_schedule(family, sc, horizon, seed=seed)
    auto_policy = ReplanPolicy(threshold=0.15, cooldown_s=horizon / 20,
                               backoff=2.0, budget=REPLAN_BUDGET,
                               min_improvement=0.02)
    eager_policy = ReplanPolicy(threshold=0.0, cooldown_s=0.0)

    arms = {
        "none": _replan_arm(sc, schedule=schedule, load=load,
                            arrivals=arrivals, seed=seed, auto_replan=None),
        "auto": _replan_arm(sc, schedule=schedule, load=load,
                            arrivals=arrivals, seed=seed,
                            auto_replan=auto_policy),
        "eager": _replan_arm(sc, schedule=schedule, load=load,
                             arrivals=arrivals, seed=seed,
                             auto_replan=eager_policy),
    }
    # Clairvoyant anchor: the first failed resource is down from t=0 (no
    # disruption ever) — only meaningful for families that fail something.
    fails = [e for e in schedule if e.kind in ("node_fail", "link_fail")]
    if fails:
        first = fails[0]
        ev = (F.node_fail(0.0, first.node) if first.kind == "node_fail"
              else F.FaultEvent(0.0, "link_fail", link=first.link))
        arms["oracle"] = _replan_arm(sc, schedule=F.FaultSchedule((ev,)),
                                     load=load, arrivals=arrivals,
                                     seed=seed, auto_replan=None)
    bounded = arms["auto"]["triggers"] <= REPLAN_BUDGET
    no_thrash = arms["auto"]["triggers"] <= max(arms["eager"]["triggers"],
                                                REPLAN_BUDGET)
    if verbose:
        row = " ".join(f"{k}:p99={v['p99_actual_s']:.2f}s/"
                       f"replans={v['replans']}" for k, v in arms.items())
        print(f"  {family:16s} {row} bounded={bounded}", flush=True)
    return {"family": family, "load": load, "arrivals": arrivals,
            "budget": REPLAN_BUDGET,
            "fault_events": [(e.time, e.kind, e.node) for e in schedule],
            "arms": arms, "auto_bounded": bounded,
            "auto_no_thrash": no_thrash}


def run(*, smoke: bool = False, seed: int = 7, verbose: bool = True) -> dict:
    cases = SMOKE_CASES if smoke else FULL_CASES
    families = SMOKE_FAMILIES if smoke else FULL_FAMILIES

    gaps = {c["name"]: _prediction_gap(c["name"]) for c in cases}
    exact = all(g <= EXACT_RTOL for g in gaps.values())
    if verbose:
        print(f"prediction exactness: worst={max(gaps.values()):.2e} "
              f"(rtol {EXACT_RTOL:g}) ok={exact}", flush=True)

    admission = [_admission_case(c, seed=seed, verbose=verbose)
                 for c in cases]
    replan = [_replan_case(f, seed=seed, verbose=verbose) for f in families]

    out = {
        "benchmark": "admission",
        "smoke": smoke,
        "exactness_rtol": EXACT_RTOL,
        "prediction_gaps": gaps,
        "prediction_exact": exact,
        "overload_threshold": OVERLOAD,
        "admission": admission,
        "replan": replan,
        "all_overload_wins": all(
            p["gated_beats_admit_all"]
            for c in admission for p in c["points"] if p["overload"]),
        "all_replan_bounded": all(r["auto_bounded"] and r["auto_no_thrash"]
                                  for r in replan),
    }
    if verbose:
        print(f"prediction_exact={out['prediction_exact']} "
              f"all_overload_wins={out['all_overload_wins']} "
              f"all_replan_bounded={out['all_replan_bounded']}", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 scenario, 1 overload point, 1 fault family "
                         "(the CI gate: exact fork + admission win + "
                         "bounded replans)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_admission.json"))
    args = ap.parse_args()
    record = run(smoke=args.smoke, seed=args.seed)
    pathlib.Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"wrote {args.out}")
    if not record["prediction_exact"]:
        raise SystemExit("what-if fork diverged from the realized drain — "
                         "admission numbers would be meaningless")
    if not record["all_overload_wins"]:
        raise SystemExit("a gated admission policy failed to beat admit-all "
                         "under overload (lower miss at >= goodput)")
    if not record["all_replan_bounded"]:
        raise SystemExit("auto re-planning exceeded its trigger budget or "
                         "out-replanned the eager strawman")


if __name__ == "__main__":
    main()
