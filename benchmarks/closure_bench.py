"""Closure-pipeline benchmark: oracle vs Pallas kernels vs closure reuse.

The routing hot-spot is the batched ``[L+1, V, V]`` min-plus transfer
closure.  This benchmark measures, per (V, L):

  * ``oracle_s``        — pure-jnp broadcast closure of the full stack,
  * ``pallas_2d_s``     — the seed's best kernel path: one 2-D Pallas
                          closure per layer slice (a Python loop over L+1),
  * ``pallas_batched_s``— the batched Pallas kernel (leading batch grid
                          dimension, one call for the whole stack),

and, on the paper's small-topology instance:

  * greedy wall-clock with and without round-level closure reuse
    (``share_closures=True`` vs the seed's rebuild-per-call behavior) plus
    the host-level closure-build count of the reuse path,
  * greedy/lazy bounds on the quickstart instance, recorded so the perf
    trajectory carries its own bit-identity check against the seed solver.

Writes ``BENCH_closure.json`` next to this file (or ``--out``).  ``--smoke``
runs tiny shapes with the kernels forced on (interpret mode on CPU) — the CI
regression gate.  Full sizes are sized for real accelerators; on CPU the
interpret-mode kernel paths are semantic-only and slow.

    PYTHONPATH=src python benchmarks/closure_bench.py --smoke
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))          # the benchmarks package itself
sys.path.insert(0, str(_ROOT / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

# Pre-change quickstart reference (shared with online_bench's
# static-identity gate): see benchmarks/common.py.
from benchmarks.common import QUICKSTART_BOUNDS, QUICKSTART_ORDER


# v5e roofline constants (same convention as kernel_bench.py): the (min,+)
# contraction is VPU work; the broadcast oracle materializes the [V, V, V]
# intermediate and is HBM-bound, the tiled kernel keeps it in VMEM and is
# compute-bound.
VPU_OPS = 4e12
HBM_BW = 819e9


def _roofline(v: int, layers: int) -> dict:
    b = layers + 1
    squarings = max(1, (v - 1).bit_length())
    ops_total = squarings * b * 2 * v ** 3
    kernel_bytes = squarings * b * 3 * v * v * 4
    oracle_bytes = squarings * b * (v ** 3 + 3 * v * v) * 4
    kernel_s = max(ops_total / VPU_OPS, kernel_bytes / HBM_BW)
    oracle_s = max(ops_total / VPU_OPS, oracle_bytes / HBM_BW)
    return dict(tpu_projected_oracle_s=oracle_s,
                tpu_projected_kernel_s=kernel_s,
                tpu_projected_speedup=oracle_s / kernel_s)


def _rand_stack(v: int, layers: int, seed: int = 0) -> jax.Array:
    """INF-sparse random [L+1, V, V] edge-weight stack."""
    rng = np.random.default_rng(seed)
    w = np.where(rng.random((layers + 1, v, v)) < 0.25,
                 rng.uniform(0.1, 5.0, (layers + 1, v, v)), 1e30)
    return jnp.asarray(w, jnp.float32)


def _time(fn, repeat: int = 3) -> float:
    fn()  # warm (jit/trace)
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat


def bench_kernels(sizes, layer_counts, *, force_pallas: bool,
                  repeat: int, verbose: bool) -> list[dict]:
    rows = []
    use_pallas = True if force_pallas else None
    for v in sizes:
        for L in layer_counts:
            w = _rand_stack(v, L)
            # minplus_closure is already jitted (static use_pallas).
            oracle_s = _time(
                lambda: ops.minplus_closure(w, use_pallas=False)
                .block_until_ready(), repeat)

            def per_slice():
                out = [ops.minplus_closure(w[l], use_pallas=use_pallas)
                       for l in range(L + 1)]
                jax.block_until_ready(out)
            pallas_2d_s = _time(per_slice, repeat)

            pallas_batched_s = _time(
                lambda: ops.minplus_closure(w, use_pallas=use_pallas)
                .block_until_ready(), repeat)

            row = dict(
                V=v, L=L,
                dispatch=ops.minplus_dispatch((L + 1, v, v),
                                              use_pallas=use_pallas),
                oracle_s=oracle_s, pallas_2d_s=pallas_2d_s,
                pallas_batched_s=pallas_batched_s,
                batched_speedup_vs_oracle=oracle_s / pallas_batched_s,
                batched_speedup_vs_2d=pallas_2d_s / pallas_batched_s,
                **_roofline(v, L),
            )
            rows.append(row)
            if verbose:
                print(f"  V={v:4d} L={L:3d} [{row['dispatch']:14s}] "
                      f"oracle {oracle_s*1e3:9.2f} ms  "
                      f"2d {pallas_2d_s*1e3:9.2f} ms  "
                      f"batched {pallas_batched_s*1e3:9.2f} ms")
    return rows


def bench_greedy_reuse(*, repeat: int, verbose: bool) -> dict:
    from repro.core import greedy, jobs as J, network as N, shortest_path as SP
    from benchmarks.common import paper_jobs_small

    net, _ = N.small_topology(capacity_scale=1e-3)
    batch = J.batch_jobs(paper_jobs_small(seed=0))
    J_ = batch.num_jobs

    reuse_s = _time(lambda: greedy.greedy_route(net, batch), repeat)
    rebuild_s = _time(
        lambda: greedy.greedy_route(net, batch, share_closures=False), repeat)

    SP.reset_closure_build_count()
    plan = greedy.greedy_route(net, batch)
    builds = SP.closure_build_count()
    lazy = greedy.greedy_route(net, batch, lazy=True)

    rec = dict(
        num_jobs=J_,
        greedy_reuse_s=reuse_s,
        greedy_rebuild_s=rebuild_s,
        reuse_speedup=rebuild_s / reuse_s,
        closure_builds_reuse=builds,
        lazy_n_routings=int(lazy.meta["n_routings"]),
        greedy_bounds=plan.bounds.tolist(),
        greedy_order=plan.order.tolist(),
        lazy_bounds=lazy.bounds.tolist(),
        bounds_match_seed=bool(
            plan.bounds.tolist() == QUICKSTART_BOUNDS
            and lazy.bounds.tolist() == QUICKSTART_BOUNDS
            and plan.order.tolist() == QUICKSTART_ORDER),
    )
    if verbose:
        print(f"  greedy J={J_}: reuse {reuse_s*1e3:.1f} ms  "
              f"rebuild {rebuild_s*1e3:.1f} ms  "
              f"(x{rec['reuse_speedup']:.2f}, {builds} closure builds)  "
              f"seed-bit-identical={rec['bounds_match_seed']}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, kernels forced on (CI gate)")
    ap.add_argument("--sizes", type=int, nargs="+", default=None)
    ap.add_argument("--layers", type=int, nargs="+", default=None)
    ap.add_argument("--force-pallas", action="store_true",
                    help="route every kernel row through Pallas even below "
                         "the dispatch threshold (CPU-record mode)")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path(__file__).parent / "BENCH_closure.json")
    args = ap.parse_args(argv)

    if args.smoke:
        sizes = args.sizes or [16, 32]
        layer_counts = args.layers or [2]
        force_pallas = True   # tiny shapes would dispatch to the oracle
    else:
        sizes = args.sizes or [64, 256, 512]
        layer_counts = args.layers or [8, 32]
        force_pallas = args.force_pallas

    print(f"closure bench (backend={jax.default_backend()}, "
          f"smoke={args.smoke})")
    kernel_rows = bench_kernels(sizes, layer_counts,
                                force_pallas=force_pallas,
                                repeat=args.repeat, verbose=True)
    greedy_rec = bench_greedy_reuse(repeat=args.repeat, verbose=True)

    record = dict(
        schema=1,
        backend=jax.default_backend(),
        smoke=bool(args.smoke),
        pallas_min_dim=ops._PALLAS_MIN_DIM,
        kernels=kernel_rows,
        greedy=greedy_rec,
        quickstart_reference=dict(bounds=QUICKSTART_BOUNDS,
                                  order=QUICKSTART_ORDER),
    )
    args.out.write_text(json.dumps(record, indent=2) + "\n")
    print(f"wrote {args.out}")
    if not greedy_rec["bounds_match_seed"]:
        print("ERROR: greedy/lazy bounds diverged from the seed solver",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
