"""Streaming pipeline throughput: sustained arr/s and p99 vs window (δ, B).

  PYTHONPATH=src python benchmarks/stream_bench.py [--smoke] [--out PATH]

For each scenario one bursty arrival stream is driven through the serving
stack twice over — once by the serial per-arrival loop (the pipeline at
δ=0, B=1: one solve per request) and once per (δ, B) batching-window
configuration (one padded batched solve per window) — with identical
arrivals, jobs, and drain semantics.  Wall-clock throughput (arrivals
processed per wall second — the metric ``BENCH_drain.json`` tracks for the
serial loop) is measured per configuration next to the *simulated* latency
the batching window costs: every request's recorded latency includes its
window residence, solver-queue wait and modeled solve latency, so a δ too
generous shows up as a p99 regression, not as a free lunch.

``BENCH_stream.json`` records, per scenario:

  * ``serial``  — the per-arrival baseline (wall arr/s, p50/p99, backlog),
  * ``grid``    — one row per (δ, B, solve_mode[, fuse_windows]): wall
    arr/s, ``speedup`` vs serial, ``p99_ratio`` (simulated p99 vs
    serial), sustained sim throughput, mean window occupancy,
    deferral/shed counts.  Rows with ``fuse_windows > 1`` drain that
    many queued windows per solver start as one fused multi-window
    dispatch (cross-arrival batching),
  * ``best_at_equal_p99`` — the fastest grid row whose p99 is within
    ``P99_EQUAL_TOL`` of serial; ``faster_at_equal_p99`` is the headline
    claim: the pipeline sustains strictly higher wall arr/s than the
    serial loop at equal p99,
  * ``pipeline_matches_serial`` — the correctness gate: at δ=0, B=1 and
    zero modeled solver latency the pipeline reproduces the serial
    ``run_online`` trace bit-identically (every record field except the
    measured solver wall, which is wall-clock),
  * ``drain_bounded`` — on sub-capacity cases, batching must not break
    stability.  Short bursty drives make the half-over-half backlog-max
    ratio noisy on heterogeneous job mixes (a heavy burst in one half
    moves it even for a perfectly drained system), so each windowed run
    is held to the serial loop's realized growth on the identical drive
    (small headroom), floored at the absolute ``online_bench`` bar.

plus global flags ``all_pipeline_match_serial`` / ``all_bounded`` (CI
gates on both via ``--smoke``) and ``faster_scenarios`` (how many
scenarios the pipeline wins at equal p99 — full mode includes
``us-backbone:lm``, where the win comes from ``solve_mode="sequential"``
windows amortizing per-entry drain-sync/backlog accounting over
heavy bursts in the deep-ledger exact-drain regime).

Timing discipline: every configuration is driven once untimed over the
identical stream first (jit compilation is keyed by data-dependent shapes
— window sizes, deduped closure rows — so only an identical drive warms
every shape), then the better of ``--repeat`` timed drives is kept.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

# Each case: scenario, stream shape, and its (δ-in-gaps, B, solve_mode)
# grid.  δ is in units of the mean inter-request gap 1/rate; the bursty
# stream (bursts of ``burst`` requests ~1 ms apart) means a tiny δ already
# captures whole bursts, and the larger-δ rows chart the p99 cost of
# holding windows open longer.
#
# The sub-capacity fluid cases measure per-call dispatch amortization with
# the batched solve (cheap evals — one padded solve per window wins ~2x)
# and carry the stability gate.  The us-backbone:lm case runs the exact
# (ledger) drain past capacity on long heavy-burst streams — the
# deep-committed-backlog regime drain_bench also targets.  There the
# solver is compute-bound and a padded batch's extra per-round candidate
# evaluations cost more than the dispatch they save (closure rows scale
# with batch width on CPU), so its winning rows use
# ``solve_mode="sequential"``: width-1 solves inside one scheduler entry —
# serial plans, amortized drain-sync/accounting — with one batched row
# kept to chart the contrast.
# A 4th grid element, when present, is ``fuse_windows``: with the batched
# solve mode, up to that many queued windows drain per solver start as ONE
# fused multi-window dispatch (``solve_fused``) — cross-arrival batching on
# top of within-window batching.  Fused rows only make sense for
# ``"batched"`` mode (sequential keeps the one-window-per-start contract).
SMOKE_CASES = [
    dict(name="star", arrivals=24, load=0.6, drain="fluid", burst=4,
         grid=[(0.05, 4, "batched"), (0.05, 4, "batched", 2)]),
    dict(name="paper-small", arrivals=24, load=0.6, drain="fluid", burst=4,
         grid=[(0.05, 4, "batched")]),
]
_SMALL_GRID = [(0.05, 2, "batched"), (0.05, 4, "batched"),
               (0.05, 8, "batched"), (0.2, 4, "batched"),
               (1.0, 4, "batched"), (0.05, 4, "batched", 2),
               (0.05, 4, "batched", 4)]
FULL_CASES = [
    dict(name="star", arrivals=40, load=0.6, drain="fluid", burst=4,
         grid=_SMALL_GRID),
    dict(name="paper-small", arrivals=40, load=0.6, drain="fluid", burst=4,
         grid=_SMALL_GRID),
    dict(name="edge-cloud:synthetic", arrivals=32, load=0.6, drain="fluid",
         burst=4, grid=_SMALL_GRID),
    dict(name="us-backbone:lm", arrivals=320, load=1.5, drain="exact",
         burst=8, repeat=3,
         grid=[(0.05, 4, "sequential"), (0.05, 8, "sequential"),
               (0.2, 8, "sequential"), (0.05, 8, "batched"),
               (0.05, 8, "batched", 4)]),
]

P99_EQUAL_TOL = 0.05        # "equal p99": within 5% of the serial loop
DRAIN_BOUNDED_MAX_GROWTH = 1.3   # same absolute stability bar as online_bench
DRAIN_BOUNDED_VS_SERIAL = 1.05   # ... or within 5% of serial on the same drive
EQUIV_ARRIVALS = 12


def _drive(name: str, *, arrivals: int, load: float, drain: str,
           seed: int, burst: int = 4, window_s: float = 0.0,
           max_batch: int = 1, solve_mode: str = "batched",
           fuse_windows: int = 1,
           solver_latency: float | str = "measured") -> tuple:
    """One full streaming session on a fresh scenario; returns (trace, wall)."""
    from repro.scenarios import make_scenario
    from repro.serving.stream import run_stream

    # Fresh scenario per drive: identical rng stream => identical jobs and
    # names across configurations.  The rate comes from a throwaway
    # instance — nominal_rate's calibration advances the name sequence.
    rate = make_scenario(name, seed=0).nominal_rate(load)
    sc = make_scenario(name, seed=0)
    t0 = time.time()
    tr = run_stream(sc, horizon=arrivals / rate, seed=seed,
                    process="bursty", rate=rate, drain=drain,
                    process_params={"burst_size": burst},
                    window_s=window_s, max_batch=max_batch,
                    solve_mode=solve_mode, fuse_windows=fuse_windows,
                    solver_latency=solver_latency)
    return tr, time.time() - t0


def _timed(repeat: int, **kw) -> tuple:
    """Identical warm-up drive, then best-of-``repeat`` timed drives."""
    _drive(**kw)
    best = None
    for _ in range(max(repeat, 1)):
        tr, wall = _drive(**kw)
        if best is None or wall < best[1]:
            best = (tr, wall)
    return best


def _equivalence(name: str, seed: int) -> bool:
    """δ=0, B=1, zero modeled latency == the serial loop, bit-identically
    (modulo the measured solver wall).  Runs on the poisson process — the
    gate is about the window/commit machinery, not the arrival law, and
    poisson guarantees arrivals inside a short horizon."""
    from repro.scenarios import make_scenario
    from repro.serving.online import run_online
    from repro.serving.stream import run_stream

    rate = make_scenario(name, seed=0).nominal_rate(0.6)
    kw = dict(horizon=EQUIV_ARRIVALS / rate, seed=seed, rate=rate)
    serial = run_online(make_scenario(name, seed=0), **kw)
    pipe = run_stream(make_scenario(name, seed=0), window_s=0.0,
                      max_batch=1, solver_latency=0.0, **kw)
    if len(serial.records) != len(pipe.records) or not serial.records:
        return False
    return all(dataclasses.replace(a, solve_s=0.0)
               == dataclasses.replace(b, solve_s=0.0)
               for a, b in zip(serial.records, pipe.records))


def _row(tr, wall: float) -> dict:
    s = tr.summary()
    n = len(tr.requests)
    return {
        "requests": n,
        "windows": s["windows"],
        "mean_window": s["mean_window"],
        "deferred": s["deferred"],
        "shed": s["shed"],
        "wall_s": wall,
        "arr_per_s_wall": n / wall,
        "p50_latency_s": s["p50_latency_s"],
        "p99_latency_s": s["p99_latency_s"],
        "p99_wait_s": s.get("p99_wait_s", 0.0),
        "sustained_arr_s": s["sustained_arr_s"],
        "backlog_growth": s["backlog_growth"],
    }


def _bench_case(case: dict, *, seed: int, repeat: int,
                verbose: bool) -> dict:
    from repro.scenarios import make_scenario

    name, arrivals = case["name"], case["arrivals"]
    load, drain, burst = case["load"], case["drain"], case["burst"]
    repeat = case.get("repeat", repeat)  # noisy cases take best-of-more
    rate = make_scenario(name, seed=0).nominal_rate(load)
    base = dict(name=name, arrivals=arrivals, load=load, drain=drain,
                burst=burst, seed=seed)
    tr, wall = _timed(repeat, **base)
    serial = _row(tr, wall)
    rows = []
    for entry in case["grid"]:
        dmult, B, mode = entry[:3]
        fuse = entry[3] if len(entry) > 3 else 1
        tr, wall = _timed(repeat, window_s=dmult / rate, max_batch=B,
                          solve_mode=mode, fuse_windows=fuse, **base)
        r = _row(tr, wall)
        r.update({
            "window_s": dmult / rate,
            "window_gaps": dmult,
            "max_batch": B,
            "solve_mode": mode,
            "fuse_windows": fuse,
            "speedup": r["arr_per_s_wall"] / serial["arr_per_s_wall"],
            "p99_ratio": r["p99_latency_s"] / serial["p99_latency_s"],
        })
        rows.append(r)
        if verbose:
            print(f"  δ={dmult:4.2f}/rate B={B} {mode[:3]}"
                  f"{f' f={fuse}' if fuse > 1 else '':5s}: "
                  f"{r['arr_per_s_wall']:7.1f} arr/s "
                  f"({r['speedup']:5.2f}x)  p99 {r['p99_latency_s']:8.3f}s "
                  f"(x{r['p99_ratio']:.3f})  win={r['windows']:3d} "
                  f"mean_B={r['mean_window']:.1f}", flush=True)
    equal = [r for r in rows if r["p99_ratio"] <= 1.0 + P99_EQUAL_TOL]
    best = max(equal, key=lambda r: r["speedup"]) if equal else None
    sub_capacity = load < 1.0
    growth_cap = max(DRAIN_BOUNDED_MAX_GROWTH,
                     serial["backlog_growth"] * DRAIN_BOUNDED_VS_SERIAL)
    bounded = all(r["backlog_growth"] <= growth_cap
                  for r in rows) if sub_capacity else True
    out = {
        "scenario": name,
        "arrivals": arrivals,
        "load": load,
        "drain": drain,
        "burst_size": burst,
        "rate_per_s": rate,
        "serial": serial,
        "grid": rows,
        "pipeline_matches_serial": _equivalence(name, seed),
        "drain_bounded": bounded,
        "best_at_equal_p99": best,
        "faster_at_equal_p99": bool(best and best["speedup"] > 1.0),
    }
    if verbose:
        b = best or {"speedup": float("nan"), "p99_ratio": float("nan")}
        print(f"{name:24s} serial {serial['arr_per_s_wall']:7.1f} arr/s  "
              f"best-at-equal-p99 {b['speedup']:5.2f}x "
              f"(p99 x{b['p99_ratio']:.3f})  "
              f"match={out['pipeline_matches_serial']} "
              f"bounded={out['drain_bounded']}", flush=True)
    return out


def run(*, smoke: bool = False, seed: int = 9, repeat: int = 2,
        verbose: bool = True) -> dict:
    cases = SMOKE_CASES if smoke else FULL_CASES
    rows = [_bench_case(case, seed=seed, repeat=repeat, verbose=verbose)
            for case in cases]
    faster = [r["scenario"] for r in rows if r["faster_at_equal_p99"]]
    out = {
        "benchmark": "stream",
        "smoke": smoke,
        "p99_equal_tol": P99_EQUAL_TOL,
        "rows": rows,
        "all_pipeline_match_serial": all(r["pipeline_matches_serial"]
                                         for r in rows),
        "all_bounded": all(r["drain_bounded"] for r in rows),
        "faster_scenarios": faster,
    }
    if verbose:
        print(f"all_pipeline_match_serial={out['all_pipeline_match_serial']} "
              f"all_bounded={out['all_bounded']} "
              f"faster_at_equal_p99={faster}", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2 small scenarios, 1 grid point (the CI gate: "
                         "serial equivalence + bounded backlog)")
    ap.add_argument("--seed", type=int, default=9)
    ap.add_argument("--repeat", type=int, default=2)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_stream.json"))
    args = ap.parse_args()
    record = run(smoke=args.smoke, seed=args.seed, repeat=args.repeat)
    pathlib.Path(args.out).write_text(json.dumps(record, indent=2))
    print(f"wrote {args.out}")
    if not record["all_pipeline_match_serial"]:
        raise SystemExit("pipeline diverged from the serial loop at "
                         "δ=0, B=1, zero solver latency")
    if not record["all_bounded"]:
        raise SystemExit("windowed pipeline backlog not bounded at "
                         "sub-capacity load")


if __name__ == "__main__":
    main()
