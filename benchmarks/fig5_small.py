"""Fig. 5 reproduction: job completion time vs link-capacity scale on the
5-node topology (2 VGG19 + 6 ResNet34, 5 random src-dst realizations)."""
from __future__ import annotations

import numpy as np

from repro.core import jobs as J, network as N, solve
from .common import paper_jobs_small

# (full paper sweep: 6 scales x 5 realizations; trimmed for the
#  single-core container — structure and trends identical)
SCALES = [1e-4, 1e-3, 1e-2, 1.0]
REALIZATIONS = 2


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for scale in SCALES:
        g_bounds, g_sims, s_bounds, s_sims = [], [], [], []
        g_time = s_time = 0.0
        for seed in range(REALIZATIONS):
            net, _ = N.small_topology(capacity_scale=scale)
            batch = J.batch_jobs(paper_jobs_small(seed))
            sol = solve(net, batch, method="greedy")
            g_time += sol.meta["solve_s"]
            g_bounds.append(sol.bound())
            g_sims.append(sol.simulate(net, batch).makespan)
            sa = solve(net, batch, method="sa", seed=seed, d=0.995,
                       num_chains=4, block_move_prob=0.3)
            s_time += sa.meta["solve_s"]
            s_bounds.append(sa.bound())
            s_sims.append(sa.simulate(net, batch).makespan)
        row = dict(scale=scale,
                   greedy_bound=float(np.mean(g_bounds)),
                   greedy_sim=float(np.mean(g_sims)),
                   sa_bound=float(np.mean(s_bounds)),
                   sa_sim=float(np.mean(s_sims)),
                   greedy_s=g_time / REALIZATIONS,
                   sa_s=s_time / REALIZATIONS)
        rows.append(row)
        if verbose:
            print(f"  scale {scale:7.4f}: greedy {row['greedy_sim']:10.3f}s "
                  f"(bound {row['greedy_bound']:10.3f})  "
                  f"sa {row['sa_sim']:10.3f}s (bound {row['sa_bound']:10.3f})",
                  flush=True)
    return rows
