"""Large-topology experiment (§V): 24-node US backbone, 10 jobs
(6 VGG19 + 2 ResNet34 + 2 hand-made models)."""
from __future__ import annotations

import numpy as np

from repro.core import jobs as J, network as N, solve
from .common import paper_jobs_large

SCALES = [1e-4, 1e-2]
REALIZATIONS = 1


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for scale in SCALES:
        g_sims, s_sims = [], []
        g_time = s_time = 0.0
        for seed in range(REALIZATIONS):
            net, _ = N.us_backbone(capacity_scale=scale)
            batch = J.batch_jobs(paper_jobs_large(seed))
            sol = solve(net, batch, method="greedy")
            g_time += sol.meta["solve_s"]
            g_sims.append(sol.simulate(net, batch).makespan)
            sa = solve(net, batch, method="sa", seed=seed, d=0.99,
                       num_chains=2, block_move_prob=0.3)
            s_time += sa.meta["solve_s"]
            s_sims.append(sa.simulate(net, batch).makespan)
        row = dict(scale=scale, greedy_sim=float(np.mean(g_sims)),
                   sa_sim=float(np.mean(s_sims)),
                   greedy_s=g_time / REALIZATIONS,
                   sa_s=s_time / REALIZATIONS)
        rows.append(row)
        if verbose:
            print(f"  scale {scale:7.4f}: greedy {row['greedy_sim']:10.3f}s "
                  f"({row['greedy_s']:5.2f}s solve)  sa {row['sa_sim']:10.3f}s "
                  f"({row['sa_s']:6.2f}s solve)", flush=True)
    return rows
