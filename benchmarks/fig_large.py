"""Large-topology experiment (§V): 24-node US backbone, 10 jobs
(6 VGG19 + 2 ResNet34 + 2 hand-made models)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import annealing, greedy, jobs as J, network as N, schedule
from .common import paper_jobs_large

SCALES = [1e-4, 1e-2]
REALIZATIONS = 1


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for scale in SCALES:
        g_sims, s_sims = [], []
        g_time = s_time = 0.0
        for seed in range(REALIZATIONS):
            net, _ = N.us_backbone(capacity_scale=scale)
            batch = J.batch_jobs(paper_jobs_large(seed))
            t0 = time.time()
            sol = greedy.greedy_route(net, batch)
            g_time += time.time() - t0
            g_sims.append(schedule.simulate(net, batch, sol.assign,
                                            sol.order).makespan)
            t0 = time.time()
            sa = annealing.anneal(net, batch, seed=seed, d=0.99,
                                  num_chains=2, block_move_prob=0.3)
            s_time += time.time() - t0
            s_sims.append(schedule.simulate(net, batch, sa.assign,
                                            sa.priority).makespan)
        row = dict(scale=scale, greedy_sim=float(np.mean(g_sims)),
                   sa_sim=float(np.mean(s_sims)),
                   greedy_s=g_time / REALIZATIONS,
                   sa_s=s_time / REALIZATIONS)
        rows.append(row)
        if verbose:
            print(f"  scale {scale:7.4f}: greedy {row['greedy_sim']:10.3f}s "
                  f"({row['greedy_s']:5.2f}s solve)  sa {row['sa_sim']:10.3f}s "
                  f"({row['sa_s']:6.2f}s solve)", flush=True)
    return rows
