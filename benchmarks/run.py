# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

  PYTHONPATH=src python -m benchmarks.run [--only fig5,large,runtime,...]

One benchmark per paper artifact (Fig. 5 small topology, §V large topology,
the runtime-scaling claim, the §III-B bound-gap) plus the kernel
micro-benchmark and the roofline table reader (deliverable g).  Each prints
a ``name,us_per_call,derived`` CSV line; ``derived`` carries the benchmark's
headline number.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    args = ap.parse_args()
    want = None if args.only == "all" else set(args.only.split(","))

    results = []

    def bench(name, fn, derive):
        if want is not None and name not in want:
            return
        print(f"== {name} ==", flush=True)
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        results.append((name, dt * 1e6, derive(rows)))

    from . import admission_bench, bound_gap, drain_bench, fault_bench, \
        fig5_small, fig_large, kernel_bench, online_bench, roofline, \
        runtime_scaling, solver_compare, solver_fused_bench, stream_bench

    def _solver_ratio(rows):
        by = {r["method"]: r for r in rows}
        if "exact" not in by or "greedy" not in by:
            return "n/a"
        return f"exact/greedy={by['exact']['bound']/by['greedy']['bound']:.3f}"

    bench("solvers", solver_compare.run,
          lambda r: _solver_ratio(r) if r else "n/a")
    bench("solver_fused",
          lambda: solver_fused_bench.run(smoke=True, verbose=False),
          lambda r: (f"match={r['fused_matches_ref']},"
                     f"e2e={r['end_to_end']['speedup']:.2f}x")
          if r else "n/a")
    bench("online", lambda: online_bench.run(smoke=True),
          lambda r: (f"bounded={all(x['drain_bounded'] for x in r)},"
                     f"diverges={all(x['nodrain_diverges'] for x in r)}")
          if r else "n/a")
    bench("online_fidelity", lambda: online_bench.run_fidelity(smoke=True),
          lambda r: (f"fluid_seed={r['fluid_matches_seed']},"
                     f"exact_holds={r['all_exact_bounds_hold']},"
                     f"gap={r['rows'][0]['backlog_gap_mean_s']:.4f}s")
          if r and r.get("rows") else "n/a")
    bench("stream", lambda: stream_bench.run(smoke=True, verbose=False),
          lambda r: (f"match={r['all_pipeline_match_serial']},"
                     f"bounded={r['all_bounded']},"
                     f"best={max((x['best_at_equal_p99']['speedup'] for x in r['rows'] if x['best_at_equal_p99']), default=float('nan')):.2f}x")
          if r else "n/a")
    bench("fault", lambda: fault_bench.run(smoke=True, verbose=False),
          lambda r: (f"replay={r['all_replay_match']},"
                     f"bounded={r['all_requeue_bounded']},"
                     f"requeue_p99_vs_oracle={r['rows'][0]['policies']['requeue'].get('p99_vs_oracle', float('nan')):.2f}x")
          if r else "n/a")
    bench("admission", lambda: admission_bench.run(smoke=True,
                                                   verbose=False),
          lambda r: (f"exact={r['prediction_exact']},"
                     f"wins={r['all_overload_wins']},"
                     f"bounded={r['all_replan_bounded']}")
          if r else "n/a")
    bench("drain", lambda: drain_bench.run(smoke=True),
          lambda r: (f"match={r['all_indexed_match_ref']},"
                     f"loop={r['headline']['loop_speedup']:.2f}x,"
                     f"replay={r['headline']['replay_speedup']:.1f}x")
          if r else "n/a")
    bench("fig5_small", fig5_small.run,
          lambda r: f"sim@1e-4={r[0]['greedy_sim']:.1f}s" if r else "n/a")
    bench("fig_large", fig_large.run,
          lambda r: f"sim@1e-4={r[0]['greedy_sim']:.1f}s" if r else "n/a")
    bench("runtime_scaling", runtime_scaling.run,
          lambda r: f"greedyV{r[-1]['V']}={r[-1]['greedy_warm_s']:.2f}s" if r else "n/a")
    bench("bound_gap", bound_gap.run,
          lambda r: f"mean_ratio={r['mean_ratio']:.3f}" if r else "n/a")
    bench("kernel_minplus", kernel_bench.run,
          lambda r: f"tpuV{r[-1]['V']}={r[-1]['tpu_projected_s']*1e6:.0f}us" if r else "n/a")
    bench("roofline", roofline.run,
          lambda r: f"{sum(1 for x in r if x.get('status') == 'ok')}cells" if r else "n/a")

    print("\nname,us_per_call,derived")
    for name, us, derived in results:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
