"""§Perf hillclimb driver: run the three chosen cells through their
optimization iterations and dump one JSON per (cell, iteration).

Cells (chosen from the baseline roofline table, see EXPERIMENTS.md §Perf):
  * deepseek_v2_236b x train_4k  — worst roofline fraction (memory-bound on
    materialized MLA scores; temp 11 TB/dev)
  * olmoe_1b_7b x train_4k       — most collective-bound
  * gemma3_1b x decode_32k       — collective-bound inference cell (the
    paper's serving regime; kv=1 makes TP16 pure overhead)

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [--cell olmoe|gemma3|deepseek]
(子processes are NOT used: must run in the dryrun-flagged interpreter.)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json
import pathlib

CELLS = {
    "olmoe": [
        # it1 REFUTED the EP-constraint hypothesis (see EXPERIMENTS §Perf):
        # post-hoc sharding constraints on the dispatch buffer force GSPMD
        # into extra reshards (X: 27 -> 168 s). Subsequent iterations drop it.
        ("olmoe_1b_7b", "train_4k", "it1_ep_shard", {"moe_ep_shard": True}),
        ("olmoe_1b_7b", "train_4k", "it2_flash", {"attn_impl": "flash"}),
        ("olmoe_1b_7b", "train_4k", "it3_flash_dots",
         {"attn_impl": "flash", "remat_policy": "dots"}),
        # it2/it3 revealed the real bottleneck: the global-argsort dispatch
        # is replicated by GSPMD. it4 localizes it per data shard.
        ("olmoe_1b_7b", "train_4k", "it4_flash_local_moe",
         {"attn_impl": "flash", "moe_local_dispatch": True}),
        # it4 cut X 12.5x but the data-axis-only shard_map replicated the
        # dispatch compute across 'model' (flops 9x). it5 shards the
        # dispatch over both axes.
        ("olmoe_1b_7b", "train_4k", "it5_flash_local_moe_2d",
         {"attn_impl": "flash", "moe_local_dispatch": True, "_v": 2}),
    ],
    "gemma3": [
        ("gemma3_1b", "decode_32k", "it1_dp_only", {"layout": "dp_only"}),
        ("gemma3_1b", "decode_32k", "it2_dp_only_chunk",
         {"layout": "dp_only", "attn_chunk_q": 512}),
        ("gemma3_1b", "decode_32k", "it3_grouped_gqa",
         {"gqa_grouped": True}),
        ("gemma3_1b", "decode_32k", "it4_grouped_dp_attn",
         {"gqa_grouped": True, "layout": "dp_attn"}),
    ],
    "deepseek": [
        ("deepseek_v2_236b", "train_4k", "it1_flash",
         {"attn_impl": "flash"}),
        ("deepseek_v2_236b", "train_4k", "it2_flash_local_moe",
         {"attn_impl": "flash", "moe_local_dispatch": True}),
    ],
}


def main():
    from repro.launch.dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all")
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    names = list(CELLS) if args.cell == "all" else [args.cell]
    for name in names:
        for arch, shape, tag, opts in CELLS[name]:
            path = outdir / f"{arch}.{shape}.{tag}.json"
            if path.exists():
                print(f"[hillclimb] {tag}: cached")
                continue
            print(f"[hillclimb] {arch} x {shape} :: {tag} {opts}", flush=True)
            opts = dict(opts)
            opts.setdefault("scan_layers", False)
            try:
                rec = run_cell(arch, shape, False, opts=opts)
            except Exception as e:
                import traceback
                rec = {"arch": arch, "shape": shape, "status": "fail",
                       "error": str(e),
                       "traceback": traceback.format_exc()[-1500:]}
            rec["tag"] = tag
            path.write_text(json.dumps(rec, indent=1))
            coll = (rec.get("collectives") or {}).get("effective_bytes", 0)
            print(f"[hillclimb] {tag}: {rec['status']} "
                  f"flops/dev={rec.get('flops_per_device', 0):.3g} "
                  f"bytes/dev={rec.get('bytes_accessed_per_device', 0):.3g} "
                  f"coll_eff={coll:.3g} "
                  f"compile={rec.get('compile_s', '-')}s", flush=True)


if __name__ == "__main__":
    main()
