"""Parameter / activation sharding rules for the production mesh.

Mesh axes (see launch/mesh.py): single-pod ('data', 'model'); multi-pod
('pod', 'data', 'model').  Strategy (DESIGN.md §3.4):

  * TP over 'model': attention head / FFN hidden / expert / vocab dims.
  * FSDP over 'data': the non-TP weight dim (ZeRO-3-style; gathered by
    GSPMD around use).  Across 'pod' parameters are *replicated* — pure DP
    with gradient all-reduce over the (slower, DCN-like) pod axis, which the
    int8 gradient compressor targets.
  * Activations: batch over ('pod', 'data'); decode KV caches shard heads
    over 'model' when divisible.

Rules are name-based templates fitted right-aligned to each leaf's shape, so
stacked [L, ...] / grouped [G, k, ...] block params inherit the rule of their
trailing dims automatically.  Any template axis that does not divide the
corresponding dim is dropped (e.g. gemma3's single KV head is replicated
rather than force-sharded) — the helper guarantees a *legal* spec for every
architecture in the pool.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = "data"
TP = "model"

# (regex on the param path, right-aligned spec template for trailing dims)
_RULES: list[tuple[str, tuple]] = [
    # [V, D] vocab-parallel only: sharding D over 'data' would leak a
    # D-sharding into the gather output and replicate the batch dim of every
    # downstream activation (found by the roofline audit; EXPERIMENTS §Perf).
    (r"embed/tok$", ("model", None)),
    (r"embed/head$", ("data", "model")),           # [D, V]
    (r"(wq|wk|wv|w_q|w_q_b)$", ("data", "model")),  # [D, H*hd]
    (r"(wo|w_out)$", ("model", "data")),           # [H*hd, D]
    (r"(w_up|w_gate|w_in)$", ("data", "model")),   # [D, F]
    (r"w_down$", ("model", "data")),               # [F, D]
    (r"router$", ("data", None)),                  # [D, E] replicated experts dim
    (r"moe/w_(gate|up)$", ("model", "data", None)),  # [E, D, F] EP over experts
    (r"moe/w_down$", ("model", None, "data")),     # [E, F, D]
    (r"(w_kv_a|w_q_a)$", ("data", None)),          # [D, r]
    (r"(w_uk|w_uv)$", ("model", None, None)),      # [H, r, hd] heads over TP
    (r"conv_w$", (None, "model")),                 # [dconv, inner+2n]
    (r"w_[ifo]$", ("data", None)),                 # xlstm gate projections
    (r"/r$", (None, None, None)),                  # sLSTM recurrent blocks
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit(template: tuple, shape: tuple, mesh: Mesh) -> P:
    """Right-align the template to ``shape``; drop non-dividing axes."""
    spec = [None] * len(shape)
    t = list(template)
    for i in range(1, min(len(t), len(shape)) + 1):
        axis = t[-i]
        dim = shape[-i]
        if axis is not None and dim % _mesh_axis_size(mesh, axis) == 0:
            spec[len(shape) - i] = axis
    return P(*spec)


_ATTN_PARAM_RE = r"(wq|wk|wv|wo|w_q$|w_q_a|w_q_b|w_uk|w_uv|w_kv_a)"


def _apply_layout(template: tuple, layout: str, name: str = "") -> tuple:
    """Layout policies.

    '2d' (baseline): TP over 'model' + FSDP over 'data'.
    'dp_only': no tensor parallelism — FSDP over the combined
    ('data', 'model') axes, batch over everything.  The right layout for
    TP-unfriendly small models (few heads; see EXPERIMENTS.md §Perf).
    """
    if layout == "2d":
        return template
    if layout == "dp_attn":
        # hybrid: attention projections go data-parallel (TP-hostile when
        # heads < mesh model size), FFN / vocab keep TP
        if re.search(_ATTN_PARAM_RE, name):
            return _apply_layout(template, "dp_only", name)
        return template
    if layout == "dp_only":
        out = []
        for a in template:
            if a == "model":
                out.append(None)
            elif a == "data":
                out.append(("data", "model"))
            else:
                out.append(a)
        return tuple(out)
    raise ValueError(layout)


def param_specs(shape_tree: Any, mesh: Mesh, *, layout: str = "2d") -> Any:
    """PartitionSpec tree matching ``shape_tree`` (a ShapeDtypeStruct tree)."""

    def leaf_spec(path, leaf):
        name = _path_str(path)
        for pat, template in _RULES:
            if re.search(pat, name):
                return _fit(_apply_layout(template, layout, name), leaf.shape,
                            mesh)
        return P()  # norms, scalars, biases: replicate

    return jax.tree_util.tree_map_with_path(leaf_spec, shape_tree)


def param_shardings(shape_tree: Any, mesh: Mesh, *, layout: str = "2d") -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(shape_tree, mesh, layout=layout))


def batch_axes(mesh: Mesh, *, layout: str = "2d"):
    """Mesh axes the global batch shards over."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if layout == "dp_only":
        dp = dp + ("model",)
    return dp


def batch_specs(batch_tree: Any, mesh: Mesh, *, layout: str = "2d") -> Any:
    dp = batch_axes(mesh, layout=layout)

    def leaf_spec(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        # drop trailing dp axes until the batch dim divides (e.g. batch 128
        # on a 512-chip dp_only layout shards over ('data',) only)
        axes = dp
        while axes and shape[0] % _mesh_axis_size(mesh, axes) != 0:
            axes = axes[:-1]
        if axes:
            return P(axes, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_tree)


def cache_specs(cache_tree: Any, mesh: Mesh, *, layout: str = "2d") -> Any:
    """Decode caches: [L, B, T, heads, hd] — batch over dp, heads over TP."""
    dp = batch_axes(mesh, layout=layout)

    def leaf_spec(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 2:
            axes = dp  # progressive fallback like batch_specs
            while axes and shape[1] % _mesh_axis_size(mesh, axes) != 0:
                axes = axes[:-1]
            if axes:
                spec[1] = axes
        # heads axis (dim 3 of [L,B,T,H,hd]) over TP when divisible
        if len(shape) == 5 and shape[3] % _mesh_axis_size(mesh, TP) == 0:
            spec[3] = TP
        # recurrent states [L,B,H,...]: heads at dim 2
        if len(shape) in (4, 5) and len(shape) != 5 and \
                shape[2] % _mesh_axis_size(mesh, TP) == 0:
            spec[2] = TP
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def opt_state_specs(param_spec_tree: Any, mesh: Mesh) -> Any:
    """AdamW state: m/v mirror param specs; step is replicated."""
    return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}
