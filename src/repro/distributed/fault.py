"""Fault tolerance & straggler handling for long-running jobs.

On a real multi-pod deployment, node failure surfaces as a collective
timeout / ICI error and the job scheduler restarts the affected workers.
Our contract (exercised end-to-end by tests/test_fault.py and the train
driver):

  * ``TrainLoop`` checkpoints every ``ckpt_every`` steps (atomic — see
    checkpoint/ckpt.py) and on (re)start resumes from the newest complete
    checkpoint; the data pipeline is a pure function of the step, so the
    restarted trajectory is bit-identical to an uninterrupted run.
  * ``FailureInjector`` kills the loop at a chosen step to simulate a node
    loss; the test then restarts and asserts identical final losses.
  * Straggler mitigation happens at two levels: (1) training — the loop
    tracks a robust (median + MAD) step-time estimate and reports
    persistent outliers so the launcher can re-place the worker
    (``StragglerMonitor``); (2) serving — slow replicas accumulate queue
    backlog Q_u, which the paper's routing objective (waiting term Q_u/mu_u)
    automatically routes around: see serving/scheduler.py.

This module is the *training-side* story: work is recomputed from a
checkpoint.  The serving-side counterpart — typed node/link
failure/recovery events on the serving clock, with stranded inference
work rerouted (requeue / migrate / lost) rather than recomputed — lives
in :mod:`repro.serving.faults`.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


class FailureInjector:
    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    """Robust step-time outlier detector (median + k*MAD)."""
    window: int = 50
    k: float = 5.0
    _times: list = dataclasses.field(default_factory=list)
    flagged: int = 0

    def record(self, dt: float) -> bool:
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 10:
            return False
        med = float(np.median(self._times))
        mad = float(np.median(np.abs(np.asarray(self._times) - med))) + 1e-9
        is_straggler = dt > med + self.k * mad
        if is_straggler:
            self.flagged += 1
        return is_straggler
