"""Rule engine: registry, pragma suppression, file runner, CLI.

A *rule* is a function ``(module: LintModule) -> Iterable[Violation]``
registered under a stable code (``RL001`` ...).  The engine owns everything
rule-independent: parsing, the per-module device-region resolver cache,
``# repro-lint: disable=<code> -- <reason>`` pragma collection and
application, and the CLI entry (:func:`run_cli`, wired to
``python -m repro.lint``).

Pragma semantics
----------------
* ``# repro-lint: disable=RL001 -- reason`` on any line spanned by the
  flagged expression/statement (or on the line directly above it)
  suppresses that code there.
* ``# repro-lint: disable-file=RL003 -- reason`` anywhere in a file
  suppresses the code for the whole file.
* Multiple codes separate with commas: ``disable=RL001,RL005 -- reason``.
* The ``-- reason`` is **mandatory** and the code must exist: a malformed
  pragma is reported as RL000 and is itself unsuppressable — tribal
  knowledge got us here, so every suppression carries its justification.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Callable, Iterable, Iterator

from .resolver import DeviceRegionResolver

PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*(?P<kind>disable(?:-file)?)\s*=\s*"
    r"(?P<codes>[A-Za-z0-9_,\s]+?)\s*(?:--\s*(?P<reason>\S.*))?$")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: where, which rule, and the human-facing message."""

    path: str
    line: int
    col: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    doc: str              # one-line invariant summary (README rule table)
    check: Callable[["LintModule"], Iterable[Violation]]


_RULES: dict[str, Rule] = {}

# RL000 is reserved for the engine itself (malformed pragmas) so rules and
# pragma bookkeeping share one reporting path.
BAD_PRAGMA = "RL000"


def register_rule(code: str, name: str, doc: str):
    """Decorator: register a check function under a rule code."""

    def deco(fn):
        if code in _RULES:
            raise ValueError(f"duplicate lint rule code {code!r}")
        _RULES[code] = Rule(code=code, name=name, doc=doc, check=fn)
        return fn

    return deco


def registered_rules() -> dict[str, Rule]:
    """Copy of the {code: Rule} registry (import order registers RL001+)."""
    return dict(_RULES)


class _Pragmas:
    """Per-file pragma index: which codes are disabled on which lines."""

    def __init__(self, source: str, path: str, known: set[str]):
        self.line_codes: dict[int, set[str]] = {}
        self.file_codes: set[str] = set()
        self.bad: list[Violation] = []
        for ln, text in self._comments(source):
            if "repro-lint" not in text:
                continue
            m = PRAGMA_RE.search(text)
            if m is None:
                self.bad.append(Violation(
                    path, ln, 0, BAD_PRAGMA,
                    "unparseable repro-lint pragma (expected "
                    "'# repro-lint: disable=<CODE> -- <reason>')"))
                continue
            codes = {c.strip() for c in m.group("codes").split(",") if c.strip()}
            unknown = sorted(c for c in codes if c not in known)
            if unknown:
                self.bad.append(Violation(
                    path, ln, 0, BAD_PRAGMA,
                    f"pragma names unknown rule code(s): {', '.join(unknown)}"))
            if not m.group("reason"):
                self.bad.append(Violation(
                    path, ln, 0, BAD_PRAGMA,
                    "pragma is missing its '-- <reason>' justification"))
                continue
            codes &= known
            if m.group("kind") == "disable-file":
                self.file_codes |= codes
            else:
                self.line_codes.setdefault(ln, set()).update(codes)

    @staticmethod
    def _comments(source: str) -> list[tuple[int, str]]:
        """(line, text) of actual COMMENT tokens — docstrings and string
        literals that merely *mention* the pragma syntax don't count."""
        try:
            return [(tok.start[0], tok.string)
                    for tok in tokenize.generate_tokens(
                        io.StringIO(source).readline)
                    if tok.type == tokenize.COMMENT]
        except (tokenize.TokenError, IndentationError):
            return []   # syntax problems surface via ast.parse instead

    def suppressed(self, v: Violation, span: tuple[int, int]) -> bool:
        if v.code == BAD_PRAGMA:
            return False
        if v.code in self.file_codes:
            return True
        lo, hi = span
        for ln in range(lo - 1, hi + 1):   # line above the span counts too
            if v.code in self.line_codes.get(ln, ()):
                return True
        return False


class LintModule:
    """One parsed file plus the lazy per-module analyses rules share."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.posix = Path(path).as_posix()
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self._resolver: DeviceRegionResolver | None = None
        self._parents: dict[ast.AST, ast.AST] | None = None

    # -- shared analyses ----------------------------------------------------
    @property
    def resolver(self) -> DeviceRegionResolver:
        if self._resolver is None:
            self._resolver = DeviceRegionResolver(self.tree)
        return self._resolver

    @property
    def parents(self) -> dict[ast.AST, ast.AST]:
        """child -> parent map over the whole tree (lazily built once)."""
        if self._parents is None:
            self._parents = {}
            for parent in ast.walk(self.tree):
                for child in ast.iter_child_nodes(parent):
                    self._parents[child] = parent
        return self._parents

    def enclosing(self, node: ast.AST, *types) -> ast.AST | None:
        """Nearest ancestor of one of the given AST types (or None)."""
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, types):
                return cur
            cur = self.parents.get(cur)
        return None

    def in_module(self, *fragments: str) -> bool:
        """Does this file live under any of the given path fragments?"""
        return any(f in self.posix for f in fragments)

    # -- violation helper ---------------------------------------------------
    def flag(self, node: ast.AST, code: str, message: str) -> Violation:
        return Violation(self.path, getattr(node, "lineno", 0),
                         getattr(node, "col_offset", 0), code, message)


def node_span(node: ast.AST) -> tuple[int, int]:
    lo = getattr(node, "lineno", 0)
    hi = getattr(node, "end_lineno", lo) or lo
    return lo, hi


def lint_module(module: LintModule,
                codes: Iterable[str] | None = None) -> list[Violation]:
    known = set(_RULES)
    pragmas = _Pragmas(module.source, module.path, known)
    out: list[Violation] = list(pragmas.bad)
    selected = known if codes is None else set(codes) & known
    # Rules report (violation, node) pairs internally via closure on the
    # module; the engine re-derives the span from the reported line by
    # walking the tree once per file below.
    spans: dict[tuple[int, int, str], tuple[int, int]] = {}
    for code in sorted(selected):
        rule = _RULES[code]
        for item in rule.check(module):
            if isinstance(item, tuple):      # (violation, node) from a rule
                v, node = item
                span = node_span(node)
            else:
                v, span = item, (item.line, item.line)
            spans[(v.line, v.col, v.code)] = span
            if not pragmas.suppressed(v, span):
                out.append(v)
    out.sort(key=lambda v: (v.line, v.col, v.code))
    return out


def lint_source(source: str, path: str = "<string>",
                codes: Iterable[str] | None = None) -> list[Violation]:
    """Lint one source string (the test-fixture entry point)."""
    try:
        module = LintModule(path, source)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 0, e.offset or 0, BAD_PRAGMA,
                          f"syntax error: {e.msg}")]
    return lint_module(module, codes)


def iter_py_files(paths: Iterable[str]) -> Iterator[Path]:
    for p in paths:
        root = Path(p)
        if root.is_file() and root.suffix == ".py":
            yield root
        elif root.is_dir():
            yield from sorted(root.rglob("*.py"))


def lint_paths(paths: Iterable[str],
               codes: Iterable[str] | None = None) -> list[Violation]:
    out: list[Violation] = []
    for f in iter_py_files(paths):
        out.extend(lint_source(f.read_text(), str(f), codes))
    return out


def run_cli(argv: list[str] | None = None) -> int:
    """``python -m repro.lint <paths...> [--strict] [--list-rules]``.

    Exit status 0 = clean, 1 = violations found, 2 = usage error.
    ``--strict`` is accepted for CI symmetry; every rule here is an error
    already (there is no warning tier to promote), so it only asserts the
    flag is wired.
    """
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="trace-safety & numerics static analysis "
                    "(see src/repro/lint/__init__.py)")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--strict", action="store_true",
                        help="CI mode (all rules are errors either way)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for code, rule in sorted(registered_rules().items()):
            print(f"{code}  {rule.name}: {rule.doc}")
        return 0

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        parser.error(f"no such path(s): {', '.join(missing)}")
        return 2
    violations = lint_paths(args.paths)
    for v in violations:
        print(v.render())
    n = len(violations)
    print(f"repro.lint: {n} violation{'s' if n != 1 else ''} "
          f"in {sum(1 for _ in iter_py_files(args.paths))} files"
          + (" (clean)" if n == 0 else ""))
    return 1 if violations else 0
