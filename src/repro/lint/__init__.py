"""repro.lint — trace-safety & numerics static analysis for this codebase.

PRs 5-8 bought bit-exactness and the fused solver's one-dispatch contract
by discovering fragile invariants *at runtime*: the FMA-proof
``(d + Q) * inv`` edge-weight form, the "unroll only contraction-free scan
bodies" rule, host-sync-free device loops, frozen-dataclass cache slots,
and the stamped-never-accumulated float64 clock.  Nothing in pytest stops
the next change from silently reintroducing any of them — only a bit-parity
benchmark catches it hours later.  This package makes those invariants
machine-checked:

    python -m repro.lint src/ tests/ benchmarks/ [--strict]

Rule families (see :mod:`repro.lint.rules` for the full docs):

=======  ====================  ==============================================
code     name                  invariant
=======  ====================  ==============================================
RL001    contraction-hazard    no ``a*x + b`` float multiply-add in device
                               code of numerics modules (FMA contraction
                               flips last-ulp argmin ties; PR 8)
RL002    unsafe-unroll         ``lax.scan(..., unroll>1)`` only for
                               contraction-free (gather/add/argmin) bodies
RL003    host-sync-in-device   no ``.item()`` / ``float(tracer)`` /
                               ``np.asarray`` / ``device_get`` /
                               ``block_until_ready`` inside jit/scan regions
RL004    frozen-mutation       ``object.__setattr__`` only in
                               ``__post_init__`` or blessed cache slots;
                               pytree dataclasses must be frozen
RL005    clock-hygiene         never *accumulate* into a clock — stamp it
                               from the authoritative float64 host clock
RL006    dispatch-accounting   solver entry points thread
                               ``meta["dispatches"]`` / ``n_routings``
=======  ====================  ==============================================

Suppression::

    bad_expr()  # repro-lint: disable=RL001 -- one-line justification

A pragma without a ``-- reason`` (or naming an unknown code) is itself an
error (RL000).  The analyzer is pure stdlib ``ast`` — no runtime imports of
the linted code, no new dependencies.
"""
from __future__ import annotations

from .engine import (Violation, lint_paths, lint_source, registered_rules,
                     run_cli)
from . import rules as _rules  # noqa: F401  (registers the rule families)

__all__ = ["Violation", "lint_paths", "lint_source", "registered_rules",
           "run_cli"]
