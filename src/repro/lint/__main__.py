"""``python -m repro.lint`` entry point."""
import sys

from repro.lint import run_cli

if __name__ == "__main__":
    sys.exit(run_cli())
