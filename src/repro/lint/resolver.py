"""Device-region resolver: which functions get traced into jax programs.

RL001-RL003 must fire only in *device* code — arithmetic and host syncs in
eager host drivers are fine (eager ops never cross-fuse, and host drivers
are allowed to sync).  A function is device-reachable when:

* it is decorated with ``jax.jit`` (directly, via ``functools.partial(
  jax.jit, ...)``, or through ``jax.jit(...)`` as an expression decorator),
  ``jax.vmap``, ``jax.pmap``, ``jax.grad``/``value_and_grad``,
  ``jax.checkpoint``/``remat``, or a Pallas ``pallas_call``; or
* it is passed (possibly wrapped in ``functools.partial``) as a function
  argument to ``lax.scan`` / ``lax.while_loop`` / ``lax.fori_loop`` /
  ``lax.cond`` / ``lax.switch`` / ``lax.map`` / ``lax.associative_scan`` /
  ``jax.jit`` / ``jax.vmap`` / ``pl.pallas_call`` / ``jax.custom_vjp`` —
  these primitives *always trace* their callee, even from eager code; or
* it is defined inside, or called (module-locally, by name) from, a
  function that is itself device-reachable.

The call graph is module-local and name-based on purpose: a lint pass must
not import the code it checks, and cross-module device entry points
(``ops.minplus_closure`` & co.) are jit-decorated in their own module, so
each file's regions resolve locally.  Name collisions over-approximate
(every local def sharing the name is marked), which for a linter errs on
the side of checking more code.
"""
from __future__ import annotations

import ast

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# Call targets whose function-valued arguments are traced.
_TRACING_CALLS = {
    "scan", "while_loop", "fori_loop", "cond", "switch", "map",
    "associative_scan", "jit", "vmap", "pmap", "grad", "value_and_grad",
    "checkpoint", "remat", "pallas_call", "custom_vjp", "custom_jvp",
}

# Decorator heads that make the decorated function device code.
_TRACING_DECORATORS = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "checkpoint", "remat",
    "custom_vjp", "custom_jvp", "pallas_call", "kernel",
}


def call_head(node: ast.AST) -> str | None:
    """Rightmost name of a call target: ``jax.lax.scan`` -> ``scan``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _unwrap_partial(node: ast.AST) -> list[ast.AST]:
    """``functools.partial(f, ...)`` -> ``[f]``; anything else -> [node]."""
    if isinstance(node, ast.Call) and call_head(node.func) == "partial":
        return list(node.args[:1])
    return [node]


class DeviceRegionResolver:
    """Marks every function def in one module as device-reachable or host."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        self._defs_by_name: dict[str, list[ast.AST]] = {}
        self._enclosing_def: dict[ast.AST, ast.AST | None] = {}
        self._device: set[ast.AST] = set()
        self._collect(tree, None)
        self._mark_roots()
        self._propagate()

    # -- construction -------------------------------------------------------
    def _collect(self, node: ast.AST, owner: ast.AST | None) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, FuncNode):
                name = getattr(child, "name", None)
                if name is not None:
                    self._defs_by_name.setdefault(name, []).append(child)
                self._enclosing_def[child] = owner
                self._collect(child, child)
            else:
                self._collect(child, owner)

    def _mark_roots(self) -> None:
        for fn in self._enclosing_def:
            if not isinstance(fn, ast.Lambda) and any(
                    self._is_tracing_decorator(d) for d in fn.decorator_list):
                self._device.add(fn)
        for call in ast.walk(self.tree):
            if not isinstance(call, ast.Call):
                continue
            if call_head(call.func) not in _TRACING_CALLS:
                continue
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                for cand in _unwrap_partial(arg):
                    if isinstance(cand, ast.Lambda):
                        self._device.add(cand)
                    elif isinstance(cand, ast.Name):
                        for d in self._defs_by_name.get(cand.id, ()):
                            self._device.add(d)

    @staticmethod
    def _is_tracing_decorator(dec: ast.AST) -> bool:
        if call_head(dec) in _TRACING_DECORATORS:
            return True
        if isinstance(dec, ast.Call):
            head = call_head(dec.func)
            if head in _TRACING_DECORATORS:
                return True
            if head == "partial":
                return any(call_head(a) in _TRACING_DECORATORS
                           for a in dec.args[:1])
        return False

    def _propagate(self) -> None:
        changed = True
        while changed:
            changed = False
            for fn in list(self._enclosing_def):
                if fn in self._device:
                    continue
                owner = self._enclosing_def[fn]
                if owner is not None and owner in self._device:
                    # defined inside a traced function => traced with it
                    self._device.add(fn)
                    changed = True
                    continue
            # calls from device functions mark their local callees
            for fn in list(self._device):
                for call in ast.walk(fn):
                    if not isinstance(call, ast.Call):
                        continue
                    head = call_head(call.func)
                    for d in self._defs_by_name.get(head or "", ()):
                        if d not in self._device:
                            self._device.add(d)
                            changed = True

    # -- queries ------------------------------------------------------------
    def is_device(self, fn: ast.AST) -> bool:
        return fn in self._device

    def device_functions(self) -> list[ast.AST]:
        """Device-reachable defs, outermost first (document order)."""
        return sorted(self._device, key=lambda n: (n.lineno, n.col_offset))

    def enclosing_function(self, fn: ast.AST) -> ast.AST | None:
        return self._enclosing_def.get(fn)
