"""The six rule families, each grounded in a real past regression.

Every rule documents the invariant it machine-checks and points back at the
docstring where the full story lives, so a lint failure is a teaching
moment, not a style nit.  Scoping:

* RL001/RL002 fire only in **numerics-contract modules**
  (:data:`NUMERICS_MODULES`) — the solver/kernel hot paths whose outputs
  are bit-parity-gated in CI.  Model code has no cross-program bit
  contract, so FMA contraction there is a non-event.
* RL003 fires in any *device region* (see
  :mod:`repro.lint.resolver`) of any module.
* RL004-RL006 are structural and fire everywhere under ``src/repro``.
"""
from __future__ import annotations

import ast
import re

from .engine import LintModule, register_rule
from .resolver import FuncNode, call_head

# Path fragments of modules whose device code carries a bit-parity contract
# (the fused<->ref solver gates in BENCH_solver.json / test_fused.py).
# Extend this list when a new subsystem grows a golden-bit contract.
NUMERICS_MODULES = ("repro/core/", "repro/kernels/")

# meta keys that satisfy the dispatch-accounting contract (RL006).
ACCOUNTING_KEYS = {"dispatches", "n_routings"}


# ---------------------------------------------------------------------------
# shared expression classifiers
# ---------------------------------------------------------------------------

_INT_NAME = re.compile(
    r"^(i|j|k|l|m|n|p|idx|axis|dim|ndim|rank|seq|ptr|off|offset|stride"
    r"|lmax|length|width|steps|src|dst|cur|nxt|prev|node|layer|hop|round"
    r"|order|routed|valid|keep|active|done|dead|mask|arrived"
    r"|num_\w+|n_\w+|max_\w+|min_\w+"
    r"|\w+_(?:idx|id|ids|index|i|j|k|n|len|count|size|dim|dims|steps|hops"
    r"|layers|jobs|nodes|rounds|windows|bp|ids32))$")

_INT_CALLS = {"int", "len", "ord", "range", "arange", "argmin", "argmax",
              "bit_length", "astype", "searchsorted", "argsort", "sum"}


def _intish(node: ast.AST) -> bool:
    """Conservatively: does this expression look integer/bool-valued?

    Integer multiply-adds cannot FMA-contract, so RL001/RL002 skip them.
    Unknown expressions report False (checked, not skipped).
    """
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, bool)) and not isinstance(
            node.value, float)
    if isinstance(node, ast.Name):
        return bool(_INT_NAME.match(node.id))
    if isinstance(node, ast.Attribute):
        return node.attr in ("shape", "ndim", "size") or bool(
            _INT_NAME.match(node.attr))
    if isinstance(node, ast.Subscript):
        return _intish(node.value)
    if isinstance(node, ast.Call):
        head = call_head(node.func)
        if head == "astype":
            return any("int" in ast.dump(a) or "bool" in ast.dump(a)
                       for a in node.args)
        return head in _INT_CALLS
    if isinstance(node, ast.BinOp):
        return _intish(node.left) and _intish(node.right)
    if isinstance(node, ast.UnaryOp):
        return _intish(node.operand)
    if isinstance(node, ast.Compare):
        return True          # comparisons are bool
    return False


def _contraction_sites(tree: ast.AST):
    """Yield Add/Sub BinOps fed by a float multiply — the FMA-contractible
    shape ``a*x + b`` / ``a + b*x`` (and the fused-multiply-subtract
    variants)."""
    for node in ast.walk(tree):
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, (ast.Add, ast.Sub))):
            continue
        for side in (node.left, node.right):
            if (isinstance(side, ast.BinOp) and isinstance(side.op, ast.Mult)
                    and not (_intish(side.left) and _intish(side.right))):
                yield node
                break


def _enclosing_function(module: LintModule, node: ast.AST) -> ast.AST | None:
    return module.enclosing(node, *FuncNode)


def _in_device_code(module: LintModule, node: ast.AST) -> bool:
    fn = _enclosing_function(module, node)
    return fn is not None and module.resolver.is_device(fn)


# ---------------------------------------------------------------------------
# RL001 — contraction hazard
# ---------------------------------------------------------------------------

@register_rule(
    "RL001", "contraction-hazard",
    "float multiply feeding an add/sub in parity-gated device code "
    "(FMA contraction flips last-ulp argmin ties)")
def rl001_contraction_hazard(module: LintModule):
    """PR 8: the split edge-weight form ``d*inv + Q*inv`` contracted into
    an FMA *or not* depending on the surrounding program, so the fused
    round scan, the standalone closure build, and eager execution each
    rounded the last ulp differently — flipping argmin ties and breaking
    bitwise solver parity (``lax.optimization_barrier`` does not stop the
    contraction on CPU).  The fix is algebraic: write the expression so
    the multiply is the LAST rounding — ``(d + Q) * inv`` — which no
    backend can contract.  See the ``layer_edge_weights`` docstring in
    ``src/repro/core/shortest_path.py`` for the full story.
    """
    if not module.in_module(*NUMERICS_MODULES):
        return
    for site in _contraction_sites(module.tree):
        if not _in_device_code(module, site):
            continue
        yield module.flag(
            site, "RL001",
            "contraction-hazard: float multiply feeding an add/sub in "
            "bit-parity-gated device code; FMA contraction is program-"
            "context dependent and flips last-ulp argmin ties (PR 8). "
            "Prefer the fused form `(a + b) * x` (multiply last) — see "
            "layer_edge_weights in src/repro/core/shortest_path.py — or "
            "suppress with a justification."), site


# ---------------------------------------------------------------------------
# RL002 — unsafe unroll
# ---------------------------------------------------------------------------

def _resolve_scan_body(module: LintModule, call: ast.Call) -> ast.AST | None:
    if not call.args:
        return None
    cand = call.args[0]
    if isinstance(cand, ast.Call) and call_head(cand.func) == "partial":
        cand = cand.args[0] if cand.args else None
    if isinstance(cand, ast.Lambda):
        return cand
    if isinstance(cand, ast.Name):
        for node in ast.walk(module.tree):
            if isinstance(node, FuncNode) and getattr(node, "name", None) \
                    == cand.id:
                return node
    return None


@register_rule(
    "RL002", "unsafe-unroll",
    "lax.scan(..., unroll>1) whose body carries a float multiply-add "
    "chain (unroll factor changes FMA contraction, hence golden values)")
def rl002_unsafe_unroll(module: LintModule):
    """PR 8: only contraction-free scan bodies may unroll.  Unrolling
    re-schedules the body's float ops, so LLVM contracts a multiply-add
    chain differently at each unroll factor — hoisting the DP forward
    scan's ``c_l * cinv`` changed golden values, while ``reconstruct_path``
    (gathers, adds, argmin — nothing to contract) unrolls bit-identically.
    See the ``reconstruct_path`` docstring in
    ``src/repro/core/shortest_path.py`` and ``_dp_back`` in
    ``src/repro/core/routing.py``.
    """
    if not module.in_module(*NUMERICS_MODULES):
        return
    for call in ast.walk(module.tree):
        if not (isinstance(call, ast.Call)
                and call_head(call.func) == "scan"):
            continue
        unroll = next((kw.value for kw in call.keywords
                       if kw.arg == "unroll"), None)
        if unroll is None:
            continue
        if isinstance(unroll, ast.Constant):
            if unroll.value in (1, False):
                continue
        else:
            yield module.flag(
                call, "RL002",
                "unsafe-unroll: non-literal unroll factor cannot be "
                "checked for contraction safety; use a literal (or "
                "suppress with a justification)"), call
            continue
        body = _resolve_scan_body(module, call)
        if body is None:
            yield module.flag(
                call, "RL002",
                "unsafe-unroll: cannot resolve the scan body to check it "
                "for float multiply-add chains; pass a local function or "
                "suppress with a justification"), call
            continue
        if any(True for _ in _contraction_sites(body)):
            yield module.flag(
                call, "RL002",
                "unsafe-unroll: scan body contains a float multiply-add "
                "chain; unrolling changes FMA contraction and hence "
                "golden values (PR 8). Only gather/add/argmin bodies like "
                "reconstruct_path may unroll — see its docstring in "
                "src/repro/core/shortest_path.py."), call


# ---------------------------------------------------------------------------
# RL003 — host sync in device code
# ---------------------------------------------------------------------------

_SYNC_ATTRS = {"item", "block_until_ready", "tolist", "copy_to_host_async"}
_NP_NAMES = {"np", "numpy", "onp"}
_NP_CONVERTERS = {"asarray", "array", "ascontiguousarray", "frombuffer"}
_SCALAR_CASTS = {"float", "int", "bool", "complex"}


@register_rule(
    "RL003", "host-sync-in-device",
    "host synchronization (.item()/float(tracer)/np.asarray/device_get/"
    "block_until_ready) lexically inside a jit/scan/while_loop region")
def rl003_host_sync(module: LintModule):
    """The fused solver's contract is exactly one dispatch and one host
    sync per solve (``meta["dispatches"] == 1``, asserted in
    tests/test_fused.py).  A host sync inside a function traced by
    ``jax.jit`` / ``lax.scan`` / ``lax.while_loop`` either fails at trace
    time (on a tracer) or — worse — silently executes at *trace* time on a
    constant and bakes a stale value into the compiled program.  Host
    reads belong in the driver, after the one explicit ``device_get``.
    """
    for call in ast.walk(module.tree):
        if not isinstance(call, ast.Call):
            continue
        if not _in_device_code(module, call):
            continue
        msg = None
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _SYNC_ATTRS:
            msg = f".{call.func.attr}() forces a host sync"
        elif call_head(call.func) == "device_get":
            msg = "jax.device_get forces a device->host transfer"
        elif (isinstance(call.func, ast.Attribute)
              and isinstance(call.func.value, ast.Name)
              and call.func.value.id in _NP_NAMES
              and call.func.attr in _NP_CONVERTERS):
            msg = (f"{call.func.value.id}.{call.func.attr} materializes on "
                   "host (a sync on traced values, a stale trace-time "
                   "constant otherwise)")
        elif (isinstance(call.func, ast.Name)
              and call.func.id in _SCALAR_CASTS and len(call.args) == 1
              and not _intish(call.args[0])
              and not isinstance(call.args[0], ast.Constant)):
            msg = (f"{call.func.id}(...) of a traced value forces a host "
                   "sync")
        if msg:
            yield module.flag(
                call, "RL003",
                f"host-sync-in-device: {msg} inside a jit/scan-traced "
                "function, breaking the one-dispatch-per-solve contract "
                "(meta[\"dispatches\"] == 1; see "
                "src/repro/core/greedy.py). Move the read to the host "
                "driver or suppress with a justification."), call


# ---------------------------------------------------------------------------
# RL004 — frozen-dataclass mutation
# ---------------------------------------------------------------------------

_MUTABLE_ANNOTATIONS = {"list", "dict", "set", "List", "Dict", "Set"}


def _annotation_head(node: ast.AST) -> str | None:
    if isinstance(node, ast.Subscript):
        return _annotation_head(node.value)
    if isinstance(node, (ast.Name, ast.Attribute)):
        return call_head(node)
    return None


@register_rule(
    "RL004", "frozen-mutation",
    "object.__setattr__ outside __post_init__/blessed cache slots, or a "
    "pytree-registered dataclass that is not frozen")
def rl004_frozen_mutation(module: LintModule):
    """Pytree dataclasses flow through jit boundaries by value; in-place
    mutation desynchronizes host copies from traced ones.  The blessed
    exceptions are ``__post_init__`` normalization (standard frozen-
    dataclass idiom) and the stamp-guarded engine cache slot documented in
    ``src/repro/core/completions.py`` ("the persistent engine cache") —
    a slot set via ``object.__setattr__`` precisely so
    ``dataclasses.replace`` never copies it; such sites carry a pragma.
    Mutable (list/dict/set) fields on pytree classes are flagged for the
    same reason: leaves must be immutable values or arrays.
    """
    for call in ast.walk(module.tree):
        if (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr == "__setattr__"
                and isinstance(call.func.value, ast.Name)
                and call.func.value.id == "object"):
            fn = _enclosing_function(module, call)
            if fn is not None and getattr(fn, "name", "") == "__post_init__":
                continue
            yield module.flag(
                call, "RL004",
                "frozen-mutation: object.__setattr__ outside "
                "__post_init__ mutates a frozen dataclass in place; only "
                "the stamp-guarded cache-slot sites (see 'the persistent "
                "engine cache' in src/repro/core/completions.py) may do "
                "this, each under a justified pragma."), call

    for cls in ast.walk(module.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        if not any(call_head(d) == "register_dataclass"
                   for d in cls.decorator_list):
            continue
        frozen = False
        for d in cls.decorator_list:
            if isinstance(d, ast.Call) and call_head(d.func) == "dataclass":
                frozen = any(kw.arg == "frozen"
                             and isinstance(kw.value, ast.Constant)
                             and kw.value.value is True
                             for kw in d.keywords)
        if not frozen:
            yield module.flag(
                cls, "RL004",
                f"frozen-mutation: pytree class {cls.name} is registered "
                "with jax.tree_util.register_dataclass but not declared "
                "@dataclasses.dataclass(frozen=True); pytrees flow "
                "through jit by value and must be immutable."), cls
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and _annotation_head(stmt.annotation) \
                    in _MUTABLE_ANNOTATIONS:
                yield module.flag(
                    stmt, "RL004",
                    f"frozen-mutation: pytree class {cls.name} declares a "
                    "mutable container field; pytree leaves must be "
                    "immutable values or arrays."), stmt


# ---------------------------------------------------------------------------
# RL005 — clock hygiene
# ---------------------------------------------------------------------------

_CLOCK_NAME = re.compile(r"^(clock|\w*_clock)$")


def _clockish(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return bool(_CLOCK_NAME.match(node.id))
    if isinstance(node, ast.Attribute):
        return bool(_CLOCK_NAME.match(node.attr))
    return False


def _unwrap_casts(node: ast.AST) -> ast.AST:
    while (isinstance(node, ast.Call) and node.args
           and call_head(node.func) in ("float", "float32", "float64",
                                        "asarray")):
        node = node.args[0]
    return node


def _accumulates_clock(value: ast.AST) -> bool:
    value = _unwrap_casts(value)
    if not (isinstance(value, ast.BinOp)
            and isinstance(value.op, (ast.Add, ast.Sub))):
        return False
    return any(_clockish(n) for n in ast.walk(value))


@register_rule(
    "RL005", "clock-hygiene",
    "arithmetic accumulation into a clock instead of stamping it from "
    "the authoritative float64 host clock")
def rl005_clock_hygiene(module: LintModule):
    """``state.clock`` is a float32 pytree leaf: accumulating it
    (``clock = clock + dt``) loses sub-second ticks past ~2^24 s and
    drifts from the host's float64 ``_now``.  Long-lived drivers keep ONE
    authoritative float64 clock host-side and *stamp* the device clock
    from it (``_stamp_clock`` in ``src/repro/serving/scheduler.py``;
    design note on ``advance`` in ``src/repro/core/state.py``).
    Accumulating into any ``clock``/``*_clock`` target is flagged;
    stamping (assigning a non-arithmetic value) is the sanctioned form.
    """
    for node in ast.walk(module.tree):
        if isinstance(node, ast.AugAssign) \
                and isinstance(node.op, (ast.Add, ast.Sub)) \
                and _clockish(node.target):
            yield module.flag(
                node, "RL005",
                "clock-hygiene: augmented accumulation into a clock; "
                "stamp it from the authoritative float64 host clock "
                "instead (see _stamp_clock in "
                "src/repro/serving/scheduler.py)."), node
        elif isinstance(node, ast.Assign):
            if any(_clockish(t) for t in node.targets) \
                    and _accumulates_clock(node.value):
                yield module.flag(
                    node, "RL005",
                    "clock-hygiene: clock assigned from clock arithmetic "
                    "(accumulation); stamp it from the authoritative "
                    "float64 host clock instead (see _stamp_clock in "
                    "src/repro/serving/scheduler.py)."), node
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == "clock" and _accumulates_clock(kw.value):
                    yield module.flag(
                        kw.value, "RL005",
                        "clock-hygiene: clock= built by accumulating a "
                        "previous clock; float32 accumulation loses "
                        "sub-second ticks past ~2^24 s — stamp from the "
                        "float64 host clock (see the advance docstring "
                        "in src/repro/core/state.py)."), kw.value


# ---------------------------------------------------------------------------
# RL006 — dispatch-count accounting
# ---------------------------------------------------------------------------

def _dict_literal_keys(node: ast.AST) -> set[str] | None:
    """String keys of a dict literal, or None when not statically a dict.

    A ``**spread`` entry makes the dict unresolvable (None): the spread
    may carry the accounting keys.
    """
    if not isinstance(node, ast.Dict):
        return None
    keys: set[str] = set()
    for k in node.keys:
        if k is None:                      # ** spread
            return None
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            keys.add(k.value)
    return keys


def _resolve_meta_keys(module: LintModule, call: ast.Call,
                       value: ast.AST) -> set[str] | None:
    """Best-effort static resolution of a ``meta=`` expression to its
    string keys: dict literals directly, names assigned from dict
    literals in the enclosing function, and calls to module-local helpers
    that return a dict literal.  None = unresolvable (give the benefit of
    the doubt)."""
    keys = _dict_literal_keys(value)
    if keys is not None:
        return keys
    if isinstance(value, ast.Name):
        fn = _enclosing_function(module, call)
        if fn is None:
            return None
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == value.id
                            for t in stmt.targets):
                return _dict_literal_keys(stmt.value)
        return None
    if isinstance(value, ast.Call) and isinstance(value.func, ast.Name):
        for node in ast.walk(module.tree):
            if isinstance(node, ast.FunctionDef) \
                    and node.name == value.func.id:
                for ret in ast.walk(node):
                    if isinstance(ret, ast.Return) and ret.value is not None:
                        return _dict_literal_keys(ret.value)
    return None


@register_rule(
    "RL006", "dispatch-accounting",
    "a solver building a Plan must thread dispatch accounting "
    "(meta['dispatches'] or meta['n_routings']) into plan.meta")
def rl006_dispatch_accounting(module: LintModule):
    """The one-dispatch-per-solve contract is only *checkable* because
    every solver reports its work: ``meta["dispatches"]`` (fused paths),
    ``meta["n_routings"]`` (host loops), with ``solvers.solve`` layering
    ``closure_builds``/``solve_s`` on top.  A solver that builds a Plan
    without accounting silently exits the regression net — so every
    ``Plan.from_order(...)`` call site outside the Plan class itself must
    pass a ``meta=`` whose statically-visible keys include one of
    ``dispatches`` / ``n_routings`` (unresolvable expressions pass; dict
    literals and local helpers are checked).
    """
    if "/tests/" in module.posix or module.posix.startswith("tests/") \
            or "/benchmarks/" in module.posix \
            or module.posix.startswith("benchmarks/"):
        return
    for call in ast.walk(module.tree):
        if not (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr == "from_order"
                and isinstance(call.func.value, ast.Name)
                and call.func.value.id == "Plan"):
            continue
        cls = module.enclosing(call, ast.ClassDef)
        if cls is not None and cls.name == "Plan":
            continue                        # (de)serialization internals
        meta = next((kw.value for kw in call.keywords if kw.arg == "meta"),
                    None)
        if meta is None:
            yield module.flag(
                call, "RL006",
                "dispatch-accounting: Plan built without meta=; solver "
                "entry points must thread meta['dispatches'] or "
                "meta['n_routings'] so the one-dispatch contract stays "
                "checkable (see fused_dispatch_count in "
                "src/repro/core/greedy.py)."), call
            continue
        keys = _resolve_meta_keys(module, call, meta)
        if keys is not None and not (keys & ACCOUNTING_KEYS):
            yield module.flag(
                call, "RL006",
                "dispatch-accounting: plan meta carries no dispatch "
                "accounting key (need one of "
                f"{sorted(ACCOUNTING_KEYS)}); see fused_dispatch_count "
                "in src/repro/core/greedy.py."), call
