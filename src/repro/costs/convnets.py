"""Per-layer cost profiles for the paper's own evaluation models.

VGG19 / ResNet34 at 224x224, FLOPs per the conv formula of Molchanov et
al. [14] (2 * K^2 * C_in * H_out * W_out * C_out, i.e. 2 FLOPs per MAC),
``d_jl`` = fp32 activation bytes of the layer output (post-pool where a pool
immediately follows).  Totals cross-check against the literature:
VGG19 ~= 39 GFLOP, ResNet34 ~= 7.3 GFLOP per image.
"""
from __future__ import annotations

import numpy as np


def _conv(cin, cout, hw, k=3, stride=1):
    hout = hw // stride
    flops = 2.0 * k * k * cin * cout * hout * hout
    return flops, hout


def vgg19_profile(*, batch: int = 1) -> tuple[np.ndarray, np.ndarray]:
    comp, data = [], [float(batch * 224 * 224 * 3 * 4)]
    hw, cin = 224, 3
    plan = [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)]
    for cout, reps in plan:
        for r in range(reps):
            f, _ = _conv(cin, cout, hw)
            comp.append(batch * f)
            out_hw = hw // 2 if r == reps - 1 else hw  # pool after last conv
            data.append(float(batch * out_hw * out_hw * cout * 4))
            cin = cout
        hw //= 2
    # FC 25088->4096->4096->1000
    for cin_fc, cout_fc in [(7 * 7 * 512, 4096), (4096, 4096), (4096, 1000)]:
        comp.append(batch * 2.0 * cin_fc * cout_fc)
        data.append(float(batch * cout_fc * 4))
    return np.asarray(comp, np.float64), np.asarray(data, np.float64)


def resnet34_profile(*, batch: int = 1) -> tuple[np.ndarray, np.ndarray]:
    comp, data = [], [float(batch * 224 * 224 * 3 * 4)]
    # conv1 7x7/2 then 3x3 maxpool/2
    f, _ = _conv(3, 64, 224, k=7, stride=2)
    comp.append(batch * f)
    data.append(float(batch * 56 * 56 * 64 * 4))
    hw, cin = 56, 64
    for cout, blocks in [(64, 3), (128, 4), (256, 6), (512, 3)]:
        for b in range(blocks):
            stride = 2 if (b == 0 and cout != 64) else 1
            f1, hout = _conv(cin, cout, hw, stride=stride)
            comp.append(batch * f1)
            data.append(float(batch * hout * hout * cout * 4))
            f2, _ = _conv(cout, cout, hout)
            comp.append(batch * f2)
            data.append(float(batch * hout * hout * cout * 4))
            cin, hw = cout, hout
    comp.append(batch * 2.0 * 512 * 1000)           # fc after global avgpool
    data.append(float(batch * 1000 * 4))
    return np.asarray(comp, np.float64), np.asarray(data, np.float64)
