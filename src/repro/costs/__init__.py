from . import convnets, lm

__all__ = ["convnets", "lm"]
