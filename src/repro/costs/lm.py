"""Per-layer (c_jl FLOPs, d_jl bytes) cost profiles for LM architectures.

This is the bridge between the model substrate and the paper's routing
framework: an inference request against an architecture becomes an
:class:`~repro.core.jobs.InferenceJob` whose layers are (embed, block_1, ...,
block_L, head).  d_jl is the inter-layer activation footprint actually
transferred in a layer-wise partition (hidden states; for the MLA arch the
latent KV story shows up here), c_jl counts forward FLOPs (2 per MAC).
"""
from __future__ import annotations

import numpy as np

from repro.models.model import ModelConfig


def _attn_flops(cfg: ModelConfig, b: int, s: int) -> float:
    hd = cfg.head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    d = cfg.d_model
    if cfg.use_mla:
        r, qr, qk, vd = (cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                         cfg.qk_nope_head_dim, cfg.v_head_dim)
        proj = d * (cfg.q_lora_rank or d) + (cfg.q_lora_rank or 0) * h * (qk + qr) \
            + d * (r + qr) + r * h * (qk + vd) + h * vd * d
        score = s * h * (qk + qr) + s * h * vd
    else:
        proj = d * h * hd + 2 * d * kv * hd + h * hd * d
        score = s * h * hd * 2
    return 2.0 * b * s * (proj + score)


def _ffn_flops(cfg: ModelConfig, b: int, s: int) -> float:
    d = cfg.d_model
    if cfg.moe_num_experts > 0:
        routed = 3 * d * cfg.moe_d_ff * cfg.moe_top_k
        shared = 3 * d * cfg.moe_d_ff * cfg.moe_num_shared
        router = d * cfg.moe_num_experts
        return 2.0 * b * s * (routed + shared + router)
    if cfg.family == "ssm":
        hd = d // cfg.num_heads
        return 2.0 * b * s * (4 * d * d + cfg.num_heads * hd * hd * 3)
    if cfg.family == "hybrid":
        inner = cfg.num_heads * cfg.mamba_headdim
        return 2.0 * b * s * (d * (2 * inner + 2 * cfg.ssm_state)
                              + inner * cfg.ssm_state * 2 + inner * d)
    return 2.0 * b * s * 3 * d * cfg.d_ff


def cost_profile(cfg: ModelConfig, *, seq_len: int, batch: int = 1,
                 act_bytes: int = 2) -> tuple[np.ndarray, np.ndarray]:
    """Returns (comp [L], data [L+1]) for a b x s inference of this arch.

    Layers: embed, block_1..block_L, head => L = num_layers + 2.
    data[0] = input token ids; data[i] = hidden state between layers;
    data[-1] = predicted token ids delivered to the destination.
    """
    b, s, d = batch, seq_len, cfg.d_model
    hidden = float(b * s * d * act_bytes)
    comp = []
    comp.append(2.0 * b * s * d)  # embedding gather + scale
    for _ in range(cfg.num_layers):
        blk = _ffn_flops(cfg, b, s)
        if cfg.family not in ("ssm",):
            blk += _attn_flops(cfg, b, s)
        comp.append(blk)
    comp.append(2.0 * b * s * d * cfg.padded_vocab)  # unembed
    # L+1 data entries: input ids, embed out, block_1..L outs, predicted ids
    data = [float(b * s * 4)] + [hidden] * (cfg.num_layers + 1) + [float(b * s * 4)]
    assert len(data) == len(comp) + 1
    return np.asarray(comp, np.float64), np.asarray(data, np.float64)
