"""Zamba2-style hybrid: Mamba2 backbone + a single shared attention block.

Every ``attn_every`` Mamba2 layers, one *shared-weight* transformer block
(full attention + MLP) runs; each invocation is a distinct attention
instance (own KV cache) over shared parameters.  Mamba2 layers are grouped
[n_groups, attn_every, ...] so the whole model is a scan over groups with an
inner scan over layers — the shared block's params are closed over.

Simplification vs. the released Zamba2 (noted in DESIGN.md): Zamba2
alternates two shared blocks and adds per-invocation LoRA deltas; we use one
shared block without LoRA, which preserves the memory/compute character the
routing cost profiles and roofline care about.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as cm
from .ssm import init_mamba2, mamba2_state, _mamba2_step


def _shared_block_init(key, cfg):
    ks = jax.random.split(key, 5)
    return {
        "attn": cm.init_attention(ks[0], cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.head_dim, cfg.dtype),
        "mlp": cm.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype),
        "ln1": cm.init_norm(ks[2], cfg.d_model, "rmsnorm", cfg.dtype),
        "ln2": cm.init_norm(ks[3], cfg.d_model, "rmsnorm", cfg.dtype),
    }


def init(key, cfg):
    kb, ks, ke = jax.random.split(key, 3)
    assert cfg.num_layers % cfg.attn_every == 0
    blocks = jax.vmap(lambda k: init_mamba2(k, cfg))(
        jax.random.split(kb, cfg.num_layers))
    return {
        "mamba": blocks,                                # stacked [L, ...]
        "shared": _shared_block_init(ks, cfg),
        "embed": cm.init_embed(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "ln_f": cm.init_norm(ke, cfg.d_model, "rmsnorm", cfg.dtype),
    }


def _group_params(cfg, params):
    n_groups = cfg.num_layers // cfg.attn_every
    return jax.tree.map(
        lambda x: x.reshape((n_groups, cfg.attn_every) + x.shape[1:]),
        params["mamba"])


def _shared_apply(cfg, p, h, positions, kv_cache=None, cache_pos=None):
    x = cm.apply_norm(p["ln1"], h, "rmsnorm")
    attn_out, new_cache = cm.attention(
        p["attn"], x, positions, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        kv_cache=kv_cache, cache_pos=cache_pos)
    h = h + attn_out
    h = h + cm.mlp(p["mlp"], cm.apply_norm(p["ln2"], h, "rmsnorm"))
    return h, new_cache


def forward(cfg, params, tokens, *, remat=True):
    h = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    h = cm.maybe_shard(h, cfg.dp_axes, None, None)
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :]
    grouped = _group_params(cfg, params)

    def group_body(h, g_params):
        h, _ = _shared_apply(cfg, params["shared"], h, positions)

        def layer_body(h_seq, p):
            st = mamba2_state(cfg, b)

            def time_body(carry, x_t):
                new_st, out = _mamba2_step(p, carry, x_t, cfg)
                return new_st, out

            xn = cm.apply_norm(p["ln"], h_seq, "rmsnorm")
            _, out = jax.lax.scan(time_body, st, jnp.swapaxes(xn, 0, 1))
            return h_seq + jnp.swapaxes(out, 0, 1), None

        if remat:
            layer_body = cm.remat_wrap(layer_body, cfg)
        if cfg.scan_layers:
            h, _ = jax.lax.scan(layer_body, h, g_params)
        else:
            for i in range(cfg.attn_every):
                h, _ = layer_body(h, jax.tree.map(lambda x: x[i], g_params))
        return h, None

    if cfg.scan_layers:
        h, _ = jax.lax.scan(group_body, h, grouped)
    else:
        for g in range(cfg.num_layers // cfg.attn_every):
            h, _ = group_body(h, jax.tree.map(lambda x: x[g], grouped))
    h = cm.apply_norm(params["ln_f"], h, "rmsnorm")
    return cm.unembed(params["embed"], h).astype(jnp.float32)


def init_cache(cfg, batch, max_len):
    n_groups = cfg.num_layers // cfg.attn_every
    kv = {"k": jnp.zeros((n_groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
          "v": jnp.zeros((n_groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim), cfg.dtype)}
    ssm = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape),
        mamba2_state(cfg, batch))
    return {"kv": kv, "ssm": ssm}


def decode_step(cfg, params, cache, tokens, pos):
    x = cm.embed(params["embed"], tokens).astype(cfg.dtype)   # [B, 1, D]
    x = cm.maybe_shard(x, cfg.dp_axes, None, None)
    grouped = _group_params(cfg, params)
    n_groups = cfg.num_layers // cfg.attn_every
    ssm_grouped = jax.tree.map(
        lambda s: s.reshape((n_groups, cfg.attn_every) + s.shape[1:]),
        cache["ssm"])
    positions = jnp.full((1, 1), pos, jnp.int32)

    def group_body(x, xs):
        g_params, g_kv, g_ssm = xs
        x, new_kv = _shared_apply(cfg, params["shared"], x, positions,
                                  kv_cache=g_kv, cache_pos=pos)

        def layer_body(x, xs_l):
            p, st = xs_l
            xn = cm.apply_norm(p["ln"], x[:, 0], "rmsnorm")
            new_st, out = _mamba2_step(p, st, xn, cfg)
            return x + out[:, None], new_st

        if cfg.scan_layers:
            x, new_ssm = jax.lax.scan(layer_body, x, (g_params, g_ssm))
        else:
            sts = []
            for i in range(cfg.attn_every):
                x, st_i = layer_body(
                    x, jax.tree.map(lambda t: t[i], (g_params, g_ssm)))
                sts.append(st_i)
            new_ssm = jax.tree.map(lambda *xs: jnp.stack(xs), *sts)
        return x, (new_kv, new_ssm)

    if cfg.scan_layers:
        x, (new_kv, new_ssm) = jax.lax.scan(
            group_body, x, (grouped, cache["kv"], ssm_grouped))
    else:
        kvs, ssms = [], []
        for g in range(cfg.num_layers // cfg.attn_every):
            xs_g = jax.tree.map(lambda t: t[g],
                                (grouped, cache["kv"], ssm_grouped))
            x, (kv_g, ssm_g) = group_body(x, xs_g)
            kvs.append(kv_g)
            ssms.append(ssm_g)
        new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
        new_ssm = jax.tree.map(lambda *xs: jnp.stack(xs), *ssms)
    new_ssm = jax.tree.map(
        lambda s: s.reshape((cfg.num_layers,) + s.shape[2:]), new_ssm)
    x = cm.apply_norm(params["ln_f"], x, "rmsnorm")
    logits = cm.unembed(params["embed"], x[:, -1])
    return logits.astype(jnp.float32), {"kv": new_kv, "ssm": new_ssm}
