"""Unified model API: one config dataclass + family dispatch.

Every architecture exposes the same four entry points, which is what the
launcher, dry-run, serving engine and smoke tests program against:

    init_params(cfg, key)                  -> params pytree
    loss_fn(cfg, params, batch)            -> scalar loss   (train shapes)
    prefill_logits(cfg, params, batch)     -> [B, S, vocab] (prefill shapes)
    init_cache(cfg, batch, max_len)        -> decode cache pytree
    serve_step(cfg, params, cache, batch)  -> (logits [B, vocab], cache)

``batch`` is a dict: 'tokens'/'labels' [B, S] always; 'frames' [B, T, D] for
the audio stub (whisper), 'patches' [B, P, D] for the vision stub (phi-3v),
'pos' (scalar) + optionally 'enc_out' during decode.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import common as cm
from . import transformer, ssm, hybrid, encdec


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads
    norm: str = "rmsnorm"
    tie_embeddings: bool = True
    rope_theta: float = 1e4
    # attention pattern
    sliding_window: int = 0
    local_global_pattern: int = 0    # gemma3: 6 => 5 local + 1 global
    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # MLA
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # SSM / hybrid
    ssm_state: int = 0
    mamba_headdim: int = 64
    mamba_dconv: int = 4
    attn_every: int = 0
    # enc-dec / stubs
    dec_layers: int = 0
    num_frames: int = 0              # audio stub frontend output length
    num_patches: int = 0             # vision stub patch count
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # True: lax.scan over stacked layers (compact HLO, fast compile).
    # False: unrolled python loop — the dry-run uses this so cost_analysis
    # and the collective audit see every layer (XLA cost analysis visits a
    # while-loop body exactly once; see EXPERIMENTS.md §Dry-run).
    scan_layers: bool = True
    # ---- performance knobs (EXPERIMENTS.md §Perf; defaults = baseline) ----
    # query-chunked attention: bound the live score tensor to
    # [B, H, chunk, T] instead of [B, H, S, S] (flash-attention blocking at
    # the XLA level; the Pallas kernel variant lives in kernels/flash.py).
    attn_chunk_q: int = 0
    # remat policy: 'full' (recompute everything) | 'dots' (save matmul
    # outputs, recompute elementwise only)
    remat_policy: str = "full"
    # activation batch-sharding anchor axes (layout policy; dp_only layout
    # folds 'model' into the batch axes for TP-unfriendly small models)
    dp_axes: tuple = ("pod", "data")
    # constrain the MoE dispatch buffer to expert-parallel sharding
    moe_ep_shard: bool = False
    # attention implementation for causal prefill/train: 'xla' (einsum
    # softmax) or 'flash' (Pallas kernel, kernels/flash.py — scores stay in
    # VMEM; requires full causal attention, i.e. no sliding window)
    attn_impl: str = "xla"
    # GQA contraction via grouped einsum (no materialized K/V repeat)
    gqa_grouped: bool = False
    # MoE dispatch sorted/bucketed per data shard under shard_map (plain
    # data-parallel MoE) instead of a global sort GSPMD must all-gather
    moe_local_dispatch: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return cm.pad_vocab(self.vocab_size)

    @property
    def sub_quadratic(self) -> bool:
        """Can this config decode at 500k context? (SSM / hybrid families)."""
        return self.family in ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Any:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init(key, cfg)
    if cfg.family == "ssm":
        return ssm.xlstm_init(key, cfg)
    if cfg.family == "hybrid":
        return hybrid.init(key, cfg)
    if cfg.family == "encdec":
        return encdec.init(key, cfg)
    raise ValueError(cfg.family)


def prefill_logits(cfg: ModelConfig, params, batch) -> jax.Array:
    tokens = batch["tokens"]
    if cfg.family in ("dense", "moe"):
        return transformer.forward(cfg, params, tokens, remat=cfg.remat)
    if cfg.family == "vlm":
        return transformer.forward(cfg, params, tokens,
                                   extra_embeds=batch.get("patches"),
                                   remat=cfg.remat)
    if cfg.family == "ssm":
        return ssm.xlstm_forward(cfg, params, tokens, remat=cfg.remat)
    if cfg.family == "hybrid":
        return hybrid.forward(cfg, params, tokens, remat=cfg.remat)
    if cfg.family == "encdec":
        return encdec.forward(cfg, params, batch["frames"], tokens, remat=cfg.remat)
    raise ValueError(cfg.family)


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    """Causal-LM cross entropy (labels = next tokens, -1 = masked)."""
    logits = prefill_logits(cfg, params, batch)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return ssm.xlstm_state(cfg, batch)
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch, max_len)
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_len)
    raise ValueError(cfg.family)


def serve_step(cfg: ModelConfig, params, cache, batch):
    """One decode step: batch = {'tokens': [B,1], 'pos': scalar, ...}."""
    tokens, pos = batch["tokens"], batch["pos"]
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.decode_step(cfg, params, cache, tokens, pos)
    if cfg.family == "ssm":
        return ssm.xlstm_decode_step(cfg, params, cache, tokens, pos)
    if cfg.family == "hybrid":
        return hybrid.decode_step(cfg, params, cache, tokens, pos)
    if cfg.family == "encdec":
        return encdec.decode_step(cfg, params, cache, tokens, pos, batch["enc_out"])
    raise ValueError(cfg.family)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Params touched per token (MoE counts top-k + shared experts only)."""
    total = param_count(params)
    if cfg.moe_num_experts <= 0:
        return total
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed_total = cfg.num_layers * cfg.moe_num_experts * per_expert
    routed_active = cfg.num_layers * cfg.moe_top_k * per_expert
    return total - routed_total + routed_active
