"""Decoder-only transformer LM covering the dense / MoE / MLA families.

One implementation parameterized by :class:`~repro.models.model.ModelConfig`:
  * dense llama-style blocks (olmo-1b, smollm-135m, minicpm-2b, phi-3 backbone)
  * gemma3-style 5:1 local:global sliding-window attention
  * MoE blocks with shared + routed experts, top-k token-choice routing with
    static capacity (olmoe-1b-7b, deepseek-v2-236b)
  * MLA (multi-head latent attention) with absorbed-form decode (deepseek-v2)

Blocks are stacked [L, ...] and scanned; per-layer heterogeneity (local vs
global attention, dense vs MoE) rides along as scanned flag vectors.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import common as cm
from .moe import init_moe, moe_block
from .mla import init_mla, mla_attention, init_mla_cache


def _block_init(key, cfg):
    ks = jax.random.split(key, 8)
    p = {}
    hd = cfg.head_dim
    if cfg.use_mla:
        p["attn"] = init_mla(ks[0], cfg)
    else:
        p["attn"] = cm.init_attention(ks[0], cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, hd, cfg.dtype)
    p["ln1"] = cm.init_norm(ks[1], cfg.d_model, cfg.norm, cfg.dtype)
    p["ln2"] = cm.init_norm(ks[2], cfg.d_model, cfg.norm, cfg.dtype)
    if cfg.moe_num_experts > 0:
        p["moe"] = init_moe(ks[3], cfg)
    else:
        p["mlp"] = cm.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def init(key, cfg):
    kb, ke = jax.random.split(key)
    blocks = jax.vmap(lambda k: _block_init(k, cfg))(
        jax.random.split(kb, cfg.num_layers))
    params = {
        "blocks": blocks,
        "embed": cm.init_embed(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype,
                               tie=cfg.tie_embeddings),
        "ln_f": cm.init_norm(ke, cfg.d_model, cfg.norm, cfg.dtype),
    }
    return params


def _layer_windows(cfg):
    """[L] per-layer attention window (0 = full/global)."""
    if cfg.local_global_pattern <= 0:
        return jnp.full((cfg.num_layers,), cfg.sliding_window, jnp.int32)
    # gemma3: (pattern-1) local layers then 1 global, repeating
    l = jnp.arange(cfg.num_layers)
    is_global = (l % cfg.local_global_pattern) == (cfg.local_global_pattern - 1)
    return jnp.where(is_global, 0, cfg.sliding_window).astype(jnp.int32)


def _block_apply(cfg, p, h, positions, window, kv_cache=None, cache_pos=None):
    x = cm.apply_norm(p["ln1"], h, cfg.norm)
    if cfg.use_mla:
        attn_out, new_cache = mla_attention(p["attn"], x, positions, cfg,
                                            kv_cache=kv_cache, cache_pos=cache_pos)
    else:
        # window is a traced per-layer scalar; full attention applies a
        # windowed mask only when static sliding_window > 0 for this config.
        win = cfg.sliding_window if cfg.local_global_pattern <= 0 \
            else None  # dynamic: handled via mask select below
        if win is None:
            # build both masks, select by the scanned flag (compiles to one
            # fused select; avoids retracing per layer)
            attn_out, new_cache = _dyn_window_attention(
                cfg, p["attn"], x, positions, window, kv_cache, cache_pos)
        else:
            attn_out, new_cache = cm.attention(
                p["attn"], x, positions, n_heads=cfg.num_heads,
                n_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, window=win,
                kv_cache=kv_cache, cache_pos=cache_pos,
                chunk_q=cfg.attn_chunk_q, unroll_chunks=not cfg.scan_layers,
                attn_impl=cfg.attn_impl, grouped=cfg.gqa_grouped)
    h = h + attn_out
    x = cm.apply_norm(p["ln2"], h, cfg.norm)
    if cfg.moe_num_experts > 0:
        h = h + moe_block(p["moe"], x, cfg)
    else:
        h = h + cm.mlp(p["mlp"], x)
    return h, new_cache


def _dyn_window_attention(cfg, p, x, positions, window, kv_cache, cache_pos):
    """Attention whose sliding window is a traced per-layer scalar.

    The mask is built dynamically: key positions within ``window`` of the
    query when window > 0, unrestricted otherwise.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    if kv_cache is None:
        if cfg.attn_chunk_q > 0 and s % cfg.attn_chunk_q == 0 \
                and s > cfg.attn_chunk_q:
            out = cm._sdpa_chunked(q, k, v, window=window,
                                   chunk=cfg.attn_chunk_q,
                                   unroll=not cfg.scan_layers)
        else:
            qpos = jnp.arange(s)[:, None]
            kpos = jnp.arange(s)[None, :]
            mask = kpos <= qpos
            mask &= (window <= 0) | (kpos > qpos - window)
            out = cm._sdpa(q, k, v, mask[None, None])
        new_cache = None
    else:
        t = kv_cache["k"].shape[1]
        ck = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_pos, axis=1)
        kpos = jnp.arange(t)[None, :]
        valid = kpos <= (cache_pos + s - 1)
        valid &= (window <= 0) | (kpos > cache_pos + s - 1 - window)
        out = cm._sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype),
                       valid[None, None], grouped=cfg.gqa_grouped)
        new_cache = {"k": ck, "v": cv}
    return out.reshape(b, s, cfg.num_heads * hd) @ p["wo"], new_cache


def forward(cfg, params, tokens, *, extra_embeds=None, remat=True):
    """tokens: [B, S] -> logits [B, S, vocab].

    ``extra_embeds`` ([B, P, D]) are prepended (phi-3-vision patch stubs);
    logits for those positions are discarded.
    """
    h = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    n_extra = 0
    if extra_embeds is not None:
        n_extra = extra_embeds.shape[1]
        h = jnp.concatenate([extra_embeds.astype(cfg.dtype), h], axis=1)
    h = cm.maybe_shard(h, cfg.dp_axes, None, None)
    positions = jnp.arange(h.shape[1])[None, :]
    windows = _layer_windows(cfg)

    def body(h, xs):
        p, w = xs
        h, _ = _block_apply(cfg, p, h, positions, w)
        return h, None

    if remat:
        body = cm.remat_wrap(body, cfg)
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, (params["blocks"], windows))
    else:
        for i in range(cfg.num_layers):
            p_i = jax.tree.map(lambda x: x[i], params["blocks"])
            h, _ = body(h, (p_i, windows[i]))
    h = cm.apply_norm(params["ln_f"], h, cfg.norm)
    if n_extra:
        h = h[:, n_extra:]
    logits = cm.unembed(params["embed"], h)
    return logits.astype(jnp.float32)


def init_cache(cfg, batch, max_len):
    """Stacked per-layer KV cache pytree (latent cache for MLA)."""
    if cfg.use_mla:
        return init_mla_cache(cfg, batch, max_len)
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def decode_step(cfg, params, cache, tokens, pos):
    """tokens: [B, 1]; pos: scalar int32 — returns (logits [B, vocab], cache)."""
    h = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    h = cm.maybe_shard(h, cfg.dp_axes, None, None)
    positions = jnp.full((1, 1), pos, jnp.int32)
    windows = _layer_windows(cfg)

    def body(h, xs):
        p, w, layer_cache = xs
        h, new_cache = _block_apply(cfg, p, h, positions, w,
                                    kv_cache=layer_cache, cache_pos=pos)
        return h, new_cache

    if cfg.scan_layers:
        h, new_cache = jax.lax.scan(body, h, (params["blocks"], windows, cache))
    else:
        outs = []
        for i in range(cfg.num_layers):
            p_i = jax.tree.map(lambda x: x[i], params["blocks"])
            c_i = jax.tree.map(lambda x: x[i], cache)
            h, nc = body(h, (p_i, windows[i], c_i))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    h = cm.apply_norm(params["ln_f"], h, cfg.norm)
    logits = cm.unembed(params["embed"], h[:, -1])
    return logits.astype(jnp.float32), new_cache
