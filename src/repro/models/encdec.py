"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings [B, T_enc, D] straight into the encoder.  The
encoder is bidirectional (no causal mask), the decoder is causal with
cross-attention to the encoder output; decode caches decoder self-attn K/V
and reuses the encoder states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as cm


def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 4)
    return {
        "attn": cm.init_attention(ks[0], cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.head_dim, cfg.dtype),
        "mlp": cm.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype, gated=False),
        "ln1": cm.init_norm(ks[2], cfg.d_model, "layernorm", cfg.dtype),
        "ln2": cm.init_norm(ks[3], cfg.d_model, "layernorm", cfg.dtype),
    }


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 6)
    p = _enc_block_init(key, cfg)
    p["xattn"] = cm.init_cross_attention(ks[4], cfg.d_model, cfg.num_heads,
                                         cfg.head_dim, cfg.dtype)
    p["ln_x"] = cm.init_norm(ks[5], cfg.d_model, "layernorm", cfg.dtype)
    return p


def init(key, cfg):
    ke, kd, kt = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _enc_block_init(k, cfg))(
        jax.random.split(ke, cfg.num_layers))
    dec = jax.vmap(lambda k: _dec_block_init(k, cfg))(
        jax.random.split(kd, cfg.dec_layers))
    return {
        "enc": enc, "dec": dec,
        "embed": cm.init_embed(kt, cfg.padded_vocab, cfg.d_model, cfg.dtype),
        "ln_enc": cm.init_norm(kt, cfg.d_model, "layernorm", cfg.dtype),
        "ln_dec": cm.init_norm(kt, cfg.d_model, "layernorm", cfg.dtype),
    }


def _sinusoid(s, d):
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def encode(cfg, params, frames, *, remat=True):
    """frames: [B, T, D] precomputed frame embeddings (conv frontend stub)."""
    b, t, d = frames.shape
    h = frames.astype(cfg.dtype) + _sinusoid(t, d).astype(cfg.dtype)[None]
    h = cm.maybe_shard(h, cfg.dp_axes, None, None)

    def body(h, p):
        x = cm.apply_norm(p["ln1"], h, "layernorm")
        full = jnp.ones((1, 1, t, t), bool)
        q = (x @ p["attn"]["wq"]).reshape(b, t, cfg.num_heads, cfg.head_dim)
        k = (x @ p["attn"]["wk"]).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
        v = (x @ p["attn"]["wv"]).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
        out = cm._sdpa(q, k, v, full)
        h = h + out.reshape(b, t, -1) @ p["attn"]["wo"]
        h = h + cm.mlp(p["mlp"], cm.apply_norm(p["ln2"], h, "layernorm"),
                       gated=False, act=jax.nn.gelu)
        return h, None

    if remat:
        body = cm.remat_wrap(body, cfg)
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["enc"])
    else:
        for i in range(cfg.num_layers):
            h, _ = body(h, jax.tree.map(lambda x: x[i], params["enc"]))
    return cm.apply_norm(params["ln_enc"], h, "layernorm")


def _dec_block(cfg, p, h, enc_out, positions, kv_cache=None, cache_pos=None):
    x = cm.apply_norm(p["ln1"], h, "layernorm")
    attn_out, new_cache = cm.attention(
        p["attn"], x, positions, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
        head_dim=cfg.head_dim, use_rope=False,
        kv_cache=kv_cache, cache_pos=cache_pos)
    h = h + attn_out
    x = cm.apply_norm(p["ln_x"], h, "layernorm")
    h = h + cm.cross_attention(p["xattn"], x, enc_out,
                               n_heads=cfg.num_heads, head_dim=cfg.head_dim)
    h = h + cm.mlp(p["mlp"], cm.apply_norm(p["ln2"], h, "layernorm"),
                   gated=False, act=jax.nn.gelu)
    return h, new_cache


def decode(cfg, params, tokens, enc_out, *, remat=True):
    b, s = tokens.shape
    h = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    h = h + _sinusoid(s, cfg.d_model).astype(cfg.dtype)[None]
    h = cm.maybe_shard(h, cfg.dp_axes, None, None)
    positions = jnp.arange(s)[None, :]

    def body(h, p):
        h, _ = _dec_block(cfg, p, h, enc_out, positions)
        return h, None

    if remat:
        body = cm.remat_wrap(body, cfg)
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["dec"])
    else:
        for i in range(cfg.dec_layers):
            h, _ = body(h, jax.tree.map(lambda x: x[i], params["dec"]))
    h = cm.apply_norm(params["ln_dec"], h, "layernorm")
    return cm.unembed(params["embed"], h).astype(jnp.float32)


def forward(cfg, params, frames, tokens, *, remat=True):
    return decode(cfg, params, tokens, encode(cfg, params, frames, remat=remat),
                  remat=remat)


def init_cache(cfg, batch, max_len):
    shape = (cfg.dec_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def decode_step(cfg, params, cache, tokens, pos, enc_out):
    b = tokens.shape[0]
    h = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    h = cm.maybe_shard(h, cfg.dp_axes, None, None)
    h = h + jax.lax.dynamic_slice_in_dim(
        _sinusoid(cache["k"].shape[2], cfg.d_model), pos, 1, 0).astype(cfg.dtype)[None]
    positions = jnp.full((1, 1), pos, jnp.int32)

    def body(h, xs):
        p, layer_cache = xs
        h, new_cache = _dec_block(cfg, p, h, enc_out, positions,
                                  kv_cache=layer_cache, cache_pos=pos)
        return h, new_cache

    if cfg.scan_layers:
        h, new_cache = jax.lax.scan(body, h, (params["dec"], cache))
    else:
        outs = []
        for i in range(cfg.dec_layers):
            h, nc = body(h, jax.tree.map(lambda x: x[i], (params["dec"], cache)))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    h = cm.apply_norm(params["ln_dec"], h, "layernorm")
    return cm.unembed(params["embed"], h[:, -1]).astype(jnp.float32), new_cache
