"""Recurrent families: xLSTM (sLSTM + mLSTM blocks) and Mamba2 (SSD).

All recurrences are ``lax.scan`` over time with explicit, exponentially
stabilized gates (log-space max-stabilizer m_t), so a single step doubles as
the decode step with O(1) state — which is why these archs run the
``long_500k`` shape that full-attention models cannot.

State conventions (per layer, stacked [L, ...] like the transformer blocks):
  mLSTM: C [B,H,hd,hd] matrix memory, n [B,H,hd] normalizer, m [B,H] stabilizer
  sLSTM: c/n [B,H,hd] scalar memory, m [B,H,hd]
  mamba2: h [B,H,P,N] state, conv tail [B,d_conv-1,conv_dim]
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import common as cm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 8)
    return {
        "wq": cm.dense_init(ks[0], d, d, cfg.dtype),
        "wk": cm.dense_init(ks[1], d, d, cfg.dtype),
        "wv": cm.dense_init(ks[2], d, d, cfg.dtype),
        "w_i": cm.dense_init(ks[3], d, h, cfg.dtype),
        "w_f": cm.dense_init(ks[4], d, h, cfg.dtype),
        "w_o": cm.dense_init(ks[5], d, d, cfg.dtype),
        "w_out": cm.dense_init(ks[6], d, d, cfg.dtype),
        "ln": cm.init_norm(ks[7], d, "rmsnorm", cfg.dtype),
    }


def mlstm_state(cfg, batch):
    h = cfg.num_heads
    hd = cfg.d_model // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def _mlstm_step(p, state, x_t, cfg):
    """x_t: [B, D] -> (new_state, h_t [B, D])."""
    b, d = x_t.shape
    h = cfg.num_heads
    hd = d // h
    q = (x_t @ p["wq"]).reshape(b, h, hd).astype(jnp.float32) / math.sqrt(hd)
    k = (x_t @ p["wk"]).reshape(b, h, hd).astype(jnp.float32) / math.sqrt(hd)
    v = (x_t @ p["wv"]).reshape(b, h, hd).astype(jnp.float32)
    log_i = (x_t @ p["w_i"]).astype(jnp.float32)               # [B, H]
    log_f = jax.nn.log_sigmoid((x_t @ p["w_f"]).astype(jnp.float32))
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    C = f_s[..., None, None] * state["C"] + i_s[..., None, None] * (
        v[..., :, None] * k[..., None, :])                    # [B,H,hd,hd]
    n = f_s[..., None] * state["n"] + i_s[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, q)), 1.0)
    h_t = (num / den[..., None]).reshape(b, d)
    o = jax.nn.sigmoid((x_t @ p["w_o"]).astype(jnp.float32))
    out = (o * h_t).astype(cfg.dtype) @ p["w_out"]
    return {"C": C, "n": n, "m": m_new}, out


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 7)
    return {
        "w_in": cm.dense_init(ks[0], d, 4 * d, cfg.dtype),     # i, f, z, o pre-acts
        "r": cm.truncated_normal(ks[1], (h, hd, 4 * hd), cfg.dtype,
                                 1.0 / math.sqrt(hd)),         # recurrent (block-diag)
        "w_out": cm.dense_init(ks[2], d, d, cfg.dtype),
        "ln": cm.init_norm(ks[3], d, "rmsnorm", cfg.dtype),
    }


def slstm_state(cfg, batch):
    h = cfg.num_heads
    hd = cfg.d_model // h
    return {
        "c": jnp.zeros((batch, h, hd), jnp.float32),
        "n": jnp.ones((batch, h, hd), jnp.float32),
        "m": jnp.zeros((batch, h, hd), jnp.float32),
        "h": jnp.zeros((batch, h, hd), jnp.float32),
    }


def _slstm_step(p, state, x_t, cfg):
    b, d = x_t.shape
    h = cfg.num_heads
    hd = d // h
    pre = (x_t @ p["w_in"]).reshape(b, h, 4 * hd).astype(jnp.float32)
    rec = jnp.einsum("bhi,hij->bhj", state["h"], p["r"].astype(jnp.float32))
    pre = pre + rec
    log_i, log_f_raw, z_raw, o_raw = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(log_f_raw)
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    c = f_s * state["c"] + i_s * jnp.tanh(z_raw)
    n = f_s * state["n"] + i_s
    h_t = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
    out = h_t.reshape(b, d).astype(cfg.dtype) @ p["w_out"]
    return {"c": c, "n": n, "m": m_new, "h": h_t}, out


# ---------------------------------------------------------------------------
# xLSTM model (alternating sLSTM / mLSTM blocks)
# ---------------------------------------------------------------------------

def xlstm_init(key, cfg):
    kb, ke = jax.random.split(key)
    keys = jax.random.split(kb, cfg.num_layers)
    # Uniform param structure across the scan: every block carries both
    # parameter sets; the scanned flag selects which path runs.
    def blk(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"m": init_mlstm(k1, cfg), "s": init_slstm(k2, cfg),
                "ln": cm.init_norm(k3, cfg.d_model, "rmsnorm", cfg.dtype)}
    blocks = jax.vmap(blk)(keys)
    return {"blocks": blocks,
            "embed": cm.init_embed(ke, cfg.padded_vocab, cfg.d_model, cfg.dtype),
            "ln_f": cm.init_norm(ke, cfg.d_model, "rmsnorm", cfg.dtype)}


def xlstm_state(cfg, batch):
    L = cfg.num_layers
    tile = lambda tree: jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), tree)
    return {"m": tile(mlstm_state(cfg, batch)), "s": tile(slstm_state(cfg, batch))}


def _xlstm_block(p, is_mlstm, state, x_t, cfg):
    xn = cm.apply_norm(p["ln"], x_t, "rmsnorm")
    new_m, out_m = _mlstm_step(p["m"], state["m"], xn, cfg)
    new_s, out_s = _slstm_step(p["s"], state["s"], xn, cfg)
    out = jnp.where(is_mlstm, out_m, out_s)
    sel = lambda a, b: jax.tree.map(
        lambda u, v: jnp.where(is_mlstm, u, v), a, b)
    return {"m": sel(new_m, state["m"]), "s": sel(state["s"], new_s)}, x_t + out


def xlstm_scan_tokens(cfg, params, h_seq):
    """h_seq: [B, S, D] embeddings -> ([B, S, D] outputs, final state).

    Layer-major scan: for each layer, scan over time (keeps state shapes
    static and the HLO compact: scan-in-scan).
    """
    flags = (jnp.arange(cfg.num_layers) % 2 == 0)  # even = mLSTM
    states = xlstm_state(cfg, h_seq.shape[0])      # stacked [L, ...] zeros

    def layer_body(h_seq, xs):
        p, flag, st = xs

        def time_body(carry, x_t):
            new_st, out = _xlstm_block(p, flag, carry, x_t, cfg)
            return new_st, out

        st_f, out_seq = jax.lax.scan(time_body, st, jnp.swapaxes(h_seq, 0, 1))
        return jnp.swapaxes(out_seq, 0, 1), st_f

    if cfg.scan_layers:
        h, final_states = jax.lax.scan(layer_body, h_seq,
                                       (params["blocks"], flags, states))
    else:
        h, outs = h_seq, []
        for i in range(cfg.num_layers):
            h, st_i = layer_body(
                h, jax.tree.map(lambda x: x[i], (params["blocks"], flags, states)))
            outs.append(st_i)
        final_states = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return h, final_states


def xlstm_forward(cfg, params, tokens, *, remat=True):
    h = cm.embed(params["embed"], tokens).astype(cfg.dtype)
    h = cm.maybe_shard(h, cfg.dp_axes, None, None)
    h, _ = xlstm_scan_tokens(cfg, params, h)
    h = cm.apply_norm(params["ln_f"], h, "rmsnorm")
    return cm.unembed(params["embed"], h).astype(jnp.float32)


def xlstm_decode_step(cfg, params, state, tokens, pos):
    """tokens: [B, 1] -> (logits [B, vocab], new state)."""
    x = cm.embed(params["embed"], tokens[:, 0]).astype(cfg.dtype)
    x = cm.maybe_shard(x, cfg.dp_axes, None)
    flags = (jnp.arange(cfg.num_layers) % 2 == 0)

    def body(x, xs):
        p, flag, st = xs
        new_st, out = _xlstm_block(p, flag, st, x, cfg)
        return out, new_st

    if cfg.scan_layers:
        x, new_state = jax.lax.scan(body, x, (params["blocks"], flags, state))
    else:
        outs = []
        for i in range(cfg.num_layers):
            x, st_i = body(x, jax.tree.map(lambda t: t[i],
                                           (params["blocks"], flags, state)))
            outs.append(st_i)
        new_state = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x = cm.apply_norm(params["ln_f"], x, "rmsnorm")
    return cm.unembed(params["embed"], x).astype(jnp.float32), new_state


# ---------------------------------------------------------------------------
# Mamba2 (SSD, scalar A per head)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg):
    d = cfg.d_model
    h = cfg.num_heads
    n = cfg.ssm_state
    p_dim = cfg.mamba_headdim
    inner = h * p_dim
    ks = jax.random.split(key, 8)
    return {
        "w_in": cm.dense_init(ks[0], d, 2 * inner + 2 * n + h, cfg.dtype),
        "conv_w": cm.truncated_normal(ks[1], (cfg.mamba_dconv, inner + 2 * n),
                                      cfg.dtype, 0.1),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "w_out": cm.dense_init(ks[2], inner, d, cfg.dtype),
        "ln": cm.init_norm(ks[3], d, "rmsnorm", cfg.dtype),
    }


def mamba2_state(cfg, batch):
    h, n, p_dim = cfg.num_heads, cfg.ssm_state, cfg.mamba_headdim
    inner = h * p_dim
    return {
        "h": jnp.zeros((batch, h, p_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_dconv - 1, inner + 2 * n), jnp.float32),
    }


def _mamba2_step(p, state, x_t, cfg):
    """Single-token SSD recurrence. x_t: [B, D]."""
    b, d = x_t.shape
    h, n, p_dim = cfg.num_heads, cfg.ssm_state, cfg.mamba_headdim
    inner = h * p_dim
    zxbcdt = x_t @ p["w_in"]                     # [B, 2*inner + 2n + h]
    z = zxbcdt[:, :inner]
    xbc = zxbcdt[:, inner:2 * inner + 2 * n]     # (x, B, C) pre-conv
    dt_raw = zxbcdt[:, 2 * inner + 2 * n:]       # [B, H]
    # causal depthwise conv over (x, B, C) with carried tail
    conv_in = jnp.concatenate([state["conv"], xbc[:, None, :].astype(jnp.float32)], 1)
    w = p["conv_w"].astype(jnp.float32)                        # [dconv, inner+2n]
    xbc_c = jax.nn.silu(jnp.einsum("btc,tc->bc", conv_in, w))
    new_conv = conv_in[:, 1:]
    x_in = xbc_c[:, :inner].reshape(b, h, p_dim)
    B_in = xbc_c[:, inner:inner + n]                           # [B, N]
    C_in = xbc_c[:, inner + n:]                                # [B, N]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B, H]
    a = jnp.exp(-jnp.exp(p["a_log"])[None] * dt)               # [B, H]
    dx = dt[..., None] * x_in                                  # [B, H, P]
    hs = a[..., None, None] * state["h"] + dx[..., None] * B_in[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", hs, C_in) + p["d_skip"][None, :, None] * x_in
    y = (y.reshape(b, inner) * jax.nn.silu(z.astype(jnp.float32))).astype(cfg.dtype)
    return {"h": hs, "conv": new_conv}, y @ p["w_out"]
