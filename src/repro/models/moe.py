"""Mixture-of-Experts block: token-choice top-k routing, static capacity.

Dispatch is sort-based (no [N, E] one-hots): flatten the (token, choice)
pairs, sort by expert id, compute within-expert ranks from segment starts,
scatter into a static [E, C, D] buffer (drops beyond capacity), run a single
grouped einsum ``ecd,edf->ecf`` per projection, and scatter-add the weighted
results back.  The [E, ...] axes shard over the 'model' mesh axis (expert
parallelism); token axes shard over 'data' — GSPMD lowers the
dispatch/return as all-to-alls on the production mesh.

Shared experts (deepseek-v2) run densely on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as cm


def init_moe(key, cfg):
    ks = jax.random.split(key, 6)
    e, d, f = cfg.moe_num_experts, cfg.d_model, cfg.moe_d_ff
    def ed(k, i, o, n):
        return jax.vmap(lambda kk: cm.dense_init(kk, i, o, cfg.dtype))(
            jax.random.split(k, n))
    p = {
        "router": cm.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": ed(ks[1], d, f, e),    # [E, D, F]
        "w_up": ed(ks[2], d, f, e),      # [E, D, F]
        "w_down": ed(ks[3], f, d, e),    # [E, F, D]
    }
    if cfg.moe_num_shared > 0:
        p["shared"] = cm.init_mlp(ks[4], d, f * cfg.moe_num_shared, cfg.dtype)
    return p


def _ranks_in_expert(sorted_e: jax.Array) -> jax.Array:
    """Within-segment rank for a sorted id vector (segment = equal ids)."""
    n = sorted_e.shape[0]
    idx = jnp.arange(n)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    return idx - seg_start


def moe_block(p, x, cfg):
    """Dispatch wrapper: optionally shard_map the dispatch per data shard.

    The global-sort dispatch makes GSPMD all-gather the token stream (the
    argsort is cross-device), replicating the [E*C, D] buffers on every
    device — the dominant memory+collective term of the MoE train cells
    (EXPERIMENTS §Perf, cell A).  ``moe_local_dispatch`` sorts and buckets
    per data shard instead (experts gathered, tokens local), which is plain
    data-parallel MoE: capacity is enforced per shard, communication reduces
    to the expert-weight gathers.
    """
    if cfg.moe_local_dispatch:
        mesh = cm.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            names = set(mesh.axis_names)
            # dispatch over ALL mesh axes (batch over data *and* model) —
            # restricting to the data axes replicates the dispatch across
            # 'model' and multiplies compute (measured: §Perf cell A it4)
            dp = tuple(a for a in ("pod", "data", "model") if a in names)
            while dp:
                size = 1
                for a in dp:
                    size *= mesh.shape[a]
                if x.shape[0] % size == 0:
                    break
                dp = dp[:-1]
            if dp:
                from jax.sharding import PartitionSpec as P
                spec_x = P(dp, None, None)
                return jax.shard_map(
                    lambda p_, x_: _moe_block_impl(p_, x_, cfg),
                    in_specs=(P(), spec_x), out_specs=spec_x,
                    check_vma=False)(p, x)
    return _moe_block_impl(p, x, cfg)


def _moe_block_impl(p, x, cfg):
    b, s, d = x.shape
    n = b * s
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    cap = int(cfg.moe_capacity_factor * n * k / e) + 1

    if n <= 64:
        # decode-sized token counts: give every token guaranteed capacity
        # (cap = n) so single-token routing matches prefill exactly
        cap = n

    xf = x.reshape(n, d)
    logits = (xf.astype(jnp.float32) @ p["router"])           # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # [N, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(n * k)
    flat_w = top_w.reshape(n * k)
    flat_tok = jnp.repeat(jnp.arange(n), k)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    ranks = _ranks_in_expert(sorted_e)                        # [N*k]
    keep = ranks < cap
    slot = sorted_e * cap + ranks                             # [N*k] in [0, E*C)
    slot = jnp.where(keep, slot, e * cap)                     # overflow bin

    buf = jnp.zeros((e * cap + 1, d), cfg.dtype)
    buf = buf.at[slot].set(xf[flat_tok[order]])
    buf = buf[: e * cap].reshape(e, cap, d)
    if cfg.moe_ep_shard:
        buf = cm.maybe_shard(buf, "model", None, None)   # EP over experts

    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", gate * up, p["w_down"])  # [E, C, D]
    if cfg.moe_ep_shard:
        out = cm.maybe_shard(out, "model", None, None)

    out_flat = out.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], out_flat[jnp.minimum(slot, e * cap - 1)], 0)
    y = jnp.zeros((n, d), cfg.dtype)
    y = y.at[flat_tok[order]].add(gathered * flat_w[order][:, None].astype(cfg.dtype))

    if "shared" in p:
        y = y + cm.mlp(p["shared"], xf)
    return y.reshape(b, s, d)
