from .model import (ModelConfig, init_params, prefill_logits, loss_fn,
                    init_cache, serve_step, param_count, active_param_count)

__all__ = ["ModelConfig", "init_params", "prefill_logits", "loss_fn",
           "init_cache", "serve_step", "param_count", "active_param_count"]
