"""Multi-head Latent Attention (DeepSeek-V2).

K/V are compressed into a ``kv_lora_rank`` latent c_kv plus a single shared
RoPE key k_rope; per-head K/V are up-projections of the latent.  Prefill /
training materializes K/V (matmul-dominant, MXU-friendly).  Decode uses the
*absorbed* form: queries are pulled into the latent space
(q_eff = q_nope @ W_uk per head) so attention runs directly against the
cached latents — the KV cache is [B, T, kv_lora + rope_dim] regardless of
head count, which is the technique's entire point (and a large d_jl saving
the routing framework sees in the cost profiles).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import common as cm


def init_mla(key, cfg):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    h = cfg.num_heads
    qk = cfg.qk_nope_head_dim
    qr = cfg.qk_rope_head_dim
    vd = cfg.v_head_dim
    r = cfg.kv_lora_rank
    p = {
        "w_kv_a": cm.dense_init(ks[1], d, r + qr, cfg.dtype),          # -> c_kv, k_rope
        "kv_a_norm": cm.init_norm(ks[2], r, "rmsnorm", cfg.dtype),
        "w_uk": cm.truncated_normal(ks[3], (h, r, qk), cfg.dtype, 1 / math.sqrt(r)),
        "w_uv": cm.truncated_normal(ks[4], (h, r, vd), cfg.dtype, 1 / math.sqrt(r)),
        "wo": cm.dense_init(ks[5], h * vd, d, cfg.dtype),
    }
    if cfg.q_lora_rank > 0:
        p["w_q_a"] = cm.dense_init(ks[6], d, cfg.q_lora_rank, cfg.dtype)
        p["q_a_norm"] = cm.init_norm(ks[0], cfg.q_lora_rank, "rmsnorm", cfg.dtype)
        p["w_q_b"] = cm.dense_init(ks[7], cfg.q_lora_rank, h * (qk + qr), cfg.dtype)
    else:
        p["w_q"] = cm.dense_init(ks[6], d, h * (qk + qr), cfg.dtype)
    return p


def _queries(p, x, cfg):
    b, s, _ = x.shape
    h, qk, qr = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if "w_q_a" in p:
        q = cm.apply_norm(p["q_a_norm"], x @ p["w_q_a"], "rmsnorm") @ p["w_q_b"]
    else:
        q = x @ p["w_q"]
    q = q.reshape(b, s, h, qk + qr)
    return q[..., :qk], q[..., qk:]


def _latents(p, x, cfg):
    r, qr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = x @ p["w_kv_a"]
    c_kv = cm.apply_norm(p["kv_a_norm"], kv[..., :r], "rmsnorm")
    k_rope = kv[..., r:]                                       # [B, S, qr]
    return c_kv, k_rope


def mla_attention(p, x, positions, cfg, *, kv_cache=None, cache_pos=None):
    b, s, _ = x.shape
    h, qk, qr, vd, r = (cfg.num_heads, cfg.qk_nope_head_dim,
                        cfg.qk_rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank)
    scale = 1.0 / math.sqrt(qk + qr)
    q_nope, q_rope = _queries(p, x, cfg)
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _latents(p, x, cfg)
    k_rope = cm.apply_rope(k_rope[:, :, None], positions, cfg.rope_theta)[:, :, 0]

    if kv_cache is None:
        # -- materialized form (prefill / train)
        k_nope = jnp.einsum("btr,hrk->bthk", c_kv, p["w_uk"])
        v = jnp.einsum("btr,hrk->bthk", c_kv, p["w_uv"])
        chunk = cfg.attn_chunk_q
        if cfg.attn_impl == "flash" and s >= 128:
            # fold the shared rope key into a standard attention: per head
            # K_eff = [k_nope, k_rope], Q_eff = [q_nope, q_rope]
            h_ = cfg.num_heads
            k_eff = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                          (b, s, h_, qr))], -1)
            q_eff = jnp.concatenate([q_nope, q_rope], -1)
            out = cm._flash_bshd(q_eff, k_eff, v, scale=scale)
        elif chunk > 0 and s % chunk == 0 and s > chunk:
            # query-chunked: live scores bounded to [B, H, chunk, S]
            kpos = jnp.arange(s)[None, :]

            def one(i):
                qn = jax.lax.dynamic_slice_in_dim(q_nope, i * chunk, chunk, 1)
                qr = jax.lax.dynamic_slice_in_dim(q_rope, i * chunk, chunk, 1)
                sc = jnp.einsum("bshk,bthk->bhst", qn, k_nope) + \
                    jnp.einsum("bshk,btk->bhst", qr, k_rope)
                sc = sc.astype(jnp.float32) * scale
                qpos = i * chunk + jnp.arange(chunk)[:, None]
                sc = jnp.where((kpos <= qpos)[None, None], sc,
                               jnp.float32(-1e30))
                pr = jax.nn.softmax(sc, -1).astype(x.dtype)
                return jnp.einsum("bhst,bthk->bshk", pr, v)

            if not cfg.scan_layers:   # dry-run: unroll for exact HLO counts
                out = jnp.concatenate([one(i) for i in range(s // chunk)], 1)
            else:
                out = jax.lax.map(one, jnp.arange(s // chunk))
                out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, vd)
        else:
            # rope term: each head has its own q_rope but all share k_rope
            scores = jnp.einsum("bshk,bthk->bhst", q_nope, k_nope) + \
                jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
            scores = (scores.astype(jnp.float32) * scale)
            mask = cm.causal_mask(s, s)
            scores = jnp.where(mask, scores, jnp.float32(-1e30))
            probs = jax.nn.softmax(scores, -1).astype(x.dtype)
            out = jnp.einsum("bhst,bthk->bshk", probs, v)
        new_cache = None
    else:
        # -- absorbed form (decode): attend in latent space
        t = kv_cache["c_kv"].shape[1]
        cc = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["c_kv"], c_kv.astype(kv_cache["c_kv"].dtype), cache_pos, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["k_rope"], k_rope.astype(kv_cache["k_rope"].dtype), cache_pos, 1)
        q_eff = jnp.einsum("bshk,hrk->bshr", q_nope, p["w_uk"])   # [B,S,H,r]
        scores = jnp.einsum("bshr,btr->bhst", q_eff, cc.astype(x.dtype)) + \
            jnp.einsum("bshk,btk->bhst", q_rope, cr.astype(x.dtype))
        scores = scores.astype(jnp.float32) * scale
        valid = (jnp.arange(t)[None, :] <= cache_pos + s - 1)[None, None]
        scores = jnp.where(valid, scores, jnp.float32(-1e30))
        probs = jax.nn.softmax(scores, -1).astype(x.dtype)
        lat = jnp.einsum("bhst,btr->bshr", probs, cc.astype(x.dtype))  # [B,S,H,r]
        out = jnp.einsum("bshr,hrk->bshk", lat, p["w_uv"])
        new_cache = {"c_kv": cc, "k_rope": cr}
    return out.reshape(b, s, h * vd) @ p["wo"], new_cache


def init_mla_cache(cfg, batch, max_len):
    return {
        "c_kv": jnp.zeros((cfg.num_layers, batch, max_len, cfg.kv_lora_rank), cfg.dtype),
        "k_rope": jnp.zeros((cfg.num_layers, batch, max_len, cfg.qk_rope_head_dim), cfg.dtype),
    }
