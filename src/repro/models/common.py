"""Shared neural-net building blocks (pure JAX, params are dict pytrees).

Conventions:
  * params are nested dicts of jnp arrays; block params are stacked along a
    leading layer axis and consumed by ``lax.scan`` (compact HLO => fast
    lowering/compiles even for 60-layer configs in the 512-device dry-run).
  * activations default to bfloat16, layernorm/softmax math in float32.
  * all shapes are static; masks implement causality / sliding windows.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def get_abstract_mesh():
    """Ambient abstract mesh, or None on jax versions without the API."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    return get() if get is not None else None


def maybe_shard(x, *axes):
    """Activation-sharding anchor: constrain ``x`` to PartitionSpec(*axes).

    No-op unless an ambient mesh (jax.set_mesh) provides the named axes and
    the corresponding dims divide evenly — model code stays runnable on a
    single CPU device while the production-mesh dry-run gets explicit
    batch/tensor sharding anchors (GSPMD propagates the rest).
    """
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    names = set(mesh.axis_names)

    def axis_size(a):
        if isinstance(a, tuple):
            return int(jnp.prod(jnp.array([mesh.shape[x] for x in a])))
        return mesh.shape[a]

    spec = []
    for i, a in enumerate(axes):
        if a is None:
            spec.append(None)
            continue
        parts = tuple(p for p in (a if isinstance(a, tuple) else (a,))
                      if p in names)   # drop axes this mesh doesn't have
        if not parts:
            spec.append(None)
            continue
        size = 1
        for p in parts:
            size *= mesh.shape[p]
        fits = x.shape[i] % size == 0
        spec.append((parts if len(parts) > 1 else parts[0]) if fits else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


DP = ("pod", "data")  # batch axes (pod collapses away on single-pod meshes)
TP = "model"


def remat_wrap(fn, cfg):
    """jax.checkpoint with the config's remat policy ('full' | 'dots')."""
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def truncated_normal(key, shape, dtype, scale):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, in_dim, out_dim, dtype, *, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return truncated_normal(key, (in_dim, out_dim), dtype, scale)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(key, d, kind: str, dtype):
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    if kind == "nonparam_ln":  # OLMo: LayerNorm without affine params
        return {}
    raise ValueError(kind)


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * params["w"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, -1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["w"].astype(jnp.float32) + params["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S,1,hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / sliding-window / cross)
# ---------------------------------------------------------------------------

def init_attention(key, d_model, n_heads, n_kv, head_dim, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype,
                         scale=1.0 / math.sqrt(n_heads * head_dim)),
    }


def _sdpa(q, k, v, mask, *, grouped=False):
    """q: [B,S,H,hd]; k/v: [B,T,Hkv,hd]; mask: [B?,1,S,T] bool or None.

    ``grouped=True`` contracts GQA via a grouped einsum instead of
    materializing ``jnp.repeat``ed K/V (a §Perf iteration: the repeat
    multiplies decode KV traffic by H/Hkv; math is identical).
    """
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if rep > 1 and grouped:
        qg = q.reshape(b, s, hkv, rep, hd)
        scores = jnp.einsum("bsgrd,btgd->bgrst", qg, k).astype(jnp.float32)
        scores = scores.reshape(b, h, s, -1) / math.sqrt(hd)
        if mask is not None:
            scores = jnp.where(mask, scores, jnp.float32(-1e30))
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        pg = probs.reshape(b, hkv, rep, s, -1)
        out = jnp.einsum("bgrst,btgd->bsgrd", pg, v)
        return out.reshape(b, s, h, hd)
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _sdpa_chunked(q, k, v, *, window: int, chunk: int, unroll: bool):
    """Query-chunked causal attention: identical math to :func:`_sdpa` with
    a causal (optionally sliding-window) mask, but the live score tensor is
    [B, H, chunk, T].  ``unroll=True`` (dry-run) emits each chunk in the
    HLO so cost analysis stays exact; otherwise chunks run under lax.map.
    """
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    if h // hkv > 1:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    n_chunks = s // chunk
    assert s % chunk == 0, (s, chunk)
    kpos = jnp.arange(s)[None, :]

    def one(i):
        qc = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, 1)
        qpos = i * chunk + jnp.arange(chunk)[:, None]
        m = kpos <= qpos
        m &= (window <= 0) | (kpos > qpos - window)   # window may be traced
        sc = jnp.einsum("bshd,bthd->bhst", qc, k).astype(jnp.float32)
        sc = sc / math.sqrt(hd)
        sc = jnp.where(m[None, None], sc, jnp.float32(-1e30))
        pr = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
        return jnp.einsum("bhst,bthd->bshd", pr, v)

    if unroll:
        return jnp.concatenate([one(i) for i in range(n_chunks)], axis=1)
    out = jax.lax.map(one, jnp.arange(n_chunks))      # [n, B, c, H, hd]
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def causal_mask(s: int, t: int, window: int = 0):
    """[1,1,S,T] causal (optionally sliding-window) mask; t >= s offsets apply."""
    qpos = jnp.arange(s)[:, None] + (t - s)
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, None]


def _flash_bshd(q, k, v, *, scale=None):
    """[B,S,H,hd] -> flash attention kernel on [B*H, S, hd] (GQA repeated).

    Under an ambient mesh the kernel is shard_map'ed: batch shards over the
    dp axes and heads over 'model' (when divisible) — each device runs the
    Pallas kernel on its local [B/dp * H/tp, S, hd] block (GSPMD cannot
    partition a custom call, so without this the inputs would be
    all-gathered and the kernel replicated).
    """
    from repro.kernels import ops as kops
    b, s, h, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    rep = h // k.shape[2]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    def local(q_, k_, v_):
        b_, s_, h_, _ = q_.shape
        to_bhsd = lambda x: jnp.moveaxis(x, 2, 1).reshape(
            b_ * h_, s_, x.shape[-1])
        out = kops.flash_attention(to_bhsd(q_), to_bhsd(k_), to_bhsd(v_),
                                   scale=scale)
        return jnp.moveaxis(out.reshape(b_, h_, s_, -1), 1, 2)

    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return local(q, k, v)
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp_ax = dp if (dp and b % dp_size == 0) else None
    tp_ax = "model" if ("model" in names
                        and h % mesh.shape["model"] == 0) else None
    spec = P(dp_ax, None, tp_ax, None)
    # check_vma=False: pallas_call out_shapes carry no varying-mesh-axes info
    return jax.shard_map(local, in_specs=(spec, spec, spec),
                         out_specs=spec, check_vma=False)(q, k, v)


def attention(params, x, positions, *, n_heads, n_kv, head_dim,
              rope_theta=1e4, window=0, kv_cache=None, cache_pos=None,
              use_rope=True, chunk_q=0, unroll_chunks=False,
              attn_impl="xla", grouped=False):
    """Self-attention. With ``kv_cache`` = {'k','v'} [B, T, n_kv, hd], runs a
    decode step: writes K/V at ``cache_pos`` and attends over <= cache_pos."""
    b, s, d = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv, head_dim)
    v = v.reshape(b, s, n_kv, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if kv_cache is None:
        if attn_impl == "flash" and window == 0 and s >= 128:
            out = _flash_bshd(q, k, v)
        elif chunk_q > 0 and s % chunk_q == 0 and s > chunk_q:
            out = _sdpa_chunked(q, k, v, window=window, chunk=chunk_q,
                                unroll=unroll_chunks)
        else:
            out = _sdpa(q, k, v, causal_mask(s, s, window), grouped=grouped)
        new_cache = None
    else:
        t = kv_cache["k"].shape[1]
        ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_pos, axis=1)
        kpos = jnp.arange(t)[None, :]
        valid = kpos <= (cache_pos + s - 1)     # decode chunks use s == 1
        if window > 0:
            valid &= kpos > (cache_pos + s - 1 - window)
        mask = valid[None, None]                # [1,1,1,T]
        out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask,
                    grouped=grouped)
        new_cache = {"k": ck, "v": cv}
    out = out.reshape(b, s, n_heads * head_dim) @ params["wo"]
    return out, new_cache


def init_cross_attention(key, d_model, n_heads, head_dim, dtype):
    return init_attention(key, d_model, n_heads, n_heads, head_dim, dtype)


def cross_attention(params, x, enc, *, n_heads, head_dim):
    b, s, d = x.shape
    t = enc.shape[1]
    q = (x @ params["wq"]).reshape(b, s, n_heads, head_dim)
    k = (enc @ params["wk"]).reshape(b, t, n_heads, head_dim)
    v = (enc @ params["wv"]).reshape(b, t, n_heads, head_dim)
    out = _sdpa(q, k, v, None)
    return out.reshape(b, s, n_heads * head_dim) @ params["wo"]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype, *, gated=True):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype,
                              scale=1.0 / math.sqrt(d_ff))}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params, x, *, gated=True, act=jax.nn.silu):
    up = x @ params["w_up"]
    if gated:
        up = act(x @ params["w_gate"]) * up
    else:
        up = act(up)
    return up @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(key, vocab, d_model, dtype, *, tie=True):
    ks = jax.random.split(key, 2)
    p = {"tok": truncated_normal(ks[0], (vocab, d_model), dtype, 0.02)}
    if not tie:
        p["head"] = dense_init(ks[1], d_model, vocab, dtype)
    return p


def embed(params, tokens):
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params, x):
    if "head" in params:
        return x @ params["head"]
    return x @ params["tok"].T


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple
