"""Training driver: fault-tolerant loop with checkpoint/restart.

CPU-scale by default (reduced configs) — the full configs are exercised by
the dry-run.  The loop is the production shape: deterministic sharded data,
jitted train step, atomic checkpoints, straggler monitor, bit-identical
resume (tests/test_fault.py kills it mid-run and restarts).

  PYTHONPATH=src python -m repro.launch.train --arch smollm_135m \
      --preset smoke --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.distributed.fault import FailureInjector, StragglerMonitor
from repro.launch.steps import make_train_step, default_optimizer
from repro.models import model as M


@dataclasses.dataclass
class TrainResult:
    losses: list
    final_step: int
    resumed_from: int | None
    straggler_flags: int


def train(arch: str, *, preset: str = "smoke", steps: int = 100,
          batch: int = 8, seq: int = 128, ckpt_dir: str | None = None,
          ckpt_every: int = 50, seed: int = 0,
          fail_at: int | None = None, log_every: int = 10,
          d_model_override: int | None = None,
          lr: float | None = None, warmup: int | None = None) -> TrainResult:
    cfg = (registry.smoke_config(arch) if preset == "smoke"
           else registry.config(arch))
    if d_model_override:
        cfg = dataclasses.replace(cfg, d_model=d_model_override)
    if lr is not None:
        from repro.optim.adamw import AdamW
        from repro.optim import schedules
        wu = warmup if warmup is not None else max(steps // 10, 5)
        opt = AdamW(schedule=lambda s: schedules.warmup_cosine(
            s, peak_lr=lr, warmup_steps=wu, total_steps=max(steps, wu + 1)))
    else:
        opt = default_optimizer(cfg)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

    data = SyntheticStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        seed=seed))

    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    start = 0
    resumed_from = None
    if ckpt_dir:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            state = ckpt.restore(ckpt_dir, last,
                                 {"params": params, "opt": opt_state})
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt"])
            start = last
            resumed_from = last

    injector = FailureInjector(fail_at)
    monitor = StragglerMonitor()
    losses = []
    for step in range(start, steps):
        injector.check(step)
        t0 = time.time()
        b = data.batch_at(step)
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((batch, cfg.num_frames, cfg.d_model),
                                    cfg.dtype)
        if cfg.family == "vlm":
            b["patches"] = jnp.zeros((batch, cfg.num_patches, cfg.d_model),
                                     cfg.dtype)
        loss, params, opt_state = step_fn(params, opt_state, b)
        loss = float(loss)
        monitor.record(time.time() - t0)
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f}", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1,
                      {"params": params, "opt": opt_state})
            ckpt.prune(ckpt_dir)
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt_state})
    return TrainResult(losses=losses, final_step=steps,
                       resumed_from=resumed_from,
                       straggler_flags=monitor.flagged)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    res = train(args.arch, preset=args.preset, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, fail_at=args.fail_at,
                seed=args.seed)
    print(f"[train] done: loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f} "
          f"(resumed_from={res.resumed_from})")


if __name__ == "__main__":
    main()
