"""Paper driver: route DNN inference jobs over the evaluation topologies.

  PYTHONPATH=src python -m repro.launch.route --topology small \
      --jobs vgg19:2,resnet34:6 --scale 1e-4 --methods greedy,sa --seed 0

``--methods`` takes any comma list of registered solver names (see
``repro.core.solvers.available()``), e.g. ``greedy,lazy,sa``.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import jobs as J, network as N, solvers
from repro.configs import registry

_SA_DEFAULTS = dict(num_chains=4)


def build_jobs(spec: str, num_nodes: int, seed: int) -> list[J.InferenceJob]:
    rng = np.random.default_rng(seed)
    out = []
    for part in spec.split(","):
        name, count = part.split(":")
        for i in range(int(count)):
            src, dst = rng.choice(num_nodes, size=2, replace=False)
            if name in registry.PAPER_MODELS:
                out.append(registry.get(name).make_job(
                    f"{name}-{i}", int(src), int(dst)))
            elif name == "synthetic":
                out.append(J.synthetic_job(f"syn-{i}", int(src), int(dst),
                                           num_layers=24, seed=seed + i,
                                           flops_scale=2e9, bytes_scale=2e6))
            else:
                mod = registry.get(name)
                comp, data = mod.cost_profile(seq_len=2048, batch=1)
                out.append(J.InferenceJob(f"{name}-{i}", int(src), int(dst),
                                          comp.astype(np.float32),
                                          data.astype(np.float32)))
    return out


def run(topology: str, jobs_spec: str, scale: float, methods: str, seed: int,
        sa_iters_d: float = 0.995, verbose: bool = True) -> dict:
    net, names = (N.small_topology(capacity_scale=scale) if topology == "small"
                  else N.us_backbone(capacity_scale=scale))
    jobs = build_jobs(jobs_spec, net.num_nodes, seed)
    batch = J.batch_jobs(jobs)
    out = {"topology": topology, "scale": scale, "J": len(jobs)}

    for method in (m.strip() for m in methods.split(",") if m.strip()):
        opts = {}
        if method == "sa":
            opts = dict(_SA_DEFAULTS, seed=seed, d=sa_iters_d)
        plan = solvers.solve(net, batch, method=method, **opts)
        sim = plan.simulate(net, batch)
        out[f"{method}_s"] = plan.meta["solve_s"]
        out[f"{method}_bound"] = plan.bound()
        out[f"{method}_sim"] = sim.makespan
        if verbose:
            print(f"[{method}] bound {plan.bound():.3f}s "
                  f"sim {sim.makespan:.3f}s "
                  f"({plan.meta['solve_s']:.2f}s to solve)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="small", choices=["small", "us"])
    ap.add_argument("--jobs", default="vgg19:2,resnet34:6")
    ap.add_argument("--scale", type=float, default=1e-4)
    ap.add_argument("--methods", default="greedy,sa",
                    help="comma list of registered solvers "
                         f"(available: {','.join(solvers.available())})")
    ap.add_argument("--algo", default=None,
                    help="deprecated; 'both' = greedy,sa, else passed through")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    methods = args.methods
    if args.algo:  # back-compat with the old flag
        methods = "greedy,sa" if args.algo == "both" else args.algo
    run(args.topology, args.jobs, args.scale, methods, args.seed)


if __name__ == "__main__":
    main()
