"""Paper driver: route DNN inference jobs over the evaluation topologies.

  PYTHONPATH=src python -m repro.launch.route --topology small \
      --jobs vgg19:2,resnet34:6 --scale 1e-4 --algo both --seed 0
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (annealing, bounds, greedy, jobs as J, network as N,
                        schedule)
from repro.configs import registry


def build_jobs(spec: str, num_nodes: int, seed: int) -> list[J.InferenceJob]:
    rng = np.random.default_rng(seed)
    out = []
    for part in spec.split(","):
        name, count = part.split(":")
        for i in range(int(count)):
            src, dst = rng.choice(num_nodes, size=2, replace=False)
            if name in registry.PAPER_MODELS:
                out.append(registry.get(name).make_job(
                    f"{name}-{i}", int(src), int(dst)))
            elif name == "synthetic":
                out.append(J.synthetic_job(f"syn-{i}", int(src), int(dst),
                                           num_layers=24, seed=seed + i,
                                           flops_scale=2e9, bytes_scale=2e6))
            else:
                mod = registry.get(name)
                comp, data = mod.cost_profile(seq_len=2048, batch=1)
                out.append(J.InferenceJob(f"{name}-{i}", int(src), int(dst),
                                          comp.astype(np.float32),
                                          data.astype(np.float32)))
    return out


def run(topology: str, jobs_spec: str, scale: float, algo: str, seed: int,
        sa_iters_d: float = 0.995, verbose: bool = True) -> dict:
    net, names = (N.small_topology(capacity_scale=scale) if topology == "small"
                  else N.us_backbone(capacity_scale=scale))
    jobs = build_jobs(jobs_spec, net.num_nodes, seed)
    batch = J.batch_jobs(jobs)
    out = {"topology": topology, "scale": scale, "J": len(jobs)}

    if algo in ("greedy", "both"):
        t0 = time.time()
        sol = greedy.greedy_route(net, batch)
        out["greedy_s"] = time.time() - t0
        sim = schedule.simulate(net, batch, sol.assign, sol.order)
        out["greedy_bound"] = sol.makespan_bound
        out["greedy_sim"] = sim.makespan
        if verbose:
            print(f"[greedy] bound {sol.makespan_bound:.3f}s "
                  f"sim {sim.makespan:.3f}s ({out['greedy_s']:.2f}s to solve)")
    if algo in ("sa", "both"):
        t0 = time.time()
        sa = annealing.anneal(net, batch, seed=seed, d=sa_iters_d,
                              num_chains=4)
        out["sa_s"] = time.time() - t0
        sim = schedule.simulate(net, batch, sa.assign, sa.priority)
        out["sa_bound"] = sa.bound
        out["sa_sim"] = sim.makespan
        if verbose:
            print(f"[sa]     bound {sa.bound:.3f}s sim {sim.makespan:.3f}s "
                  f"({out['sa_s']:.2f}s to solve)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="small", choices=["small", "us"])
    ap.add_argument("--jobs", default="vgg19:2,resnet34:6")
    ap.add_argument("--scale", type=float, default=1e-4)
    ap.add_argument("--algo", default="both", choices=["greedy", "sa", "both"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.topology, args.jobs, args.scale, args.algo, args.seed)


if __name__ == "__main__":
    main()
