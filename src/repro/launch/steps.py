"""Step-function builders shared by the launcher, dry-run and tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim.adamw import AdamW
from repro.optim import schedules


def default_optimizer(cfg) -> AdamW:
    if "minicpm" in cfg.name:  # the WSD-schedule arch
        sched = lambda step: schedules.wsd(
            step, peak_lr=1e-2, warmup_steps=2000, stable_steps=40_000,
            decay_steps=5_000)
    else:
        sched = lambda step: schedules.warmup_cosine(
            step, peak_lr=3e-4, warmup_steps=2000, total_steps=100_000)
    return AdamW(schedule=sched)


def make_train_step(cfg, opt: AdamW | None = None):
    opt = opt or default_optimizer(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch))(params)
        params, opt_state, info = opt.apply(params, grads, opt_state)
        return loss, params, opt_state

    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        return M.prefill_logits(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg):
    def serve_step(params, cache, batch):
        return M.serve_step(cfg, params, cache, batch)

    return serve_step
