"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state.  Target: TPU v5e pods — 256 chips/pod in a
(data=16, model=16) layout; the multi-pod mesh adds a leading 'pod' axis
(2 x 256 = 512 chips) over DCN.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_debug_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests only."""
    need = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:need])
