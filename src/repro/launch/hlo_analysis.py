"""Parse optimized HLO text for collective traffic (roofline collective term).

``cost_analysis()`` does not expose collective bytes, so we sum the result
sizes of every collective op in the compiled module.  Each op also gets an
*effective-bytes* weighting by the standard ring-algorithm factors over its
replica-group size k:

    all-reduce           2 (k-1)/k      (reduce-scatter + all-gather)
    all-gather           (k-1)/k
    reduce-scatter       (k-1)/k
    all-to-all           (k-1)/k
    collective-permute   1

Both IotaReplicaGroup (``replica_groups=[G,S]<=...``) and explicit list
(``replica_groups={{0,1},...}``) syntaxes are parsed.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _factor(op: str, k: int) -> float:
    if op == "collective-permute":   # point-to-point: full payload moves
        return 1.0
    if k <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (k - 1) / k
    return (k - 1) / k


def collective_stats(hlo_text: str) -> dict:
    """Aggregate collective traffic from optimized HLO text.

    Returns per-op counts/bytes plus ``total_bytes`` (sum of result sizes,
    per device) and ``effective_bytes`` (ring-factor weighted — the number a
    per-link bandwidth divides for the roofline collective term).
    """
    per_op: dict[str, dict] = {}
    total = 0
    effective = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("result"))
        k = _group_size(line)
        d = per_op.setdefault(op, {"count": 0, "bytes": 0, "effective_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += nbytes
        d["effective_bytes"] += nbytes * _factor(op, k)
        total += nbytes
        effective += nbytes * _factor(op, k)
    return {"per_op": per_op, "total_bytes": total,
            "effective_bytes": effective}
