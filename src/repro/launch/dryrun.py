import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — JAX
locks the host device count at first init, and the production meshes need
512 placeholder devices.  Nothing else in the repo sets this flag (smoke
tests and benchmarks see the 1 real CPU device).

For every cell this driver:
  1. builds ShapeDtypeStruct stand-ins for params / optimizer state / batch
     (never allocating),
  2. jits the step with explicit NamedShardings from
     repro.distributed.sharding and ``.lower().compile()``s it,
  3. records ``compiled.memory_analysis()`` (proves the cell fits),
     ``compiled.cost_analysis()`` (per-device FLOPs / bytes for §Roofline),
     and the collective bytes parsed from the optimized HLO,
  4. writes one JSON per cell under --out.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm_135m \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from repro.configs import registry
from repro.configs.shapes import SHAPES, shape_applicable
from repro.distributed import sharding as sh
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step, make_serve_step, \
    make_prefill_step, default_optimizer
from repro.models import model as M


def _struct_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def _flash_hidden(cfg, spec, chips: int) -> dict:
    """Analytic flops/bytes of the shard_map'ed flash-attention kernels.

    pallas_call is a custom call, invisible to cost_analysis; this is the
    correction §Roofline adds back.  Causal blocking halves the S^2 work;
    the whole point of the kernel is that HBM traffic is the O(S*d) operand
    movement, not the O(S^2) scores.
    """
    b, s = spec.global_batch, spec.seq_len
    h = cfg.num_heads
    if cfg.use_mla:
        dq = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        dv = cfg.v_head_dim
    else:
        dq = dv = cfg.head_dim
    fwd_flops = 0.5 * 2.0 * b * h * s * s * (dq + dv) * cfg.num_layers
    mult = 4.0 if spec.kind == "train" else 1.0     # fwd + 3x-fwd backward
    per_layer_io = b * s * h * (2 * dq + 2 * dv) * 2  # Q,K,V,O bf16
    io_mult = 3.0 if spec.kind == "train" else 1.0
    return {
        "flops_per_device": fwd_flops * mult / chips,
        "bytes_per_device": per_layer_io * io_mult * cfg.num_layers / chips,
    }


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             opts: dict | None = None) -> dict:
    """Lower+compile one cell; returns the §Dry-run / §Roofline record."""
    opts = opts or {}
    cfg = registry.config(arch)
    spec = SHAPES[shape]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok"}
    ok, reason = shape_applicable(cfg, spec)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    mod = registry.get(arch)
    # Unroll layer loops so cost_analysis / the collective audit see every
    # layer (XLA visits while bodies once).  Time-recurrent scans (ssm /
    # hybrid prefill+train) necessarily remain loops; those cells get
    # analytic compute terms in §Roofline (flops_source flags this).
    layout = opts.get("layout", "2d")
    knobs = {k: opts[k] for k in
             ("attn_chunk_q", "remat_policy", "moe_ep_shard", "attn_impl",
              "gqa_grouped", "moe_local_dispatch")
             if k in opts}
    if layout == "dp_only":
        knobs["dp_axes"] = ("pod", "data", "model")
    cfg = dataclasses.replace(
        cfg, scan_layers=bool(opts.get("scan_layers", False)), **knobs)
    time_scanned = cfg.family in ("ssm", "hybrid") and spec.kind != "decode"
    rec["flops_source"] = "analytic" if time_scanned else "hlo"
    rec["opts"] = opts
    if cfg.attn_impl == "flash" and spec.kind != "decode":
        rec["flash_hidden"] = _flash_hidden(cfg, spec, 512 if multi_pod
                                            else 256)
    batch_struct = mod.input_specs(spec, cfg)
    params_struct = _struct_tree(
        lambda k: M.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
    p_sharding = sh.param_shardings(params_struct, mesh, layout=layout)
    b_sharding = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s),
        sh.batch_specs(batch_struct, mesh, layout=layout))

    t0 = time.time()
    with jax.set_mesh(mesh):
        if spec.kind == "train":
            opt = default_optimizer(cfg)
            step = make_train_step(cfg, opt)
            opt_struct = _struct_tree(opt.init, params_struct)
            o_sharding = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                sh.opt_state_specs(sh.param_specs(params_struct, mesh), mesh))
            jitted = jax.jit(step,
                             in_shardings=(p_sharding, o_sharding, b_sharding),
                             out_shardings=(jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                                            p_sharding, o_sharding),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_struct, opt_struct, batch_struct)
        elif spec.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sharding, b_sharding))
            lowered = jitted.lower(params_struct, batch_struct)
        else:  # decode
            step = make_serve_step(cfg)
            cache_struct = _struct_tree(
                lambda: M.init_cache(cfg, spec.global_batch, spec.seq_len))
            c_sharding = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s),
                sh.cache_specs(cache_struct, mesh, layout=layout))
            jitted = jax.jit(step,
                             in_shardings=(p_sharding, c_sharding, b_sharding),
                             out_shardings=(None, c_sharding),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_struct, cache_struct, batch_struct)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = hlo_analysis.collective_stats(compiled.as_text())
    n_params = sum(int(x.size) for x in jax.tree.leaves(params_struct)
                   if hasattr(x, "size"))
    rec.update(
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        flops_per_device=cost.get("flops", 0.0),
        bytes_accessed_per_device=cost.get("bytes accessed", 0.0),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        } if mem else None,
        collectives=coll,
        params=n_params,
        kind=spec.kind,
        tokens=spec.global_batch * (spec.seq_len if spec.kind != "decode"
                                    else 1),
        seq_len=spec.seq_len, global_batch=spec.global_batch,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--opts", default="{}",
                    help='JSON perf knobs, e.g. \'{"attn_chunk_q": 512, '
                         '"layout": "dp_only"}\'')
    args = ap.parse_args()
    opts = json.loads(args.opts)

    archs = registry.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}.{shape}.{'multi' if multi else 'single'}"
                path = outdir / f"{tag}.json"
                if path.exists():
                    print(f"[dryrun] {tag}: cached")
                    continue
                try:
                    rec = run_cell(arch, shape, multi, opts=opts)
                except Exception as e:  # a failure here is a bug in the system
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "fail", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    n_fail += 1
                path.write_text(json.dumps(rec, indent=1))
                print(f"[dryrun] {tag}: {rec['status']} "
                      f"(lower {rec.get('lower_s', '-')}s, "
                      f"compile {rec.get('compile_s', '-')}s, "
                      f"flops/dev {rec.get('flops_per_device', 0):.3g})",
                      flush=True)
    print(f"[dryrun] done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
