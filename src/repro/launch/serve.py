"""Serving driver: routed scheduling + batched decode.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m \
      --requests 4 --gen 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import registry
from repro.core import network as N
from repro.models import model as M
from repro.serving.engine import DecodeEngine
from repro.serving.scheduler import Request, RoutedScheduler


def default_cluster() -> N.ComputeNetwork:
    G, GB = 1e12, 1e9
    return N.make_network(
        6,
        [(0, 1, 10 * GB), (1, 2, 40 * GB), (2, 3, 40 * GB), (3, 4, 40 * GB),
         (4, 5, 10 * GB), (1, 3, 40 * GB), (2, 4, 40 * GB)],
        [0, 50 * G, 50 * G, 50 * G, 50 * G, 0])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--method", default="greedy",
                    help="routing solver (greedy|lazy|sa|exact|registered)")
    args = ap.parse_args()

    sched = RoutedScheduler(default_cluster(), method=args.method)
    plans = sched.schedule([
        Request(args.arch, src=0, dst=5, seq_len=2048, name=f"req{i}")
        for i in range(args.requests)])
    for p in plans:
        print(f"[serve] prio {p.priority} {p.job_name}: slices "
              f"{p.nodes_used} bound {p.bound_s*1e3:.2f} ms")
    print(f"[serve] plan: solver={sched.last_plan.solver} "
          f"makespan bound {sched.last_plan.bound()*1e3:.2f} ms")

    cfg = registry.smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = DecodeEngine(cfg, params,
                          max_len=args.prompt_len + args.gen + 8)
    prompts = np.tile(np.arange(args.prompt_len, dtype=np.int32)[None],
                      (args.requests, 1))
    extra = {}
    if cfg.family == "encdec":
        from repro.models import encdec
        import jax.numpy as jnp
        frames = jnp.zeros((args.requests, cfg.num_frames, cfg.d_model),
                           cfg.dtype)
        extra["enc_out"] = encdec.encode(cfg, params, frames, remat=False)
    res = engine.generate(prompts, gen_len=args.gen, extra_batch=extra)
    print(f"[serve] {args.requests} requests x {args.gen} tokens: "
          f"{res.tokens_per_s:.1f} tok/s (decode {res.decode_s:.2f}s)")


if __name__ == "__main__":
    main()
