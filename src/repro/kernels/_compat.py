"""Version shims for the Pallas TPU API shared by the kernel modules."""
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes the TPU compiler options as TPUCompilerParams.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
