"""Pure-jnp oracles for the tropical (min, +) kernels.

These are the semantic references the Pallas kernels are validated against
(tests sweep shapes/dtypes and assert_allclose kernel vs. oracle).
"""
from __future__ import annotations

import jax.numpy as jnp


def minplus_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[i, j] = min_k A[i, k] + B[k, j]   (tropical semiring matmul).

    Supports leading batch dims on both operands (broadcast like matmul).
    """
    return jnp.min(a[..., :, :, None] + b[..., None, :, :], axis=-2)


def minplus_matvec_ref(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[i] = min_k A[i, k] + x[k]."""
    return jnp.min(a + x[..., None, :], axis=-1)


def minplus_closure_ref(w: jnp.ndarray, *, num_nodes: int | None = None) -> jnp.ndarray:
    """All-pairs shortest path distances: the reflexive-transitive min-plus
    closure of the edge-weight matrix ``w`` (repeated tropical squaring).

    ``w[i, j]`` is the direct edge weight (a large finite INF when absent).
    The diagonal is forced to 0 before squaring.
    """
    n = w.shape[-1] if num_nodes is None else num_nodes
    eye = jnp.arange(w.shape[-1])
    d = w.at[..., eye, eye].min(0.0)
    steps = max(1, int(jnp.ceil(jnp.log2(max(n - 1, 2)))))
    for _ in range(steps):
        d = minplus_matmul_ref(d, d)
    return d
