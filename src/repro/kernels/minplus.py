"""Pallas TPU kernel: tropical (min, +) matrix multiply.

This is the compute hot-spot of the paper's routing framework at scale: the
all-pairs transfer-cost closures (one per DNN layer, per candidate routing)
are computed by repeated min-plus squaring, each squaring a V x V x V
tropical contraction.

TPU adaptation (see DESIGN.md §3.3): the MXU performs multiply-accumulate
only, so a (min, +) contraction cannot use the systolic array.  It *is*
however a perfectly regular dense contraction, so the memory-hierarchy
discipline of a matmul kernel still applies verbatim: stream (bm, bk) /
(bk, bn) tiles HBM->VMEM, keep a (bm, bn) running-min accumulator in VMEM
scratch across the K grid dimension, and emit the tile once on the last K
step.  Inside the tile the contraction is VPU work: bk rank-1 broadcast-adds
followed by elementwise minimum, with fully aligned (8, 128)-lane shapes when
bm, bn are multiples of 128.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential on TPU, so the VMEM
accumulator carries across K steps of the same (i, j) tile).

Batched variant: the routing pipeline squares whole closure *stacks* —
``[L+1, V, V]`` (one matrix per DNN layer) or ``[U, L+1, V, V]`` (per
deduplicated job) — so :func:`minplus_matmul_pallas_batched` adds a leading
**batch grid dimension**: grid ``(B, M/bm, N/bn, K/bk)`` with block shapes
``(1, bm, bk)`` / ``(1, bk, bn)`` / ``(1, bm, bn)``.  Each batch element is
an independent (parallel) slice of the grid reusing the same VMEM
accumulator discipline; K stays innermost/sequential.  Higher-rank stacks
are flattened to one batch axis in :mod:`repro.kernels.ops` before reaching
the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _minplus_kernel(a_ref, b_ref, o_ref, acc_ref, *, bk: int, k_steps: int,
                    inner_chunk: int):
    """One (bm, bn) output tile; min-accumulate over the K grid dim."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, jnp.float32(3.0e38) / 2)

    a = a_ref[...].astype(jnp.float32)  # [bm, bk]
    b = b_ref[...].astype(jnp.float32)  # [bk, bn]

    # Contract bk in chunks: each chunk materializes a [bm, chunk, bn]
    # broadcast-sum in VREGs/VMEM and folds it into the accumulator with a
    # running min.  chunk is chosen so the intermediate stays ~1 MiB.
    def body(c, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, c * inner_chunk, inner_chunk, 1)
        b_c = jax.lax.dynamic_slice_in_dim(b, c * inner_chunk, inner_chunk, 0)
        part = jnp.min(a_c[:, :, None] + b_c[None, :, :], axis=1)  # [bm, bn]
        return jnp.minimum(acc, part)

    acc = acc_ref[...]
    acc = jax.lax.fori_loop(0, bk // inner_chunk, body, acc)
    acc_ref[...] = acc

    @pl.when(k_idx == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "inner_chunk", "interpret"))
def minplus_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    inner_chunk: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """C = A (min,+) B for 2-D operands whose dims divide the block sizes.

    Shape padding / batching live in :mod:`repro.kernels.ops`; this function
    is the raw tiled kernel.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    assert bk % inner_chunk == 0
    k_steps = k // bk

    kernel = functools.partial(
        _minplus_kernel, bk=bk, k_steps=k_steps, inner_chunk=inner_chunk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)


def _minplus_kernel_batched(a_ref, b_ref, o_ref, acc_ref, *, bk: int,
                            k_steps: int, inner_chunk: int):
    """One (bm, bn) output tile of one batch element; min-accumulate over K."""
    k_idx = pl.program_id(3)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, jnp.float32(3.0e38) / 2)

    a = a_ref[0].astype(jnp.float32)  # [bm, bk]
    b = b_ref[0].astype(jnp.float32)  # [bk, bn]

    def body(c, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, c * inner_chunk, inner_chunk, 1)
        b_c = jax.lax.dynamic_slice_in_dim(b, c * inner_chunk, inner_chunk, 0)
        part = jnp.min(a_c[:, :, None] + b_c[None, :, :], axis=1)  # [bm, bn]
        return jnp.minimum(acc, part)

    acc = acc_ref[...]
    acc = jax.lax.fori_loop(0, bk // inner_chunk, body, acc)
    acc_ref[...] = acc

    @pl.when(k_idx == k_steps - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "inner_chunk", "interpret"))
def minplus_matmul_pallas_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    inner_chunk: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """C[b] = A[b] (min,+) B[b] for [B, M, K] x [B, K, N] operands.

    The batch axis is the leading (parallel) grid dimension; within a batch
    element the tiling/accumulator scheme is identical to
    :func:`minplus_matmul_pallas`.  M, N, K must divide the block sizes —
    padding and flattening of higher-rank stacks live in
    :mod:`repro.kernels.ops`.
    """
    bsz, m, k = a.shape
    bsz2, k2, n = b.shape
    assert bsz == bsz2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (a.shape, b.shape, (bm, bn, bk))
    assert bk % inner_chunk == 0
    k_steps = k // bk

    kernel = functools.partial(
        _minplus_kernel_batched, bk=bk, k_steps=k_steps,
        inner_chunk=inner_chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda bb, i, j, kk: (bb, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda bb, i, j, kk: (bb, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda bb, i, j, kk: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
