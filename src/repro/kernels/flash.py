"""Pallas TPU kernel: causal flash attention (online-softmax blocking).

The §Roofline baseline showed every training cell memory-bound on the
materialized [B, H, S, S] score tensors (deepseek-v2 train_4k: 274 s memory
term vs 7.5 s compute).  Flash attention is the canonical TPU adaptation:
stream K/V tiles HBM->VMEM, keep the running (max, sum, weighted-acc) of the
online softmax in VMEM scratch, and never write scores to HBM — per-device
attention HBM traffic collapses from O(S^2) to O(S * d).

Layout: q/k/v are [BH, S, d] (batch*heads flattened outside; GQA/MLA map
onto this by repeating K/V heads / concatenating nope+rope dims — see
ops.flash_attention).  Grid (BH, nq, nk), nk innermost and sequential, with
f32 scratch accumulators carried across the nk dimension.  Causal blocks
with k_start > q_end are skipped entirely (pl.when), halving work.

Backward: also Pallas (``flash_bwd``): the forward saves only O and the
logsumexp L; dq/dk/dv kernels recompute p = exp(s - L) per tile, so the
backward's HBM traffic is O(S * d) as well.  ops.flash_attention wires the
three kernels through jax.custom_vjp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30  # python float: jnp constants must not be closure-captured


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, bq: int, bk: int, nk: int, scale: float, causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk
    # causal: this k block overlaps queries iff k_start <= q_end
    live = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)                    # [bq, d]
        k = k_ref[0].astype(jnp.float32)                    # [bk, d]
        v = v_ref[0].astype(jnp.float32)                    # [bk, dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                       # [bq, bk]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])                     # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "scale",
                                             "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         scale: float, causal: bool = True, bq: int = 512,
                         bk: int = 512, interpret: bool = False) -> jax.Array:
    """q, k: [BH, S, d]; v: [BH, S, dv] -> [BH, S, dv]."""
    bh, s, d = q.shape
    dv = v.shape[-1]
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward kernels.  Standard flash-attention backward with logsumexp L
# saved by the forward:  p = exp(s - L);  dv = p^T do;  dp = do v^T;
# ds = p * (dp - rowsum(do * o));  dq = ds k;  dk = ds^T q.
# Two kernels: dq accumulates over kv blocks (grid nk innermost); dk/dv
# accumulate over q blocks (grid nq innermost).
# ---------------------------------------------------------------------------

def _flash_fwd_lse_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                          acc_ref, m_ref, l_ref,
                          *, bq: int, bk: int, nk: int, scale: float,
                          causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk
    live = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l)


def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dq_ref, acc_ref,
                     *, bq: int, bk: int, nk: int, scale: float,
                     causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start, k_start = iq * bq, ik * bk
    live = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dk_ref, dv_ref, dk_acc, dv_acc,
                      *, bq: int, bk: int, nq: int, scale: float,
                      causal: bool):
    ik = pl.program_id(1)
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start, k_start = iq * bq, ik * bk
    live = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                       # [bq, bk]
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # [bk, dv]
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale              # [bq, bk]
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)             # [bk, d]

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "scale",
                                             "interpret"))
def flash_fwd_lse(q, k, v, *, scale, causal=True, bq=512, bk=512,
                  interpret=False):
    bh, s, d = q.shape
    dv = v.shape[-1]
    bq, bk = min(bq, s), min(bk, s)
    nq, nk = s // bq, s // bk
    kernel = functools.partial(_flash_fwd_lse_kernel, bq=bq, bk=bk, nk=nk,
                               scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
                   jax.ShapeDtypeStruct((bh, s), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, dv), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "scale",
                                             "interpret"))
def flash_bwd(q, k, v, o, lse, do, *, scale, causal=True, bq=512, bk=512,
              interpret=False):
    bh, s, d = q.shape
    dv = v.shape[-1]
    bq, bk = min(bq, s), min(bk, s)
    nq, nk = s // bq, s // bk
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_flash_dq_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
                          causal=causal),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv_out = pl.pallas_call(
        functools.partial(_flash_dkv_kernel, bq=bq, bk=bk, nq=nq, scale=scale,
                          causal=causal),
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bq, dv), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, s, d), q.dtype),
                   jax.ShapeDtypeStruct((bh, s, dv), q.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, dv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv_out
