"""jit'd public wrappers around the tropical kernels.

``minplus_matmul`` dispatches to the Pallas kernel when the problem is big
enough to amortize tiling (and pads to block multiples with +INF, which is
absorbing for ``min``), otherwise to the pure-jnp oracle.  On CPU the kernel
runs in interpret mode — the TPU is the target, CPU validates semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .minplus import minplus_matmul_pallas

_PAD = jnp.float32(1e30)
# Below this dimension the [n, n, n] broadcast oracle is cheaper than tiling.
_PALLAS_MIN_DIM = 256


def _should_use_pallas(m: int, k: int, n: int) -> bool:
    return min(m, k, n) >= _PALLAS_MIN_DIM


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def minplus_matmul(a: jax.Array, b: jax.Array, *, use_pallas: bool | None = None,
                   block: int = 128) -> jax.Array:
    """C[..., i, j] = min_k A[..., i, k] + B[..., k, j].

    Batched operands fall back to the oracle (vmapping the kernel is possible
    but the routing closures call the 2-D path).
    """
    if a.ndim != 2 or b.ndim != 2:
        return ref.minplus_matmul_ref(a, b)
    m, k = a.shape
    _, n = b.shape
    if use_pallas is None:
        use_pallas = _should_use_pallas(m, k, n)
    if not use_pallas:
        return ref.minplus_matmul_ref(a, b)

    pm, pk, pn = (-m) % block, (-k) % block, (-n) % block
    a_p = jnp.pad(a, ((0, pm), (0, pk)), constant_values=_PAD)
    b_p = jnp.pad(b, ((0, pk), (0, pn)), constant_values=_PAD)
    out = minplus_matmul_pallas(
        a_p, b_p, bm=block, bn=block, bk=block,
        interpret=_interpret_default())
    return out[:m, :n]


def minplus_matvec(a: jax.Array, x: jax.Array) -> jax.Array:
    return ref.minplus_matvec_ref(a, x)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def minplus_closure(w: jax.Array, *, use_pallas: bool | None = None) -> jax.Array:
    """All-pairs shortest-path distances by repeated tropical squaring.

    ``w``: [V, V] (or batched [..., V, V]) edge weights, INF-sentinel for
    absent edges. Returns D with D[u, u] = 0 and D[u, v] = min-cost path.
    ``ceil(log2(V-1))`` squarings cover all simple paths.
    """
    n = w.shape[-1]
    eye = jnp.arange(n)
    d = w.at[..., eye, eye].min(0.0)
    # After s squarings, d covers all paths of <= 2^s hops; simple paths have
    # at most n-1 hops, so ceil(log2(n-1)) squarings suffice.
    steps = max(1, (n - 1).bit_length())
    if w.ndim == 2:
        for _ in range(steps):
            d = minplus_matmul(d, d, use_pallas=use_pallas)
    else:
        for _ in range(steps):
            d = ref.minplus_matmul_ref(d, d)
    return d


# ---------------------------------------------------------------------------
# Flash attention (kernels/flash.py) with a memory-bounded XLA backward.
# ---------------------------------------------------------------------------

def _attn_ref_bhsd(q, k, v, scale):
    """Chunk-free reference math (used under jax.vjp for the backward)."""
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    n = q.shape[1]
    mask = jnp.arange(n)[:, None] >= jnp.arange(n)[None, :]
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p.astype(q.dtype), v)


@functools.lru_cache(maxsize=None)
def _make_flash(scale: float, bq: int, bk: int, interpret: bool):
    from .flash import flash_fwd_lse, flash_bwd

    @jax.custom_vjp
    def fn(q, k, v):
        o, _ = flash_fwd_lse(q, k, v, scale=scale, causal=True,
                             bq=bq, bk=bk, interpret=interpret)
        return o

    def fwd(q, k, v):
        o, lse = flash_fwd_lse(q, k, v, scale=scale, causal=True,
                               bq=bq, bk=bk, interpret=interpret)
        return o, (q, k, v, o, lse)

    def bwd(res, g):
        q, k, v, o, lse = res
        return flash_bwd(q, k, v, o, lse, g, scale=scale, causal=True,
                         bq=bq, bk=bk, interpret=interpret)

    fn.defvjp(fwd, bwd)
    return fn


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, bq: int = 512, bk: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """Causal flash attention on [BH, S, d] operands (see kernels/flash.py).

    Forward runs the Pallas kernel (scores never reach HBM); backward
    recomputes attention under jax.vjp of the reference math (remat-style).
    """
    if interpret is None:
        interpret = _interpret_default()
    bq = min(bq, q.shape[1])
    bk = min(bk, k.shape[1])
    return _make_flash(float(scale), int(bq), int(bk), bool(interpret))(
        q, k, v)
