"""jit'd public wrappers around the tropical kernels.

``minplus_matmul`` dispatches to a Pallas kernel when the problem is big
enough to amortize tiling (and pads to block multiples with +INF, which is
absorbing for ``min``), otherwise to the pure-jnp oracle.  Batched operands
(any leading stack dims, flattened to one batch axis) go to the batched
kernel, so ``[L+1, V, V]`` and ``[J, L+1, V, V]`` closure stacks stay on the
tiled path.  On CPU the kernels run in interpret mode — the TPU is the
target, CPU validates semantics.

``minplus_dispatch`` is the pure (shape -> path) decision function, exposed
so tests and benchmarks can introspect dispatch without running the kernel;
``dispatch_counts`` tallies which path each traced ``minplus_matmul`` took.
"""
from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from . import ref
from .minplus import minplus_matmul_pallas, minplus_matmul_pallas_batched

_PAD = jnp.float32(1e30)
# Below this dimension the [n, n, n] broadcast oracle is cheaper than tiling.
_PALLAS_MIN_DIM = 256

# Trace-time tally of dispatch decisions (jit caching means a hit is recorded
# once per traced shape, not once per execution) — introspection/testing aid.
_DISPATCH_COUNTS: collections.Counter = collections.Counter()


def dispatch_counts() -> dict[str, int]:
    """Copy of the {path: times-traced} tally ("oracle" | "pallas_2d" |
    "pallas_batched").

    These counters fire at **trace time**, not execution time: a jitted
    caller records each kernel choice once per compiled signature, then
    every cached re-execution runs the chosen kernel without touching the
    tally.  The distinction matters most for the fused greedy solver —
    its whole round loop (J rounds x closure squarings per round) is one
    device program, so a solve that *executes* hundreds of min-plus
    kernels adds at most a handful of entries here (and a warmed shape
    adds zero).  Per-solve execution telemetry lives in the solver's
    plan meta instead: ``meta["dispatches"]`` / ``meta["rounds_per_
    dispatch"]`` count what the device actually ran.

    Raises ``RuntimeError`` when called under an active trace: the tally
    mid-trace is a partial mixture of finished and in-flight tracings, so
    any number read there silently over/under-counts (and a traced reader
    would bake the stale snapshot into the compiled program as a
    constant).
    """
    if not jax.core.trace_state_clean():
        raise RuntimeError(
            "dispatch_counts() called under an active jax trace: the "
            "trace-time tally is mid-update, and a traced reader would "
            "bake a stale snapshot into the compiled program. Read it "
            "from host driver code after the traced call returns.")
    return dict(_DISPATCH_COUNTS)


def reset_dispatch_counts() -> None:
    _DISPATCH_COUNTS.clear()


def minplus_dispatch(a_shape: tuple[int, ...],
                     b_shape: tuple[int, ...] | None = None,
                     *, use_pallas: bool | None = None) -> str:
    """Which path ``minplus_matmul`` takes for these operand shapes.

    Returns ``"oracle"``, ``"pallas_2d"``, or ``"pallas_batched"``.  The
    decision is purely shape-based (and therefore static under jit): the
    Pallas kernels win once every contraction dim reaches ``_PALLAS_MIN_DIM``
    (or when forced via ``use_pallas=True``); mismatched leading batch dims
    always fall back to the broadcasting oracle.
    """
    b_shape = tuple(a_shape) if b_shape is None else tuple(b_shape)
    a_shape = tuple(a_shape)
    if len(a_shape) < 2 or len(b_shape) < 2 or a_shape[:-2] != b_shape[:-2]:
        return "oracle"
    m, k = a_shape[-2:]
    n = b_shape[-1]
    big = (_should_use_pallas(m, k, n) if use_pallas is None else use_pallas)
    if not big:
        return "oracle"
    return "pallas_2d" if len(a_shape) == 2 else "pallas_batched"


def _should_use_pallas(m: int, k: int, n: int) -> bool:
    return min(m, k, n) >= _PALLAS_MIN_DIM


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def minplus_matmul(a: jax.Array, b: jax.Array, *, use_pallas: bool | None = None,
                   block: int = 128) -> jax.Array:
    """C[..., i, j] = min_k A[..., i, k] + B[..., k, j].

    2-D operands use the tiled kernel; operands with (matching) leading
    batch dims are flattened to one batch axis and use the batched kernel
    (leading batch grid dimension).  Small problems and mismatched batch
    shapes use the broadcast oracle.
    """
    kind = minplus_dispatch(a.shape, b.shape, use_pallas=use_pallas)
    _DISPATCH_COUNTS[kind] += 1
    if kind == "oracle":
        return ref.minplus_matmul_ref(a, b)

    m, k = a.shape[-2:]
    n = b.shape[-1]
    pm, pk, pn = (-m) % block, (-k) % block, (-n) % block
    if kind == "pallas_2d":
        a_p = jnp.pad(a, ((0, pm), (0, pk)), constant_values=_PAD)
        b_p = jnp.pad(b, ((0, pk), (0, pn)), constant_values=_PAD)
        out = minplus_matmul_pallas(
            a_p, b_p, bm=block, bn=block, bk=block,
            interpret=_interpret_default())
        return out[:m, :n]

    lead = a.shape[:-2]
    a3 = a.reshape((-1, m, k))
    b3 = b.reshape((-1, k, n))
    a_p = jnp.pad(a3, ((0, 0), (0, pm), (0, pk)), constant_values=_PAD)
    b_p = jnp.pad(b3, ((0, 0), (0, pk), (0, pn)), constant_values=_PAD)
    out = minplus_matmul_pallas_batched(
        a_p, b_p, bm=block, bn=block, bk=block,
        interpret=_interpret_default())
    return out[:, :m, :n].reshape(lead + (m, n))


def minplus_matvec(a: jax.Array, x: jax.Array) -> jax.Array:
    return ref.minplus_matvec_ref(a, x)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def minplus_closure(w: jax.Array, *, use_pallas: bool | None = None) -> jax.Array:
    """All-pairs shortest-path distances by repeated tropical squaring.

    ``w``: [V, V] (or batched [..., V, V]) edge weights, INF-sentinel for
    absent edges. Returns D with D[u, u] = 0 and D[u, v] = min-cost path.

    After s squarings d covers all paths of <= 2^s hops and simple paths
    have at most V-1, so ``ceil(log2(V-1))`` squarings always suffice — but
    real topologies converge in ``ceil(log2(diameter))`` squarings, so the
    loop is a ``lax.while_loop`` that exits as soon as ``d == minplus(d, d)``
    (squaring a fixed point reproduces it bit-for-bit, so the early exit is
    exact).  Batched stacks exit when every batch element has converged.
    Both 2-D and batched operands stay on the Pallas path via
    :func:`minplus_matmul` dispatch.
    """
    n = w.shape[-1]
    eye = jnp.arange(n)
    d = w.at[..., eye, eye].min(0.0)
    steps = max(1, (n - 1).bit_length())

    def cond(state):
        _, i, converged = state
        return jnp.logical_and(i < steps, jnp.logical_not(converged))

    def body(state):
        d, i, _ = state
        d2 = minplus_matmul(d, d, use_pallas=use_pallas)
        return d2, i + 1, jnp.all(d2 == d)

    d, _, _ = jax.lax.while_loop(
        cond, body, (d, jnp.int32(0), jnp.asarray(False)))
    return d


# ---------------------------------------------------------------------------
# Flash attention (kernels/flash.py) with a memory-bounded XLA backward.
# ---------------------------------------------------------------------------

def _attn_ref_bhsd(q, k, v, scale):
    """Chunk-free reference math (used under jax.vjp for the backward)."""
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    n = q.shape[1]
    mask = jnp.arange(n)[:, None] >= jnp.arange(n)[None, :]
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p.astype(q.dtype), v)


@functools.lru_cache(maxsize=None)
def _make_flash(scale: float, bq: int, bk: int, interpret: bool):
    from .flash import flash_fwd_lse, flash_bwd

    @jax.custom_vjp
    def fn(q, k, v):
        o, _ = flash_fwd_lse(q, k, v, scale=scale, causal=True,
                             bq=bq, bk=bk, interpret=interpret)
        return o

    def fwd(q, k, v):
        o, lse = flash_fwd_lse(q, k, v, scale=scale, causal=True,
                               bq=bq, bk=bk, interpret=interpret)
        return o, (q, k, v, o, lse)

    def bwd(res, g):
        q, k, v, o, lse = res
        return flash_bwd(q, k, v, o, lse, g, scale=scale, causal=True,
                         bq=bq, bk=bk, interpret=interpret)

    fn.defvjp(fwd, bwd)
    return fn


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, bq: int = 512, bk: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """Causal flash attention on [BH, S, d] operands (see kernels/flash.py).

    Forward runs the Pallas kernel (scores never reach HBM); backward
    recomputes attention under jax.vjp of the reference math (remat-style).
    """
    if interpret is None:
        interpret = _interpret_default()
    bq = min(bq, q.shape[1])
    bk = min(bk, k.shape[1])
    return _make_flash(float(scale), int(bq), int(bk), bool(interpret))(
        q, k, v)
