"""Checkpointing: atomic, sharded, elastic.

Layout: <dir>/step_<n>/  with one .npz per top-level param group plus a
manifest; writes go to a tmp dir + atomic rename, so a crash mid-save never
corrupts the latest checkpoint (restart picks the newest complete manifest).

``restore`` is *elastic*: it returns host numpy trees that the caller
re-places onto whatever mesh/sharding the restarted job uses (the logical
tree is mesh-independent).  ``place`` does the device_put against a sharding
tree — growing or shrinking the mesh between save and restore is therefore
just a different ``place`` call, which tests/test_checkpoint.py exercises by
restoring a 1-device save onto an 8-host-device mesh and back.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile

import jax
import ml_dtypes
import numpy as np

# numpy can't natively serialize these; store as a same-width integer view
# plus a dtype tag in the manifest.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    dtypes = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        name = arr.dtype.name
        if name in _EXOTIC:
            dtypes[key] = name
            arr = arr.view(_EXOTIC[name][1])
        flat[key] = arr
    return flat, treedef, dtypes


def save(ckpt_dir: str | os.PathLike, step: int, tree) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat, _, dtypes = _flatten(tree)
    tmp = pathlib.Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps({
            "step": step, "keys": sorted(flat.keys()), "dtypes": dtypes,
            "complete": True}))
        final = ckpt_dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    best = None
    for d in sorted(ckpt_dir.glob("step_*")):
        man = d / "manifest.json"
        if man.exists() and json.loads(man.read_text()).get("complete"):
            best = int(d.name.split("_")[1])
    return best


def restore(ckpt_dir: str | os.PathLike, step: int, like_tree):
    """Load arrays for ``step`` shaped like ``like_tree`` (host numpy)."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    dtypes = json.loads((d / "manifest.json").read_text()).get("dtypes", {})
    flat_like, treedef, _ = _flatten(like_tree)
    out = []
    for key in flat_like:
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if key in dtypes:
            arr = arr.view(_EXOTIC[dtypes[key]][0])
        if arr.shape != flat_like[key].shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected "
                f"{flat_like[key].shape}")
        out.append(arr)
    # tree_flatten_with_path ordering == tree_flatten ordering
    return jax.tree_util.tree_unflatten(treedef, out)


def place(host_tree, sharding_tree):
    """Elastically place a restored host tree onto device shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), host_tree, sharding_tree)


def prune(ckpt_dir: str | os.PathLike, keep: int = 3) -> None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = sorted(d for d in ckpt_dir.glob("step_*") if d.is_dir())
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)
