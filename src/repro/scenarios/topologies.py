"""Parameterized topology generators: the scenario catalog's network side.

Every generator returns ``(net, names, ingress, egress)`` where ``net`` is
a fresh :class:`~repro.core.network.ComputeNetwork` (empty queues), and
ingress/egress are the node sets traffic enters/leaves through.  All
generators are deterministic in ``seed``.

Families:
  * ``paper_small``      — the paper's 5-node Fig. 2 topology.
  * ``us_backbone``      — the paper's 24-node USNET backbone (Fig. 4).
  * ``edge_cloud``       — k edge sites -> aggregation tier -> cloud; edge
                           nodes have thin compute and thin uplinks, the
                           cloud is fat on both (split-computing setting).
  * ``random_geometric`` — nodes in the unit square, links within a radius
                           (capacity falls with distance), chained into one
                           component; heterogeneous compute.
  * ``star``             — cellular: one hub with fat compute, leaves with
                           thin local compute and mixed-rate uplinks.
"""
from __future__ import annotations

import numpy as np

from repro.core import network as N

G = 1e9
MB = 1e6


def paper_small(seed: int = 0, *, capacity_scale: float = 1e-3):
    net, names = N.small_topology(capacity_scale=capacity_scale)
    return net, names, [0], [4]


def us_backbone(seed: int = 0, *, capacity_scale: float = 1e-3):
    net, names = N.us_backbone(capacity_scale=capacity_scale, seed=seed)
    # coastal ingress, interior egress (fixed, documented choice)
    return net, names, [0, 5, 10, 20], [4, 9, 15, 23]


def edge_cloud(seed: int = 0, *, n_edge: int = 6, n_agg: int = 2,
               capacity_scale: float = 1e-3):
    """Edge sites -> aggregation -> cloud hierarchy.

    Node order: [edge_0..edge_{k-1}, agg_0..agg_{m-1}, cloud].  Edge nodes
    carry thin compute (they *can* run early layers locally), aggregation
    nodes are pure forwarders, the cloud node is fat.
    """
    rng = np.random.default_rng(seed)
    v = n_edge + n_agg + 1
    cloud = v - 1
    caps = [float(rng.uniform(5, 15)) * G for _ in range(n_edge)] \
        + [0.0] * n_agg + [300 * G]
    edges = []
    for e in range(n_edge):
        agg = n_edge + (e % n_agg)
        edges.append((e, agg, float(rng.choice([125, 375])) * MB))
    for a in range(n_agg):
        edges.append((n_edge + a, cloud, 1000 * MB))
    if n_agg > 1:  # ring over the aggregation tier for cross-site paths
        for a in range(n_agg):
            edges.append((n_edge + a, n_edge + (a + 1) % n_agg, 375 * MB))
    edges = [(u, w, c * capacity_scale) for u, w, c in edges]
    names = [f"edge{i}" for i in range(n_edge)] \
        + [f"agg{i}" for i in range(n_agg)] + ["cloud"]
    net = N.make_network(v, edges, caps)
    return net, names, list(range(n_edge)), list(range(n_edge))


def random_geometric(seed: int = 0, *, num_nodes: int = 12,
                     radius: float = 0.45, capacity_scale: float = 1e-3):
    """Random geometric mesh: connect nodes within ``radius``; capacity
    decays with distance.  Components are chained by nearest cross-links so
    the graph is always connected."""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.0, 1.0, size=(num_nodes, 2))
    caps_cycle = [30, 50, 200, 100, 70]
    caps = [caps_cycle[int(rng.integers(0, 5))] * G for _ in range(num_nodes)]
    edges = []
    dist = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
    for u in range(num_nodes):
        for w in range(u + 1, num_nodes):
            if dist[u, w] <= radius:
                cap = (375 if dist[u, w] < radius / 2 else 125) * MB
                edges.append((u, w, cap))
    # Union-find to chain components with their closest cross pair.
    parent = list(range(num_nodes))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, w, _ in edges:
        parent[find(u)] = find(w)
    while len({find(i) for i in range(num_nodes)}) > 1:
        roots = {}
        for i in range(num_nodes):
            roots.setdefault(find(i), []).append(i)
        comps = list(roots.values())
        best = None
        for a in comps[0]:
            for comp in comps[1:]:
                for b in comp:
                    if best is None or dist[a, b] < dist[best[0], best[1]]:
                        best = (a, b)
        edges.append((best[0], best[1], 125 * MB))
        parent[find(best[0])] = find(best[1])
    edges = [(u, w, c * capacity_scale) for u, w, c in edges]
    names = [f"g{i}" for i in range(num_nodes)]
    net = N.make_network(num_nodes, edges, caps)
    ingress = sorted(int(i) for i in rng.choice(num_nodes, 3, replace=False))
    egress = sorted(int(i) for i in rng.choice(num_nodes, 3, replace=False))
    return net, names, ingress, egress


def star(seed: int = 0, *, num_leaves: int = 8, capacity_scale: float = 1e-3):
    """Cellular star: hub node 0 (fat compute), leaves with thin compute."""
    rng = np.random.default_rng(seed)
    v = num_leaves + 1
    caps = [200 * G] + [float(rng.uniform(10, 40)) * G
                        for _ in range(num_leaves)]
    edges = [(0, 1 + i, float(rng.choice([125, 375])) * MB * capacity_scale)
             for i in range(num_leaves)]
    names = ["hub"] + [f"leaf{i}" for i in range(num_leaves)]
    net = N.make_network(v, edges, caps)
    leaves = list(range(1, v))
    return net, names, leaves, leaves


FAMILIES = {
    "paper-small": paper_small,
    "us-backbone": us_backbone,
    "edge-cloud": edge_cloud,
    "random-geometric": random_geometric,
    "star": star,
}
