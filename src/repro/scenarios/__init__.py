"""Scenario catalog: one entry point for every benchmark, example, and test.

``make_scenario(name, seed)`` composes a topology family
(:mod:`.topologies`) with a traffic mix (:mod:`.traffic`) into a
:class:`Scenario`: the network, where traffic enters/leaves, what arrives,
and calibration helpers (``nominal_rate`` turns an offered-load factor into
an arrival rate).  Names are ``"family"`` or ``"family:traffic"``:

    sc = make_scenario("edge-cloud", seed=0)          # family default mix
    sc = make_scenario("us-backbone:paper", seed=1)   # explicit mix
    trace = repro.serving.online.run_online(sc, horizon=..., rate=...)

Catalog (see ``available_scenarios()``):

  family             default traffic   shape
  paper-small        paper             the paper's 5-node Fig. 2
  us-backbone        paper             24-node USNET backbone (Fig. 4)
  edge-cloud         lm                edge sites -> aggregation -> cloud
  random-geometric   synthetic         seeded geometric mesh
  star               synthetic         cellular hub-and-spoke
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import jobs as J
from repro.core.network import ComputeNetwork
from repro.core.state import QueueState, Topology
from .topologies import FAMILIES
from .traffic import MIXES, TrafficEntry, TrafficMix, make_traffic

_DEFAULT_TRAFFIC = {
    "paper-small": "paper",
    "us-backbone": "paper",
    "edge-cloud": "lm",
    "random-geometric": "synthetic",
    "star": "synthetic",
}


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named (topology, traffic) pairing with sampling helpers."""

    name: str
    seed: int
    topology: Topology
    node_names: tuple[str, ...]
    ingress: tuple[int, ...]
    egress: tuple[int, ...]
    traffic: TrafficMix

    @property
    def num_nodes(self) -> int:
        return self.topology.num_nodes

    @property
    def max_layers(self) -> int:
        """Common jit-stable padding width for this scenario's batches."""
        return self.traffic.max_layers

    def network(self, state: QueueState | None = None) -> ComputeNetwork:
        return self.topology.view(state)

    def sample_src_dst(self, rng: np.random.Generator) -> tuple[int, int]:
        src = int(rng.choice(self.ingress))
        egress = [e for e in self.egress if e != src] or list(self.egress)
        return src, int(rng.choice(egress))

    def sample_jobs(self, rng: np.random.Generator,
                    n: int = 1) -> list[J.InferenceJob]:
        # Names end in a monotonic per-instance sequence number, not the
        # batch index: completion tracking keys on job names (the
        # exact-drain ledger rejects repeats), and the 30-bit nonce alone
        # has ~0.4% birthday-collision odds by 3k requests.  The nonce draw
        # is kept as-is so the rng stream — and every recorded trajectory —
        # stays bit-identical.
        seq = getattr(self, "_name_seq", 0)
        out = []
        for i in range(n):
            src, dst = self.sample_src_dst(rng)
            out.append(self.traffic.sample(
                rng, f"{self.name}-{int(rng.integers(1 << 30))}-{seq + i}",
                src, dst))
        # repro-lint: disable=RL004 -- host-only name counter, never jitted
        object.__setattr__(self, "_name_seq", seq + n)
        return out

    def job_stream(self, rng: np.random.Generator, times,
                   batch_size: int = 1):
        """Lazy ``(t, jobs)`` arrival epochs for the streaming pipeline.

        Jobs are sampled *at pull time*, in arrival order — the pipeline
        consumes epochs strictly time-ordered, so the rng stream (and
        hence every job and job name) is identical to the serial
        ``run_online`` loop over the same ``times``.
        """
        for t in times:
            yield float(t), self.sample_jobs(rng, batch_size)

    @functools.cached_property
    def mean_service_s(self) -> float:
        """Mean empty-network optimal completion time of a request (s).

        The true per-request work along its critical resource chain —
        compute *and* transfers — so offered-load calibration respects
        whichever resource actually bottlenecks the scenario.
        """
        from repro.core import routing
        rng = np.random.default_rng(self.seed + 0x5EED)
        # 32 samples: enough that a lopsided mix (rare-but-heavy entries)
        # doesn't under-estimate the mean and mis-calibrate offered load.
        batch = J.batch_jobs(self.sample_jobs(rng, 32))
        costs = np.asarray(routing.route_batch(self.topology.view(),
                                               batch).cost, np.float64)
        return float(costs.mean())

    def nominal_rate(self, load: float) -> float:
        """Arrival rate (req/s) offering ``load`` x one-request-at-a-time
        service capacity: ``load / mean_service_s``.

        This is conservative (the network serves disjoint routes in
        parallel), so ``load < 1`` is comfortably sub-capacity — the regime
        the draining scheduler must hold bounded; the online benchmark
        sweeps this factor.
        """
        return load / max(self.mean_service_s, 1e-30)


def available_scenarios() -> tuple[str, ...]:
    return tuple(sorted(FAMILIES))


def make_scenario(name: str, seed: int = 0, *, traffic: str | None = None,
                  **family_opts) -> Scenario:
    """Build a scenario by name (``"family"`` or ``"family:traffic"``)."""
    family, _, mix_name = name.partition(":")
    if traffic is not None:
        if mix_name:
            raise ValueError("pass traffic either in the name or as traffic=")
        mix_name = traffic
    try:
        gen = FAMILIES[family]
    except KeyError:
        raise ValueError(
            f"unknown scenario family {family!r}; available: "
            f"{', '.join(available_scenarios())}") from None
    mix = make_traffic(mix_name or _DEFAULT_TRAFFIC[family])
    net, names, ingress, egress = gen(seed, **family_opts)
    return Scenario(
        name=f"{family}:{mix.name}", seed=seed, topology=net.topology,
        node_names=tuple(names), ingress=tuple(ingress),
        egress=tuple(egress), traffic=mix)


__all__ = [
    "Scenario", "TrafficEntry", "TrafficMix", "MIXES", "FAMILIES",
    "available_scenarios", "make_scenario", "make_traffic",
]
