"""Traffic mixes: what arrives, built from the config registry's cost profiles.

A :class:`TrafficMix` is a weighted set of request kinds.  Registry-backed
entries cost-profile a real architecture (``configs/<arch>.cost_profile``);
synthetic entries draw random fixed-shape jobs (fast, jit-shape-stable —
the choice for property tests and smoke benchmarks).  Sampling a job picks
an entry by weight and a (src, dst) pair from the scenario's ingress/egress
sets.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import jobs as J
from repro.configs import registry


@functools.lru_cache(maxsize=64)
def _arch_profile(arch: str, seq_len: int, batch: int) -> tuple[np.ndarray, np.ndarray]:
    comp, data = registry.cost_profile(arch, seq_len=seq_len, batch=batch)
    return comp.astype(np.float32), data.astype(np.float32)


@dataclasses.dataclass(frozen=True)
class TrafficEntry:
    """One request kind: a registry arch, or ``arch="synthetic"``."""

    arch: str
    weight: float = 1.0
    seq_len: int = 1024
    batch: int = 1
    # synthetic-only knobs
    num_layers: int = 6
    flops_scale: float = 1e9
    bytes_scale: float = 1e6

    @property
    def max_layers(self) -> int:
        if self.arch == "synthetic":
            return self.num_layers
        return int(_arch_profile(self.arch, self.seq_len, self.batch)[0].shape[0])

    def mean_flops(self) -> float:
        """Expected total compute of one request (synthetic: uniform mean)."""
        if self.arch == "synthetic":
            # synthetic_job draws comp ~ U(0.2, 2.0) * flops_scale per layer
            return 1.1 * self.flops_scale * self.num_layers
        return float(_arch_profile(self.arch, self.seq_len, self.batch)[0].sum())

    def make_job(self, rng: np.random.Generator, name: str, src: int,
                 dst: int) -> J.InferenceJob:
        if self.arch == "synthetic":
            return J.synthetic_job(
                name, src, dst, self.num_layers,
                seed=int(rng.integers(0, 2**31 - 1)),
                flops_scale=self.flops_scale, bytes_scale=self.bytes_scale)
        comp, data = _arch_profile(self.arch, self.seq_len, self.batch)
        return J.InferenceJob(name, src, dst, comp, data)


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    name: str
    entries: tuple[TrafficEntry, ...]

    def __post_init__(self):
        if not self.entries:
            raise ValueError("TrafficMix needs at least one entry")
        if any(e.weight <= 0 for e in self.entries):
            raise ValueError("entry weights must be positive")

    @property
    def max_layers(self) -> int:
        return max(e.max_layers for e in self.entries)

    def _probs(self) -> np.ndarray:
        w = np.array([e.weight for e in self.entries], np.float64)
        return w / w.sum()

    def mean_flops(self) -> float:
        """Expected compute per request (for offered-load calibration)."""
        return float(sum(p * e.mean_flops()
                         for p, e in zip(self._probs(), self.entries)))

    def sample(self, rng: np.random.Generator, name: str, src: int,
               dst: int) -> J.InferenceJob:
        e = self.entries[int(rng.choice(len(self.entries), p=self._probs()))]
        return e.make_job(rng, name, src, dst)


MIXES: dict[str, TrafficMix] = {
    # The paper's §V evaluation mix (2:6 VGG19:ResNet34).
    "paper": TrafficMix("paper", (
        TrafficEntry("vgg19", weight=0.25),
        TrafficEntry("resnet34", weight=0.75),
    )),
    # LM serving: mostly small models, some big-context requests.
    "lm": TrafficMix("lm", (
        TrafficEntry("smollm_135m", weight=0.7, seq_len=1024),
        TrafficEntry("olmo_1b", weight=0.3, seq_len=2048),
    )),
    # Fixed-shape random jobs: fast + one jit shape (tests, smoke benches).
    "synthetic": TrafficMix("synthetic", (
        TrafficEntry("synthetic", num_layers=6),
    )),
    "conv": TrafficMix("conv", (TrafficEntry("vgg19"),)),
}


def make_traffic(name: str) -> TrafficMix:
    try:
        return MIXES[name]
    except KeyError:
        raise ValueError(f"unknown traffic mix {name!r}; available: "
                         f"{', '.join(sorted(MIXES))}") from None
