"""The *actual system*: an event-driven, preemptive-priority simulator.

The routing formulation minimizes an upper bound on completion time (the
fictitious system of §III-B).  This module measures what actually happens
when the routed jobs run: every resource (compute node, directed link)
serves the highest-priority arrived task, preempting lower-priority work on
arrival (preempt-resume, work-conserving) — exactly the paper's scheduling
model.  Tests assert bound >= simulated completion on every instance.

``replay_solution`` reconstructs, for any (assignment, priority) solution —
raw arrays or a :class:`~repro.core.plan.Plan` — the per-job fictitious
bounds, the explicit per-layer transfer paths (chosen against the queue
state seen at that job's priority level, as both Alg. 1 and Alg. 2 do), and
the final queue state.  ``Plan.replay``/``Plan.simulate`` are the
plan-first entry points.

The inner event loop is shared machinery: :func:`run_event_loop` advances a
set of :class:`TaskRun` records (per-job stage pointers + residual work)
from ``t`` to ``t_end`` under preempt-resume priority service.  One-shot
:func:`simulate` runs it to completion from time 0; the committed-work
ledger (:mod:`repro.core.completions`) runs it *incrementally* — a ``dt``
window at a time between online arrivals — which is what makes the exact
queue drain a first-class alternative to the fluid model.

Two interchangeable engines implement the loop's semantics:

  * ``engine="ref"`` — :func:`run_event_loop_ref`, the seed's linear-scan
    loop: every event rescans every task to rebuild the per-resource
    serving heads (O(events x tasks)).  It is the semantic reference the
    indexed engine is gated against, and stays the default for the
    one-shot :func:`simulate` so its results are unchanged bit-for-bit.
  * ``engine="indexed"`` — :mod:`repro.core.eventsim`, a priority-indexed
    event engine (per-resource heaps, a global event heap, virtual-time
    residuals) that costs O(log) per event and persists across drain
    windows.  The serving hot path (:mod:`repro.core.completions`) runs on
    it; ``benchmarks/drain_bench.py`` measures the speedup and gates
    parity.

Event-time comparisons share one tolerance discipline: :func:`time_eps`
(relative to the clock — an absolute epsilon like ``t + 1e-18`` silently
degrades to exact comparison once ``t`` exceeds ~1e-2 in float64) and
:func:`work_eps` (relative to a stage's work) are used by both engines and
by :func:`repro.core.completions.exact_backlog_trace`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .network import ComputeNetwork
from .jobs import JobBatch
from . import routing


@dataclasses.dataclass(frozen=True)
class SimResult:
    completion: np.ndarray  # [J] actual completion time of each job
    makespan: float


def _as_assign_order(assign, order):
    """Accept either (assign, order) arrays or a Plan in the first slot."""
    from .plan import Plan
    if isinstance(assign, Plan):
        if order is not None:
            raise ValueError("pass either a Plan or (assign, order), not both")
        return assign.assign, assign.order
    if order is None:
        raise ValueError("order is required when assign is an array")
    return assign, order


def replay_solution(net: ComputeNetwork, batch: JobBatch, assign, order=None):
    """Replay jobs in priority order, committing loads; return bounds+paths.

    Each priority step builds the job's closure stack once
    (``shortest_path.build_closures``) and shares it across the bound
    evaluation, the path extraction, and the queue commit (3 closure builds
    per job in the seed -> 1).
    """
    import jax.numpy as jnp

    from . import shortest_path as SP

    assign, order = _as_assign_order(assign, order)
    assign = jnp.asarray(assign, jnp.int32)
    J = batch.num_jobs
    bounds = np.zeros((J,), np.float64)
    paths: dict[int, list[list[tuple[int, int]]]] = {}
    cur = net
    for p in range(J):
        j = int(order[p])
        args = (batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
                batch.num_layers[j])
        cl = SP.build_closures(cur, batch.data[j])
        bounds[j] = float(routing.cost_given_assignment(cur, *args, assign[j],
                                                        closures=cl))
        paths[j] = routing.extract_paths(cur, *args, assign[j], closures=cl)
        cur = routing.commit_assignment(cur, *args, assign[j], closures=cl)
    return bounds, paths, cur


# A work stage: (resource key, amount of work).  Resource keys are
# ("node", u) for compute (work in FLOPs) and ("link", u, v) for a directed
# transfer hop (work in bytes).
Stage = tuple[tuple, float]


def job_stages(batch: JobBatch, assign,
               paths: dict[int, list[list[tuple[int, int]]]]
               ) -> dict[int, list[Stage]]:
    """Per-job (resource, work) stage lists, in precedence order.

    Layer l's output transfer hops come before layer l+1's compute, which
    comes before layer l+1's output hops — so layer k's transfer cannot
    start (and its bytes cannot occupy a link) before layer k's compute
    completes.  This is the precedence structure both the one-shot
    simulator and the incremental committed-work drain honour.
    """
    comp = np.asarray(batch.comp, np.float64)
    data = np.asarray(batch.data, np.float64)
    nl = np.asarray(batch.num_layers)
    a = np.asarray(assign)
    stages: dict[int, list[Stage]] = {}
    for j in range(batch.num_jobs):
        L = int(nl[j])
        st: list[Stage] = []
        for l in range(L + 1):
            for (u, v) in paths[j][l]:
                st.append((("link", u, v), float(data[j, l])))
            if l < L:
                st.append((("node", int(a[j, l])), float(comp[j, l])))
        stages[j] = st
    return stages


@dataclasses.dataclass
class TaskRun:
    """Mutable run-state of one job inside the shared event loop."""

    stages: list[Stage]        # (resource, work) in precedence order
    prio: int                  # global priority (0 = served first)
    ptr: int = 0               # completed-stage count
    remaining: float | None = None  # residual work of the current stage
    arrived: float = 0.0       # instant the job became ready at this stage
    done: bool = False
    completion: float = 0.0    # valid once done


def time_eps(t: float) -> float:
    """Tolerance for event-time comparisons at clock ``t``.

    Relative to the clock magnitude: an absolute epsilon (the seed used
    ``t + 1e-18``) is below one ulp of ``t`` whenever ``t`` exceeds ~1e-2,
    so the arrival guard silently degraded to exact comparison at any
    nonzero clock.  Shared by both event-loop engines and the ledger's
    backlog trace so window boundaries and arrival cutoffs agree.
    """
    return 1e-12 * max(1.0, abs(t))


def work_eps(work: float) -> float:
    """Completion threshold for a stage of ``work`` units (relative)."""
    return 1e-12 * max(1.0, work)


def _resource_rate(res: tuple, mu_node: np.ndarray,
                   mu_link: np.ndarray) -> float:
    return float(mu_node[res[1]] if res[0] == "node"
                 else mu_link[res[1], res[2]])


def run_event_loop_ref(tasks: list[TaskRun], mu_node: np.ndarray,
                       mu_link: np.ndarray, *, t: float = 0.0,
                       t_end: float = np.inf, guard: int = 1_000_000,
                       down: frozenset | tuple = ()) -> float:
    """Preempt-resume priority service of ``tasks`` over ``[t, t_end]``.

    Every resource serves the highest-priority arrived task (strict
    priority, preempting on arrival, work-conserving).  Mutates the tasks
    in place and returns the stop time: ``t_end`` if work remains beyond
    it, else the instant the last event fired.  With the default
    ``t_end=inf`` this is exactly the one-shot simulator's loop; a finite
    ``t_end`` is the incremental drain window used by the committed-work
    ledger.

    ``down`` lists resource keys failed for the whole window: tasks whose
    current stage targets one wait (no service, no dead-resource error).
    Work stuck behind an outage at an infinite ``t_end`` raises — the
    caller must restore the resource or clear the work (recovery
    policies requeue / migrate / shed it) before running to completion.

    This is the seed's linear-scan loop (the semantic reference for
    :mod:`repro.core.eventsim`): each event rescans every task.  Service
    rates are hoisted into per-stage arrays up front — the rate of a
    (task, stage) pair never changes within a run, so the scan does one
    list index instead of two dict lookups per serving resource per event.
    """
    # Hoisted per-stage service rates, indexed [task][stage].
    stage_rates = [[_resource_rate(res, mu_node, mu_link)
                    for res, _ in task.stages] for task in tasks]
    down = frozenset(down)
    for task in tasks:
        if not task.done and task.ptr >= len(task.stages):
            task.done = True
            task.completion = task.arrived
    steps = 0
    while not all(task.done for task in tasks):
        steps += 1
        if steps > guard:
            raise RuntimeError("simulator did not converge")
        # Highest-priority arrived task per resource.
        serving: dict[tuple, tuple[TaskRun, float]] = {}
        eps = time_eps(t)
        for task, rates in zip(tasks, stage_rates):
            if task.done or task.arrived > t + eps:
                continue
            res, work = task.stages[task.ptr]
            if task.remaining is None:
                task.remaining = work
            if res in down:
                continue              # blocked on a failed resource
            cur = serving.get(res)
            if cur is None or task.prio < cur[0].prio:
                serving[res] = (task, rates[task.ptr])
        if not serving:
            # advance to the next stage-arrival (nothing serveable now).
            # With failed resources, live tasks may be *stuck* with
            # arrived <= t — jumping to min(arrived) would freeze the
            # clock and spin the guard out; only future arrivals advance.
            nxt = min((task.arrived for task in tasks
                       if not task.done and task.arrived > t + eps),
                      default=np.inf)
            if nxt >= t_end:
                if not np.isfinite(t_end) and not np.isfinite(nxt):
                    raise RuntimeError(
                        f"event loop stalled: live tasks blocked on "
                        f"failed resources {sorted(down)} — restore them "
                        f"or clear the work before running to completion")
                return t_end if np.isfinite(t_end) else t
            t = nxt
            continue
        # Next completion event.
        dt = np.inf
        for res, (task, rate) in serving.items():
            if rate <= 0:
                raise RuntimeError(
                    f"job with priority {task.prio} scheduled on dead "
                    f"resource {res}")
            dt = min(dt, task.remaining / rate)
        nxt_arr = min((task.arrived for task in tasks
                       if not task.done and task.arrived > t + eps),
                      default=np.inf)
        dt = min(dt, nxt_arr - t)
        clipped = t + dt >= t_end
        if clipped:
            dt = t_end - t  # serve the final partial slice, then stop
        t += dt
        for res, (task, rate) in serving.items():
            task.remaining -= rate * dt
            if task.remaining <= work_eps(task.stages[task.ptr][1]):
                task.remaining = None
                task.ptr += 1
                task.arrived = t
                if task.ptr >= len(task.stages):
                    task.done = True
                    task.completion = t
        if clipped:
            return t_end
    return t


def run_event_loop(tasks: list[TaskRun], mu_node: np.ndarray,
                   mu_link: np.ndarray, *, t: float = 0.0,
                   t_end: float = np.inf, guard: int = 1_000_000,
                   engine: str = "ref", down: frozenset | tuple = ()) -> float:
    """Run the preempt-resume loop with the chosen engine.

    ``engine="ref"`` (default) is the seed linear-scan loop;
    ``engine="indexed"`` routes through the O(log)-per-event engine of
    :mod:`repro.core.eventsim` — same semantics, same tolerance
    discipline, event times equal up to float accumulation order (gated by
    the parity tests and ``benchmarks/drain_bench.py``).  ``down`` lists
    resource keys failed for the whole window (both engines honour it).
    """
    if engine == "indexed":
        from . import eventsim
        return eventsim.run_event_loop_indexed(
            tasks, mu_node, mu_link, t=t, t_end=t_end, guard=guard,
            down=tuple(down))
    if engine != "ref":
        raise ValueError(f"engine must be 'ref' or 'indexed', got {engine!r}")
    return run_event_loop_ref(tasks, mu_node, mu_link, t=t, t_end=t_end,
                              guard=guard, down=down)


def simulate(net: ComputeNetwork, batch: JobBatch, assign, order=None,
             paths: dict[int, list[list[tuple[int, int]]]] | None = None,
             engine: str = "ref") -> SimResult:
    """Event-driven simulation of the routed jobs in the actual system.

    ``assign`` may be a :class:`~repro.core.plan.Plan` (then ``order`` must
    be omitted and the plan's stored paths, if any, are used).  ``engine``
    picks the event-loop implementation; the default ``"ref"`` keeps
    one-shot results bit-identical to the seed loop (``"indexed"`` agrees
    up to float accumulation order — see ``benchmarks/drain_bench.py``).
    """
    from .plan import Plan
    if isinstance(assign, Plan) and paths is None:
        paths = assign.paths
    assign, order = _as_assign_order(assign, order)
    if paths is None:
        _, paths, _ = replay_solution(net.reset_queues(), batch, assign, order)

    mu_node = np.asarray(net.mu_node, np.float64)
    mu_link = np.asarray(net.mu_link, np.float64)
    J = batch.num_jobs
    prio_of = {int(order[p]): p for p in range(len(order))}
    stages = job_stages(batch, assign, paths)
    tasks = [TaskRun(stages=stages[j], prio=prio_of[j]) for j in range(J)]
    run_event_loop(tasks, mu_node, mu_link, engine=engine)
    completion = np.array([task.completion for task in tasks], np.float64)
    return SimResult(completion=completion, makespan=float(np.max(completion)))
