"""The *actual system*: an event-driven, preemptive-priority simulator.

The routing formulation minimizes an upper bound on completion time (the
fictitious system of §III-B).  This module measures what actually happens
when the routed jobs run: every resource (compute node, directed link)
serves the highest-priority arrived task, preempting lower-priority work on
arrival (preempt-resume, work-conserving) — exactly the paper's scheduling
model.  Tests assert bound >= simulated completion on every instance.

``replay_solution`` reconstructs, for any (assignment, priority) solution —
raw arrays or a :class:`~repro.core.plan.Plan` — the per-job fictitious
bounds, the explicit per-layer transfer paths (chosen against the queue
state seen at that job's priority level, as both Alg. 1 and Alg. 2 do), and
the final queue state.  ``Plan.replay``/``Plan.simulate`` are the
plan-first entry points.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .network import ComputeNetwork
from .jobs import JobBatch
from . import routing


@dataclasses.dataclass(frozen=True)
class SimResult:
    completion: np.ndarray  # [J] actual completion time of each job
    makespan: float


def _as_assign_order(assign, order):
    """Accept either (assign, order) arrays or a Plan in the first slot."""
    from .plan import Plan
    if isinstance(assign, Plan):
        if order is not None:
            raise ValueError("pass either a Plan or (assign, order), not both")
        return assign.assign, assign.order
    if order is None:
        raise ValueError("order is required when assign is an array")
    return assign, order


def replay_solution(net: ComputeNetwork, batch: JobBatch, assign, order=None):
    """Replay jobs in priority order, committing loads; return bounds+paths.

    Each priority step builds the job's closure stack once
    (``shortest_path.build_closures``) and shares it across the bound
    evaluation, the path extraction, and the queue commit (3 closure builds
    per job in the seed -> 1).
    """
    import jax.numpy as jnp

    from . import shortest_path as SP

    assign, order = _as_assign_order(assign, order)
    assign = jnp.asarray(assign, jnp.int32)
    J = batch.num_jobs
    bounds = np.zeros((J,), np.float64)
    paths: dict[int, list[list[tuple[int, int]]]] = {}
    cur = net
    for p in range(J):
        j = int(order[p])
        args = (batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
                batch.num_layers[j])
        cl = SP.build_closures(cur, batch.data[j])
        bounds[j] = float(routing.cost_given_assignment(cur, *args, assign[j],
                                                        closures=cl))
        paths[j] = routing.extract_paths(cur, *args, assign[j], closures=cl)
        cur = routing.commit_assignment(cur, *args, assign[j], closures=cl)
    return bounds, paths, cur


def simulate(net: ComputeNetwork, batch: JobBatch, assign, order=None,
             paths: dict[int, list[list[tuple[int, int]]]] | None = None) -> SimResult:
    """Event-driven simulation of the routed jobs in the actual system.

    ``assign`` may be a :class:`~repro.core.plan.Plan` (then ``order`` must
    be omitted and the plan's stored paths, if any, are used).
    """
    from .plan import Plan
    if isinstance(assign, Plan) and paths is None:
        paths = assign.paths
    assign, order = _as_assign_order(assign, order)
    if paths is None:
        _, paths, _ = replay_solution(net.reset_queues(), batch, assign, order)

    mu_node = np.asarray(net.mu_node, np.float64)
    mu_link = np.asarray(net.mu_link, np.float64)
    comp = np.asarray(batch.comp, np.float64)
    data = np.asarray(batch.data, np.float64)
    nl = np.asarray(batch.num_layers)
    J = batch.num_jobs

    prio_of = {int(order[p]): p for p in range(len(order))}
    a = np.asarray(assign)

    # Build each job's stage list: (resource_key, work, rate)
    stages: dict[int, list[tuple[tuple, float, float]]] = {}
    for j in range(J):
        L = int(nl[j])
        st: list[tuple[tuple, float, float]] = []
        for l in range(L + 1):
            for (u, v) in paths[j][l]:
                st.append((("link", u, v), float(data[j, l]), mu_link[u, v]))
            if l < L:
                u = int(a[j, l])
                st.append((("node", u), float(comp[j, l]), mu_node[u]))
        stages[j] = st

    ptr = {j: 0 for j in range(J)}            # current stage index
    remaining = {j: None for j in range(J)}   # remaining work of current stage
    arrived = {j: 0.0 for j in range(J)}      # arrival time at current stage
    done = {j: len(stages[j]) == 0 for j in range(J)}
    completion = np.zeros((J,), np.float64)
    t = 0.0
    guard = 0
    while not all(done.values()):
        guard += 1
        if guard > 1_000_000:
            raise RuntimeError("simulator did not converge")
        # Highest-priority arrived task per resource.
        serving: dict[tuple, int] = {}
        for j in range(J):
            if done[j] or arrived[j] > t + 1e-18:
                continue
            res, work, rate = stages[j][ptr[j]]
            if remaining[j] is None:
                remaining[j] = work
            cur = serving.get(res)
            if cur is None or prio_of[j] < prio_of[cur]:
                serving[res] = j
        if not serving:
            # advance to next arrival
            pending = [arrived[j] for j in range(J) if not done[j]]
            t = min(pending)
            continue
        # Next completion event.
        dt = np.inf
        for res, j in serving.items():
            rate = stages[j][ptr[j]][2]
            if rate <= 0:
                raise RuntimeError(f"job {j} scheduled on dead resource {res}")
            dt = min(dt, remaining[j] / rate)
        nxt_arr = min((arrived[j] for j in range(J)
                       if not done[j] and arrived[j] > t + 1e-18), default=np.inf)
        dt = min(dt, nxt_arr - t)
        t += dt
        for res, j in serving.items():
            rate = stages[j][ptr[j]][2]
            remaining[j] -= rate * dt
            if remaining[j] <= 1e-12 * max(1.0, stages[j][ptr[j]][1]):
                remaining[j] = None
                ptr[j] += 1
                arrived[j] = t
                if ptr[j] >= len(stages[j]):
                    done[j] = True
                    completion[j] = t
    return SimResult(completion=completion, makespan=float(np.max(completion)))
