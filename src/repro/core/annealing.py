"""Algorithm 2: simulated annealing over (node assignment, job priority).

Faithful to the paper: odd iterations re-assign a random layer of a random
job to a random compute-capable node; even iterations swap two priorities;
Metropolis acceptance with temperature T <- T * d until T_lim.

The completion-time evaluator replays jobs in priority order against the
fictitious-system queues, exactly like the greedy commit path, with
transfers taking min-cost paths under the current queues.

Beyond the paper (recorded separately in EXPERIMENTS.md): ``anneal`` vmaps K
independent chains over one jitted move tape — a multi-start ladder that both
improves solution quality and turns the algorithm into a single large batched
tensor program (accelerator-friendly), and the whole annealing run is one
``lax.scan`` => one XLA program instead of ~10^3 Python round trips.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .network import ComputeNetwork
from .jobs import JobBatch
from .plan import Plan
from . import routing
from .shortest_path import closures_for

# Deprecated alias (one release): anneal now returns the canonical Plan.
# NB the old SAResult.priority was slot->job, i.e. the new ``Plan.order``;
# the old scalar ``.bound`` is ``Plan.bound()`` and ``.history`` lives in
# ``Plan.meta["history"]``.
SAResult = Plan


def evaluate_solution(net: ComputeNetwork, batch: JobBatch, assign: jax.Array,
                      prio: jax.Array) -> jax.Array:
    """Fictitious-system makespan bound of a full solution.

    Each replay step builds the job's closure stack once and shares it
    between the cost evaluation and the queue commit (the two used to
    recompute it independently — this evaluator is SA's inner loop, so the
    closure work halves).
    """

    def step(cur, p):
        j = prio[p]
        args = (batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
                batch.num_layers[j])
        cl = closures_for(cur, batch.data[j])
        cost = routing.cost_given_assignment(cur, *args, assign[j],
                                             closures=cl)
        cur = routing.commit_assignment(cur, *args, assign[j], closures=cl)
        return cur, cost

    _, costs = jax.lax.scan(step, net, jnp.arange(batch.num_jobs))
    return jnp.max(costs)


def _num_iters(t0: float, t_lim: float, d: float) -> int:
    return max(1, int(math.ceil(math.log(t_lim / t0) / math.log(d))))


@functools.partial(jax.jit,
                   static_argnames=("iters", "k_boltz", "block_move_prob"))
def _anneal_chain(net: ComputeNetwork, batch: JobBatch, key: jax.Array,
                  comp_nodes: jax.Array, t0: float, d: float,
                  init_assign: jax.Array | None = None,
                  init_prio: jax.Array | None = None,
                  *, iters: int, k_boltz: float = 1.0,
                  block_move_prob: float = 0.0):
    J, lmax = batch.num_jobs, batch.max_layers
    k_init, k_tape = jax.random.split(key)
    ka, kp = jax.random.split(k_init)
    if init_assign is None:
        assign0 = comp_nodes[jax.random.randint(
            ka, (J, lmax), 0, comp_nodes.shape[0])].astype(jnp.int32)
    else:
        assign0 = jnp.asarray(init_assign, jnp.int32)
    if init_prio is None:
        prio0 = jax.random.permutation(kp, jnp.arange(J, dtype=jnp.int32))
    else:
        prio0 = jnp.asarray(init_prio, jnp.int32)
    cost0 = evaluate_solution(net, batch, assign0, prio0)

    def step(carry, xs):
        assign, prio, cost, best_a, best_p, best_c, temp = carry
        it, k = xs
        kj, kl, kw, ks, ku, kb = jax.random.split(k, 6)
        odd = (it % 2) == 0  # first iteration is the paper's "odd" move

        # -- odd move: reassign (job j, layer l) -> node w.  With
        # block_move_prob > 0 (beyond-paper "SA+"), sometimes move the whole
        # job to w — mirrors the single-fast-node optima the paper observes
        # at high link capacity and radically shortens the walk to them.
        j = jax.random.randint(kj, (), 0, J)
        l = jax.random.randint(kl, (), 0, jnp.maximum(batch.num_layers[j], 1))
        w = comp_nodes[jax.random.randint(kw, (), 0, comp_nodes.shape[0])]
        single = assign.at[j, l].set(w.astype(jnp.int32))
        block = assign.at[j].set(w.astype(jnp.int32))
        use_block = jax.random.uniform(kb) < block_move_prob
        assign_new = jnp.where(use_block, block, single)

        # -- even move: swap two priority slots
        p12 = jax.random.randint(ks, (2,), 0, J)
        prio_sw = prio.at[p12[0]].set(prio[p12[1]]).at[p12[1]].set(prio[p12[0]])

        cand_assign = jnp.where(odd, assign_new, assign)
        cand_prio = jnp.where(odd, prio, prio_sw)
        cand_cost = evaluate_solution(net, batch, cand_assign, cand_prio)

        accept = jax.random.uniform(ku) < jnp.minimum(
            1.0, jnp.exp((cost - cand_cost) / (k_boltz * temp)))
        assign = jnp.where(accept, cand_assign, assign)
        prio = jnp.where(accept, cand_prio, prio)
        cost = jnp.where(accept, cand_cost, cost)

        better = cost < best_c
        best_a = jnp.where(better, assign, best_a)
        best_p = jnp.where(better, prio, best_p)
        best_c = jnp.where(better, cost, best_c)
        return (assign, prio, cost, best_a, best_p, best_c, temp * d), best_c

    keys = jax.random.split(k_tape, iters)
    carry0 = (assign0, prio0, cost0, assign0, prio0, cost0, jnp.float32(t0))
    carry, hist = jax.lax.scan(step, carry0, (jnp.arange(iters), keys))
    _, _, _, best_a, best_p, best_c, _ = carry
    return best_a, best_p, best_c, hist


def anneal(net: ComputeNetwork, batch: JobBatch, *, seed: int = 0,
           t0: float = 1.0, t_lim: float = 1e-3, d: float = 0.995,
           k_boltz: float = 1.0, num_chains: int = 1,
           init: str = "random", block_move_prob: float = 0.0) -> Plan:
    """Run Algorithm 2.

    Defaults are paper-faithful.  Beyond-paper knobs (recorded separately in
    EXPERIMENTS.md): ``num_chains`` (vmapped multi-start), ``init='greedy'``
    (warm start from Algorithm 1 — SA then only refines) and
    ``block_move_prob`` (whole-job moves).
    """
    iters = _num_iters(t0, t_lim, d)
    mu = np.asarray(net.mu_node)
    comp_nodes = jnp.asarray(np.nonzero(mu > 0)[0].astype(np.int32))
    init_assign = init_prio = None
    if init == "greedy":
        from . import greedy as _greedy
        sol = _greedy.greedy_route(net, batch)
        init_assign = jnp.asarray(sol.assign, jnp.int32)
        init_prio = jnp.asarray(sol.order, jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(seed), num_chains)
    run = functools.partial(_anneal_chain, net, batch,
                            comp_nodes=comp_nodes, t0=t0, d=d,
                            init_assign=init_assign, init_prio=init_prio,
                            iters=iters, k_boltz=k_boltz,
                            block_move_prob=block_move_prob)
    best_a, best_p, best_c, hist = jax.vmap(run)(keys)
    best_a, best_p, best_c, hist = jax.device_get((best_a, best_p, best_c, hist))
    i = int(np.argmin(best_c))
    assign = np.asarray(best_a[i])
    order = np.asarray(best_p[i])  # SA's "priority" vector is slot -> job
    # Replay the winning chain to recover per-job bounds, explicit transfer
    # paths, and the final queue state (the scalar chain cost is only the
    # makespan max).
    from . import schedule
    bounds, paths, final = schedule.replay_solution(net, batch, assign, order)
    return Plan.from_order(
        assign, order, bounds, solver="sa", paths=paths, net=final,
        meta={"history": np.min(hist, axis=0), "iters": iters,
              "num_chains": num_chains, "chain_cost": float(best_c[i]),
              "n_routings": int(iters) * int(num_chains)})
