"""Time-aware network state: immutable :class:`Topology` + fluid :class:`QueueState`.

The paper charges waiting time against queue backlogs Q but says nothing
about *time passing*: a one-shot batch evaluation only ever adds to the
queues.  For online serving the state must also **drain** — every resource
works through its backlog at its service rate mu while the clock runs.
This module is the split the rest of the stack builds on:

  * :class:`Topology` — what the network *is*: compute capacities
    ``mu_node`` [V] and link capacities ``mu_link`` [V, V].  Immutable for
    the lifetime of a deployment (straggler events scale a *view* of it,
    never mutate it).
  * :class:`QueueState` — what the network is *doing*: backlogs ``q_node``
    [V] / ``q_link`` [V, V] plus a scalar ``clock``.  :func:`advance`
    implements the fluid drain  q <- max(q - mu * dt, 0),  clock <- clock
    + dt: each resource serves its backlog at full rate (work-conserving,
    the same service model the fictitious bound charges waiting against).

Both are registered JAX pytrees, so jitted paths take them explicitly and a
:class:`~repro.core.network.ComputeNetwork` is just the zero-copy composed
view ``topology.view(state)`` — no arrays are rebuilt anywhere.

The fluid drain is exact for the bound's purposes: the waiting term
Q_u / mu_u of a backlog drained for dt seconds is exactly ``max(Q_u -
mu_u * dt, 0) / mu_u`` — the residual wait a new arrival at ``clock + dt``
would experience.  It also composes: ``advance(s, a).advance(b) ==
advance(s, a + b)`` (clipping at zero commutes with further draining),
which the property tests assert.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Topology:
    """Immutable capacities of the physical network (a JAX pytree)."""

    mu_node: jax.Array  # [V] FLOP/s (0 = no compute resources at node)
    mu_link: jax.Array  # [V, V] bytes/s (0 = no link)

    @property
    def num_nodes(self) -> int:
        return self.mu_node.shape[0]

    def empty_state(self, clock: float = 0.0) -> "QueueState":
        """All-zero backlogs at the given clock."""
        return QueueState(
            q_node=jnp.zeros_like(self.mu_node),
            q_link=jnp.zeros_like(self.mu_link),
            clock=jnp.float32(clock),
        )

    def view(self, state: "QueueState | None" = None):
        """Compose with a queue state into a :class:`ComputeNetwork` view."""
        from .network import ComputeNetwork
        return ComputeNetwork(topology=self,
                              state=self.empty_state() if state is None
                              else state)

    def scale_nodes(self, factor) -> "Topology":
        """Topology with ``mu_node * factor`` (elementwise; straggler views)."""
        return Topology(mu_node=self.mu_node * jnp.asarray(factor),
                        mu_link=self.mu_link)


def effective_topology(topo: Topology, slowdown,
                       avail_node=None, link_up=None) -> Topology:
    """Health-scaled *view* of a topology: the one rate computation shared
    by the online scheduler's drains/solves and the piecewise ground-truth
    replay, so both always see bit-identical effective rates.

    ``slowdown`` [V] follows the "factor=2 means half speed" convention
    (float32 in both callers).  ``avail_node`` [V] bool zeroes failed
    nodes' compute *and* every incident link (a dead node cannot relay);
    ``link_up`` [V, V] bool zeroes individually failed directed links.
    With both masks omitted this is exactly ``scale_nodes(1/slowdown)`` —
    the pre-fault expression, preserved bit-for-bit.
    """
    if avail_node is None and link_up is None:
        return topo.scale_nodes(1.0 / jnp.asarray(slowdown))
    avail = (np.ones((topo.num_nodes,), bool) if avail_node is None
             else np.asarray(avail_node, bool))
    scale = jnp.where(jnp.asarray(avail),
                      1.0 / jnp.asarray(slowdown), 0.0)
    mask = avail[:, None] & avail[None, :]
    if link_up is not None:
        mask = mask & np.asarray(link_up, bool)
    return Topology(mu_node=topo.mu_node * scale,
                    mu_link=topo.mu_link * jnp.asarray(mask,
                                                       topo.mu_link.dtype))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QueueState:
    """Backlogs + clock: the only mutable part of the network (a pytree)."""

    q_node: jax.Array  # [V] FLOPs queued
    q_link: jax.Array  # [V, V] bytes queued
    clock: jax.Array   # scalar f32 seconds

    def advance(self, topo: Topology, dt) -> "QueueState":
        """Fluid drain for ``dt`` seconds (see :func:`advance`)."""
        return advance(topo, self, dt)

    def with_queues(self, q_node: jax.Array, q_link: jax.Array) -> "QueueState":
        """Same clock, new backlogs."""
        return dataclasses.replace(self, q_node=q_node, q_link=q_link)


@jax.jit
def advance(topo: Topology, state: QueueState, dt) -> QueueState:
    """Drain every resource at its service rate for ``dt`` seconds.

    q <- max(q - mu * dt, 0) on nodes and links; clock <- clock + dt.
    Resources with mu == 0 hold no backlog by construction and stay at 0.

    ``clock`` is float32 (a pytree leaf under 32-bit JAX), so *accumulating*
    it here loses sub-second ticks once it exceeds ~2^24 s; long-lived
    drivers (the serving schedulers) keep an authoritative float64 clock
    host-side and stamp ``state.clock`` from it instead of summing.
    """
    dt = jnp.asarray(dt, jnp.float32)
    return QueueState(
        # repro-lint: disable=RL001 -- fluid drain IS q - mu*dt; sim state,
        q_node=jnp.maximum(state.q_node - topo.mu_node * dt, 0.0),
        # repro-lint: disable=RL001 -- not the parity-gated solver closures
        q_link=jnp.maximum(state.q_link - topo.mu_link * dt, 0.0),
        # repro-lint: disable=RL005 -- single-step add; drivers re-stamp f64
        clock=state.clock + dt,
    )


def backlog_seconds(topo: Topology, state: QueueState) -> float:
    """Worst-resource residual wait: max over nodes/links of Q / mu (host).

    This is the quantity a new top-priority arrival would wait behind at the
    most backed-up resource — the scalar the online benchmarks and the
    stability tests track over time.
    """
    mu_n = np.asarray(topo.mu_node, np.float64)
    mu_l = np.asarray(topo.mu_link, np.float64)
    q_n = np.asarray(state.q_node, np.float64)
    q_l = np.asarray(state.q_link, np.float64)
    node_wait = np.where(mu_n > 0, q_n / np.maximum(mu_n, 1e-30), 0.0)
    link_wait = np.where(mu_l > 0, q_l / np.maximum(mu_l, 1e-30), 0.0)
    return float(max(node_wait.max(initial=0.0), link_wait.max(initial=0.0)))


def total_backlog(state: QueueState) -> tuple[float, float]:
    """(sum of node backlogs in FLOPs, sum of link backlogs in bytes)."""
    return (float(np.asarray(state.q_node, np.float64).sum()),
            float(np.asarray(state.q_link, np.float64).sum()))
