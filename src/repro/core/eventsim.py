"""Indexed preempt-resume event engine: the exact drain's hot path.

The reference loop (:func:`repro.core.schedule.run_event_loop_ref`) rescans
every task at every event to rebuild the per-resource serving heads —
O(events x tasks), with every resource's rate looked up per event.  That is
fine for one-shot simulation of a small batch, but the online serving loop
in exact-drain mode runs it *per arrival*, over every live committed job,
at us-backbone:lm scale — the profile ROADMAP flagged after PR 4.

:class:`EventEngine` replaces the scan with three indexes:

  * ``ready`` — per-resource min-heaps of ``(priority, task, stage)`` over
    *arrived* tasks whose current stage runs on that resource, with lazy
    deletion: an entry is stale the moment its task moved past that stage,
    so preemption never has to find-and-remove anything.
  * a single global event heap holding only the *next* completion per busy
    resource (epoch-guarded against preemption) plus the pending stage
    arrivals — never one entry per task.
  * virtual-time residuals — a serving task's ``remaining`` is only
    materialized when its resource's serving head changes (preemption,
    completion, rate change, window end).  An uncontested stage costs one
    heap push and one pop no matter how many events fire elsewhere.

Cost per event: O(log) heap work — O((events + arrivals) * log) per drain
window instead of O(events * tasks * resources).  The engine is
*persistent*: it keeps its indexes alive across drain windows (finite
``t_end`` calls to :meth:`advance`), across commits (:meth:`add_tasks`
mid-stream), and across rate changes (:meth:`set_rates` re-prices only the
busy heads), which is how :mod:`repro.core.completions` stops rebuilding
every ``TaskRun`` per online arrival.

Semantics are the reference loop's exactly — strict priority, preempt-
resume, work-conserving, precedence via stage order, the shared
:func:`repro.core.schedule.time_eps` tolerance discipline — and event
times agree with the reference up to float accumulation order (the
reference decrements every serving residual at every global event; the
engine decrements each residual once per head change).  Parity is gated by
``tests/test_eventsim.py`` and ``benchmarks/drain_bench.py``.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from . import schedule

# Event kinds, ordered so a completion at time t fires before a stage
# arrival at the same t — the reference loop applies a step's completions
# before the next serving re-decision sees new arrivals, and the order
# matters at a knife edge: an arrival processed first would preempt a head
# whose residual just hit zero, deferring its completion by a whole
# service quantum.  (Coincidences *between* the engines' float
# accumulation orders can still race; the parity gate budgets those.)
_DONE, _ARR = 0, 1


class EventEngine:
    """Indexed preempt-resume simulator over :class:`~repro.core.schedule.TaskRun` records.

    Mutates the task records in place exactly like the reference loop
    (``ptr``/``remaining``/``arrived``/``done``/``completion``), so the
    two engines are drop-in interchangeable on the same task lists.
    """

    def __init__(self, mu_node, mu_link, *, clock: float = 0.0,
                 guard: int = 1_000_000):
        mu_node = np.asarray(mu_node, np.float64)
        mu_link = np.asarray(mu_link, np.float64)
        self.V = int(mu_node.shape[0])
        # Flat rate/backlog vectors indexed by resource id:
        # node u -> u, link (u, v) -> V + u*V + v.
        self._rate = np.concatenate([mu_node, mu_link.reshape(-1)])
        self._q = np.zeros_like(self._rate)   # residual committed work
        self.now = float(clock)
        self.guard = int(guard)
        self.tasks: list[schedule.TaskRun] = []
        self._stage_res: list[list[int]] = []  # [task][stage] -> resource id
        self._ready: dict[int, list] = {}      # res id -> heap of (prio, i, ptr)
        self._head: dict[int, int] = {}        # busy res id -> serving task
        self._head_since: dict[int, float] = {}
        self._epoch: dict[int, int] = {}       # invalidates completion events
        self._events: list = []                # (time, kind, seq, a, b)
        self._seq = 0
        self.live = 0                          # unfinished tasks
        self.events_processed = 0              # real (non-stale) events
        self.completions: list[tuple[int, float]] = []  # (task index, time)
        self._down: set[int] = set()           # failed resource ids

    # -- resource ids ---------------------------------------------------------
    def _res_id(self, res: tuple) -> int:
        if res[0] == "node":
            return int(res[1])
        return self.V + int(res[1]) * self.V + int(res[2])

    def _res_key(self, rid: int) -> tuple:
        if rid < self.V:
            return ("node", rid)
        rid -= self.V
        return ("link", rid // self.V, rid % self.V)

    # -- loading work ---------------------------------------------------------
    def add_tasks(self, tasks: list[schedule.TaskRun]) -> None:
        """Index new tasks (a committed batch, or the initial load).

        Tasks whose current stage has already arrived (``arrived <= now``
        up to :func:`~repro.core.schedule.time_eps`) enter the ready heaps
        immediately and may preempt; later stage arrivals become events.
        """
        t = self.now
        eps = schedule.time_eps(t)
        touched = set()
        for task in tasks:
            i = len(self.tasks)
            self.tasks.append(task)
            self._stage_res.append([self._res_id(res)
                                    for res, _ in task.stages])
            if task.done:
                continue
            if task.ptr >= len(task.stages):   # no work at all
                task.done = True
                task.completion = task.arrived
                self.completions.append((i, task.arrived))
                continue
            self.live += 1
            # Residual committed work into the incremental backlog arrays.
            sres = self._stage_res[i]
            for k in range(task.ptr, len(task.stages)):
                w = (task.remaining if k == task.ptr
                     and task.remaining is not None else task.stages[k][1])
                self._q[sres[k]] += w
            if task.arrived > t + eps:
                self._push_event(task.arrived, _ARR, i, task.ptr)
            else:
                if task.remaining is None:
                    task.remaining = task.stages[task.ptr][1]
                rid = sres[task.ptr]
                heapq.heappush(self._ready.setdefault(rid, []),
                               (task.prio, i, task.ptr))
                touched.add(rid)
        for rid in touched:
            self._contest(rid, t)

    # -- what-if forking ------------------------------------------------------
    def fork(self) -> "EventEngine":
        """Independent what-if copy of the live simulation, O(tasks + V^2).

        The fork owns its rate/backlog arrays, task records, ready heaps,
        event heap, and head/epoch maps, so advancing or mutating it never
        perturbs this engine — and vice versa.  Stage lists are shared
        (the engine treats ``TaskRun.stages`` as immutable), which is what
        makes the copy cheap: no ledger re-fold, no index rebuild, no
        re-routing.  Advancing a fork from the same state fires the exact
        same float operations in the same order as advancing the original,
        so predictions made on a fork are bit-identical to the realized
        trajectory until new work or health events diverge them.
        """
        new = EventEngine.__new__(EventEngine)
        new.V = self.V
        new._rate = self._rate.copy()
        new._q = self._q.copy()
        new.now = self.now
        new.guard = self.guard
        new.tasks = [dataclasses.replace(task) for task in self.tasks]
        new._stage_res = list(self._stage_res)   # inner lists are read-only
        new._ready = {rid: list(h) for rid, h in self._ready.items()}
        new._head = dict(self._head)
        new._head_since = dict(self._head_since)
        new._epoch = dict(self._epoch)
        new._events = list(self._events)
        new._seq = self._seq
        new.live = self.live
        new.events_processed = self.events_processed
        new.completions = list(self.completions)
        new._down = set(self._down)
        return new

    # -- rates ----------------------------------------------------------------
    def set_rates(self, mu_node, mu_link) -> None:
        """Re-price service (straggler events between windows).

        No-op when the rates are unchanged; otherwise materializes every
        busy head at the old rates up to ``now``, then reschedules each
        head's completion at its new rate — O(busy resources), never
        O(tasks).
        """
        rate = np.concatenate([np.asarray(mu_node, np.float64),
                               np.asarray(mu_link, np.float64).reshape(-1)])
        if np.array_equal(rate, self._rate):
            return
        t = self.now
        for rid in list(self._head):
            self._touch(rid, t)
        self._rate = rate
        for rid, i in list(self._head.items()):
            self._set_head(rid, i, t)   # epoch bump invalidates the old event

    # -- availability ---------------------------------------------------------
    def remove_resource(self, res: tuple) -> None:
        """Mark a resource failed from ``now`` on (idempotent).

        The serving head (if any) is materialized — work served before the
        failure stays served — and unseated; its scheduled completion event
        goes stale via the epoch guard (lazy invalidation, nothing is
        searched or removed from the heaps).  Ready tasks stay indexed and
        simply wait; no new head is seated until :meth:`restore_resource`.
        Clearing the blocked work itself (requeue / migrate / shed) is the
        recovery policy's job, via :meth:`remove_tasks`.
        """
        rid = self._res_id(res)
        if rid in self._down:
            return
        self._down.add(rid)
        if rid in self._head:
            self._touch(rid, self.now)
            del self._head[rid]
            del self._head_since[rid]
            self._epoch[rid] = self._epoch.get(rid, 0) + 1

    def restore_resource(self, res: tuple) -> None:
        """Resource recovered (idempotent): the highest-priority ready task
        blocked on it resumes serving from ``now`` at its banked residual."""
        rid = self._res_id(res)
        if rid not in self._down:
            return
        self._down.discard(rid)
        top = self._peek(rid)
        if top is not None:
            self._set_head(rid, top, self.now)

    def remove_tasks(self, idxs) -> None:
        """Withdraw live tasks from the simulation (fault policies: the
        job's remaining work is requeued elsewhere, migrated, or lost).

        Work already served stays served; every residual stage leaves the
        incremental backlog arrays.  No completion is recorded — the task
        goes done-without-completion, so a ledger fold simply drops it from
        the live set.  Index entries (ready heaps, pending events) go stale
        lazily, exactly like a preemption.
        """
        t = self.now
        freed = set()
        for i in idxs:
            task = self.tasks[i]
            if task.done:
                continue
            sres = self._stage_res[i]
            rid = sres[task.ptr]
            if self._head.get(rid) == i:
                self._touch(rid, t)   # bank the partial service
                del self._head[rid]
                del self._head_since[rid]
                self._epoch[rid] = self._epoch.get(rid, 0) + 1
                freed.add(rid)
            for k in range(task.ptr, len(task.stages)):
                w = (task.remaining if k == task.ptr
                     and task.remaining is not None else task.stages[k][1])
                self._q[sres[k]] -= w
            task.done = True          # withdrawn, not served to completion
            self.live -= 1
        for rid in freed:
            top = self._peek(rid)
            if top is not None:
                self._set_head(rid, top, t)

    def sync(self, mu_node, mu_link, down=()) -> None:
        """Rates + availability in one step, in the only safe order.

        ``down`` is the *authoritative* set of currently-failed resource
        keys: resources newly failed are unseated **before** re-pricing (a
        busy head on a zeroed rate would otherwise trip the dead-resource
        guard), and recoveries are re-seated **after** (at their new
        rates).  Passing ``down=()`` restores everything.
        """
        want = {self._res_id(res) for res in down}
        for rid in sorted(want - self._down):
            self.remove_resource(self._res_key(rid))
        self.set_rates(mu_node, mu_link)
        for rid in sorted(self._down - want):
            self.restore_resource(self._res_key(rid))

    # -- index internals ------------------------------------------------------
    def _push_event(self, time: float, kind: int, a: int, b: int) -> None:
        self._seq += 1
        heapq.heappush(self._events, (time, kind, self._seq, a, b))

    def _peek(self, rid: int):
        """Min-priority *valid* ready task on ``rid`` (lazy deletion)."""
        h = self._ready.get(rid)
        while h:
            prio, i, ptr = h[0]
            task = self.tasks[i]
            if task.done or task.ptr != ptr:
                heapq.heappop(h)      # stale: task moved on
                continue
            return i
        return None

    def _touch(self, rid: int, t: float) -> None:
        """Materialize the head's virtual-time residual up to ``t``."""
        i = self._head.get(rid)
        if i is None:
            return
        dt = t - self._head_since[rid]
        if dt > 0.0:
            served = self._rate[rid] * dt
            self.tasks[i].remaining -= served
            self._q[rid] -= served
        self._head_since[rid] = t

    def _set_head(self, rid: int, i: int, t: float) -> None:
        if rid in self._down:
            return                    # failed resource serves nothing
        task = self.tasks[i]
        rate = self._rate[rid]
        if rate <= 0:
            raise RuntimeError(
                f"job with priority {task.prio} scheduled on dead "
                f"resource {self._res_key(rid)}")
        self._head[rid] = i
        self._head_since[rid] = t
        ep = self._epoch[rid] = self._epoch.get(rid, 0) + 1
        self._push_event(t + task.remaining / rate, _DONE, rid, ep)

    def _contest(self, rid: int, t: float) -> None:
        """Re-decide the serving head after ready-heap pushes."""
        if rid in self._down:
            return                    # ready work waits out the outage
        top = self._peek(rid)
        cur = self._head.get(rid)
        if top is None or top == cur:
            return
        if cur is not None:
            self._touch(rid, t)       # preempted: bank the served work
        self._set_head(rid, top, t)

    # -- event firing ---------------------------------------------------------
    def _fire_arr(self, t: float, i: int, ptr: int) -> bool:
        task = self.tasks[i]
        if task.done or task.ptr != ptr:
            return False
        if task.remaining is None:
            task.remaining = task.stages[ptr][1]
        rid = self._stage_res[i][ptr]
        heapq.heappush(self._ready.setdefault(rid, []), (task.prio, i, ptr))
        self._contest(rid, t)
        return True

    def _fire_done(self, t: float, rid: int, ep: int) -> bool:
        if self._epoch.get(rid) != ep:
            return False              # head changed since this was scheduled
        i = self._head.pop(rid)
        del self._head_since[rid]
        self._epoch[rid] = ep + 1
        task = self.tasks[i]
        self._q[rid] -= task.remaining   # residual since the last touch
        task.remaining = None
        task.ptr += 1
        task.arrived = t
        if task.ptr >= len(task.stages):
            task.done = True
            task.completion = t
            self.live -= 1
            self.completions.append((i, t))
        else:
            # Next stage arrives here and now; its heap entry is pushed
            # before the freed resource re-decides, so a same-resource
            # follow-on stage (consecutive layers on one node) contends.
            task.remaining = task.stages[task.ptr][1]
            rid2 = self._stage_res[i][task.ptr]
            heapq.heappush(self._ready.setdefault(rid2, []),
                           (task.prio, i, task.ptr))
            if rid2 != rid:
                self._contest(rid2, t)
        top = self._peek(rid)
        if top is not None:
            self._set_head(rid, top, t)
        return True

    # -- driving --------------------------------------------------------------
    def advance(self, t_end: float = np.inf) -> float:
        """Serve until ``t_end`` (or to completion when infinite).

        Fires events in time order; with a finite window, busy heads are
        materialized at ``t_end`` so residuals (and the backlog arrays)
        reflect the partial slice — exactly the reference loop's clipped
        final step.  Returns the reference loop's stop time: ``t_end`` if
        work remains beyond it, else the instant the last event fired.
        """
        t_end = float(t_end)
        steps = 0
        last = self.now
        while self.live > 0 and self._events:
            time = self._events[0][0]
            if time > t_end:
                break
            _, kind, _, a, b = heapq.heappop(self._events)
            fired = (self._fire_arr(time, a, b) if kind == _ARR
                     else self._fire_done(time, a, b))
            if fired:
                last = max(last, float(time))
                self.now = max(self.now, float(time))
                steps += 1
                self.events_processed += 1
                if steps > self.guard:
                    raise RuntimeError("simulator did not converge")
        if np.isfinite(t_end):
            for rid in list(self._head):
                self._touch(rid, t_end)
            self.now = t_end
            return t_end if self.live > 0 else last
        if self.live > 0:
            if self._down:
                raise RuntimeError(
                    f"{self.live} live task(s) blocked on failed resources "
                    f"{sorted(self._res_key(r) for r in self._down)} with "
                    f"no pending events: restore the resources or clear "
                    f"the work first (recovery policies requeue, migrate, "
                    f"or shed it)")
            raise RuntimeError(
                "event engine stalled with live tasks and no events — "
                "index invariant broken")
        return self.now

    def materialize(self) -> None:
        """Bank every busy head's virtual-time residual up to ``now``."""
        for rid in list(self._head):
            self._touch(rid, self.now)

    def queue_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """Residual committed work per resource, materialized to ``now``.

        float64 ``(q_node [V], q_link [V, V])`` maintained incrementally —
        O(V^2) copy, never a rescan of live jobs.  Clamped at zero (float
        drift from incremental subtraction is ~1 ulp per event).
        """
        self.materialize()
        qn = np.maximum(self._q[:self.V], 0.0)
        ql = np.maximum(self._q[self.V:], 0.0).reshape(self.V, self.V)
        return qn, ql


def run_event_loop_indexed(tasks: list[schedule.TaskRun], mu_node, mu_link,
                           *, t: float = 0.0, t_end: float = np.inf,
                           guard: int = 1_000_000,
                           down: tuple = ()) -> float:
    """Drop-in replacement for :func:`repro.core.schedule.run_event_loop_ref`.

    Builds a fresh engine over ``tasks`` and advances it — same mutation
    contract, same return value.  ``down`` lists resource keys failed for
    the whole window (work on them waits).  For the persistent
    (cross-window) use hold an :class:`EventEngine` instead.
    """
    eng = EventEngine(mu_node, mu_link, clock=t, guard=guard)
    for res in down:
        eng.remove_resource(res)
    eng.add_tasks(tasks)
    return eng.advance(t_end)
