"""Theorem 2 approximation-ratio machinery.

``alpha(net, jobs)`` evaluates the paper's bound

    alpha = max{ 2*a_tx, 2(L+1)(|V_p|+|E_p|)*a_tx / k, (1+|E_p|/|V_p|)*a_cp }
            * (2 - 1/(|V_p|+|E_p|))

with |V_p| = #nodes with positive compute, |E_p| = #links with finite
capacity, k = edge connectivity, a_tx / a_cp the heterogeneity ratios, and
h_L / h_S the longest/shortest s-t hop counts (longest simple path is
exact for small graphs, else upper-bounded by |V|-1 — an upper bound on
h_L only ever loosens alpha, so the bound stays valid).

``service_lower_bounds`` gives Lemma 8's two lower bounds on T*.
"""
from __future__ import annotations


import networkx as nx
import numpy as np

from .network import ComputeNetwork
from .jobs import InferenceJob
from . import routing


def _nx_graph(net: ComputeNetwork) -> nx.Graph:
    g = nx.Graph()
    mu = np.asarray(net.mu_link)
    v = net.num_nodes
    g.add_nodes_from(range(v))
    for u in range(v):
        for w in range(v):
            if mu[u, w] > 0:
                g.add_edge(u, w)
    return g


def _longest_simple_path_len(g: nx.Graph, s: int, t: int, exact_max_nodes: int = 10) -> int:
    if g.number_of_nodes() <= exact_max_nodes:
        best = 0
        for path in nx.all_simple_paths(g, s, t):
            best = max(best, len(path) - 1)
        return best
    return g.number_of_nodes() - 1  # safe upper bound


def alpha(net: ComputeNetwork, jobs: list[InferenceJob]) -> float:
    g = _nx_graph(net)
    mu_n = np.asarray(net.mu_node, np.float64)
    mu_l = np.asarray(net.mu_link, np.float64)
    comp_nodes = mu_n[mu_n > 0]
    n_v = int((mu_n > 0).sum())
    n_e = g.number_of_edges()
    k = nx.edge_connectivity(g)
    L = max(j.num_layers for j in jobs)

    h_long = max(_longest_simple_path_len(g, j.src, j.dst) for j in jobs)
    h_short = min(nx.shortest_path_length(g, j.src, j.dst) for j in jobs)
    h_short = max(h_short, 1)

    d_all = np.concatenate([j.data for j in jobs])
    d_all = d_all[d_all > 0]
    links = mu_l[mu_l > 0]
    a_tx = (h_long * d_all.max() * links.max()) / (h_short * d_all.min() * links.min())
    a_cp = comp_nodes.max() / comp_nodes.min()

    core = max(2 * a_tx,
               2 * (L + 1) * (n_v + n_e) * a_tx / max(k, 1),
               (1 + n_e / n_v) * a_cp)
    return float(core * (2 - 1.0 / (n_v + n_e)))


def corollary1_factor(net: ComputeNetwork) -> float:
    """2 - 1/|V_p| (zero network delay, identical compute capacities)."""
    mu_n = np.asarray(net.mu_node)
    n_v = int((mu_n > 0).sum())
    return 2 - 1.0 / n_v


def service_lower_bounds(net: ComputeNetwork, batch) -> tuple[np.ndarray, float]:
    """Lemma 8: per-job S^SS (a lower bound on T*) and the averaged bound.

    S_j^SS is the fastest possible service time of job j = its optimal route
    in the empty-queue network (waiting terms vanish, objective = service).
    """
    empty = net.reset_queues()
    r = routing.route_batch(empty, batch)
    s_ss = np.asarray(r.cost, np.float64)
    mu_n = np.asarray(net.mu_node)
    g = _nx_graph(net)
    denom = int((mu_n > 0).sum()) + g.number_of_edges()
    return s_ss, float(s_ss.sum() / denom)
