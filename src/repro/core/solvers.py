"""One entry point for every routing algorithm: ``solve(net, batch, method=...)``.

Every algorithm — greedy (Alg. 1), lazy greedy, simulated annealing
(Alg. 2), the exact oracle — is a :class:`Solver`: a callable
``(net, batch, **opts) -> Plan``.  Solvers live in a registry keyed by a
short method name, so choosing an algorithm is a string flag everywhere
(serving scheduler, launch drivers, benchmarks) and a new solver (beam
search, LP rounding, multi-objective) is a drop-in registration:

    from repro.core import solvers

    @solvers.register("beam")
    def beam_solve(net, batch, *, width=8, **opts) -> Plan:
        ...

    plan = solvers.solve(net, batch, method="beam", width=16)

Built-in methods: ``greedy``, ``lazy``, ``sa``, ``exact``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Protocol, runtime_checkable

from .network import ComputeNetwork
from .state import QueueState, Topology
from .jobs import JobBatch
from .plan import Plan
from .shortest_path import closure_build_count


@runtime_checkable
class Solver(Protocol):
    """A routing algorithm: maps (network, job batch, options) to a Plan."""

    def __call__(self, net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
        ...


_REGISTRY: dict[str, Solver] = {}


def register(name: str) -> Callable[[Solver], Solver]:
    """Decorator: register a solver under ``name`` (overwrites silently so
    downstream code can shadow a built-in with a tuned variant)."""

    def deco(fn: Solver) -> Solver:
        _REGISTRY[name] = fn
        return fn

    return deco


def available() -> tuple[str, ...]:
    """Registered method names, sorted."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Solver:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; available: {', '.join(available())}"
        ) from None


def solve(net: ComputeNetwork | Topology, batch: JobBatch,
          method: str = "greedy", *, state: QueueState | None = None,
          **opts) -> Plan:
    """Route a job batch with the named algorithm; always returns a Plan.

    ``net`` may be a fused :class:`ComputeNetwork` view or an immutable
    :class:`Topology` with the queue ``state`` passed explicitly — the
    online scheduler's calling convention (``solve(topo, batch,
    state=qs)``); the two are composed zero-copy.

    The plan's ``meta`` records the method name, wall-clock solve time
    (``meta["solve_s"]``), and the number of host-level min-plus closure
    builds the solve triggered (``meta["closure_builds"]`` — the hot-spot
    metric the closure-reuse pipeline minimizes) on top of whatever the
    solver itself reports.
    """
    if isinstance(net, Topology):
        net = net.view(state)
    elif state is not None:
        raise ValueError("state= is only meaningful with a Topology first arg")
    fn = get(method)
    n0 = closure_build_count()
    t0 = time.perf_counter()
    plan = fn(net, batch, **opts)
    if not isinstance(plan, Plan):
        raise TypeError(f"solver {method!r} returned {type(plan).__name__}, "
                        "expected Plan")
    # Fresh meta dict: a solver may return a shared/cached Plan, and the
    # caller's copy must not have its provenance clobbered by later calls.
    meta = {"method": method, **plan.meta,
            "solve_s": time.perf_counter() - t0,
            "closure_builds": closure_build_count() - n0}
    return dataclasses.replace(plan, meta=meta)


# -- built-ins --------------------------------------------------------------

@register("greedy")
def _solve_greedy(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import greedy
    return greedy.greedy_route(net, batch, **opts)


@register("lazy")
def _solve_lazy(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import greedy
    return greedy.greedy_route(net, batch, lazy=True, **opts)


@register("sa")
def _solve_sa(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import annealing
    return annealing.anneal(net, batch, **opts)


@register("exact")
def _solve_exact(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import exact
    return exact.exact_plan(net, batch, **opts)


@register("migrate")
def _solve_migrate(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    # Importing the fault layer re-registers the real function over this
    # stub; either path runs the same solver.
    from repro.serving import faults
    return faults.migrate_solve(net, batch, **opts)
