"""One entry point for every routing algorithm: ``solve(net, batch, method=...)``.

Every algorithm — greedy (Alg. 1), lazy greedy, simulated annealing
(Alg. 2), the exact oracle — is a :class:`Solver`: a callable
``(net, batch, **opts) -> Plan``.  Solvers live in a registry keyed by a
short method name, so choosing an algorithm is a string flag everywhere
(serving scheduler, launch drivers, benchmarks) and a new solver (beam
search, LP rounding, multi-objective) is a drop-in registration:

    from repro.core import solvers

    @solvers.register("beam")
    def beam_solve(net, batch, *, width=8, **opts) -> Plan:
        ...

    plan = solvers.solve(net, batch, method="beam", width=16)

Built-in methods: ``greedy`` (fused single-dispatch), ``greedy_ref`` (the
host-driven round loop the fused solver is parity-gated against), ``lazy``,
``sa``, ``exact``.  :func:`solve_fused` is the cross-arrival entry: several
queued arrival windows solved in one padded multi-window dispatch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Protocol, runtime_checkable

from .network import ComputeNetwork
from .state import QueueState, Topology
from .jobs import JobBatch
from .plan import Plan
from .shortest_path import closure_build_count


@runtime_checkable
class Solver(Protocol):
    """A routing algorithm: maps (network, job batch, options) to a Plan."""

    def __call__(self, net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
        ...


_REGISTRY: dict[str, Solver] = {}


def register(name: str) -> Callable[[Solver], Solver]:
    """Decorator: register a solver under ``name`` (overwrites silently so
    downstream code can shadow a built-in with a tuned variant)."""

    def deco(fn: Solver) -> Solver:
        _REGISTRY[name] = fn
        return fn

    return deco


def available() -> tuple[str, ...]:
    """Registered method names, sorted."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Solver:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; available: {', '.join(available())}"
        ) from None


def solve(net: ComputeNetwork | Topology, batch: JobBatch,
          method: str = "greedy", *, state: QueueState | None = None,
          **opts) -> Plan:
    """Route a job batch with the named algorithm; always returns a Plan.

    ``net`` may be a fused :class:`ComputeNetwork` view or an immutable
    :class:`Topology` with the queue ``state`` passed explicitly — the
    online scheduler's calling convention (``solve(topo, batch,
    state=qs)``); the two are composed zero-copy.

    The plan's ``meta`` records the method name, wall-clock solve time
    (``meta["solve_s"]``), and the number of host-level min-plus closure
    builds the solve triggered (``meta["closure_builds"]`` — the hot-spot
    metric the closure-reuse pipeline minimizes) on top of whatever the
    solver itself reports.
    """
    if isinstance(net, Topology):
        net = net.view(state)
    elif state is not None:
        raise ValueError("state= is only meaningful with a Topology first arg")
    fn = get(method)
    n0 = closure_build_count()
    t0 = time.perf_counter()
    plan = fn(net, batch, **opts)
    if not isinstance(plan, Plan):
        raise TypeError(f"solver {method!r} returned {type(plan).__name__}, "
                        "expected Plan")
    # Fresh meta dict: a solver may return a shared/cached Plan, and the
    # caller's copy must not have its provenance clobbered by later calls.
    meta = {"method": method, **plan.meta,
            "solve_s": time.perf_counter() - t0,
            "closure_builds": closure_build_count() - n0}
    return dataclasses.replace(plan, meta=meta)


def solve_fused(net: ComputeNetwork | Topology, batches: list[JobBatch],
                *, state: QueueState | None = None, pad_to: int | None = None,
                **opts) -> list[Plan]:
    """Solve several queued arrival windows in **one** fused dispatch.

    ``batches`` are solved in order, each against the previous window's
    committed queues — bit-identical to sequential ``solve(method="greedy")``
    calls threading the state by hand, but the whole chain is one padded
    multi-window device program (``greedy.greedy_route_windows``).  All
    windows must share a padded layer width; ``pad_to`` asserts it (callers
    that built their batches with ``batch_jobs(pad_to=...)`` pass the same
    value).  Returns one Plan per window; each plan's ``net`` carries that
    window's post-commit queue state and its ``meta`` the shared-dispatch
    accounting (``solve_s`` is the whole call's wall; ``solve_share_s`` the
    per-window share).
    """
    from . import greedy
    if isinstance(net, Topology):
        net = net.view(state)
    elif state is not None:
        raise ValueError("state= is only meaningful with a Topology first arg")
    if pad_to is not None:
        bad = [b.max_layers for b in batches if b.max_layers != pad_to]
        if bad:
            raise ValueError(f"every window must be padded to pad_to="
                             f"{pad_to}; got layer widths {bad}")
    n0 = closure_build_count()
    t0 = time.perf_counter()
    plans = greedy.greedy_route_windows(net, batches, **opts)
    wall = time.perf_counter() - t0
    builds = closure_build_count() - n0
    return [dataclasses.replace(p, meta={
        "method": "greedy", **p.meta, "solve_s": wall,
        "solve_share_s": wall / max(len(plans), 1),
        "closure_builds": builds}) for p in plans]


# -- built-ins --------------------------------------------------------------

@register("greedy")
def _solve_greedy(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import greedy
    return greedy.greedy_route(net, batch, **opts)


@register("greedy_ref")
def _solve_greedy_ref(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import greedy
    return greedy.greedy_route_ref(net, batch, **opts)


@register("lazy")
def _solve_lazy(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import greedy
    return greedy.greedy_route(net, batch, lazy=True, **opts)


@register("sa")
def _solve_sa(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import annealing
    return annealing.anneal(net, batch, **opts)


@register("exact")
def _solve_exact(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    from . import exact
    return exact.exact_plan(net, batch, **opts)


@register("migrate")
def _solve_migrate(net: ComputeNetwork, batch: JobBatch, **opts) -> Plan:
    # Importing the fault layer re-registers the real function over this
    # stub; either path runs the same solver.
    from repro.serving import faults
    return faults.migrate_solve(net, batch, **opts)
