"""The canonical solver result: one :class:`Plan` for every algorithm.

The paper's point is that node selection and path selection collapse into a
single routing problem on the layered graph; accordingly every solver —
greedy (Alg. 1), lazy greedy, simulated annealing (Alg. 2), the exact
oracles — returns the *same* artifact.  A ``Plan`` pins down a full
multi-job solution:

  * ``assign  [J, Lmax]`` — compute node of each (real) layer of each job,
  * ``priority [J]``      — priority slot of each job (0 = highest),
  * ``bounds  [J]``       — per-job fictitious-system completion bounds
                            C_j(Q_p) evaluated at that job's priority level,
  * ``paths``             — optional explicit per-layer transfer hop lists
                            (filled by :meth:`replay`; consumed by the
                            event-driven simulator),
  * ``net``               — optional final queue state after committing all
                            jobs (what a scheduler carries forward),
  * ``solver`` / ``meta`` — provenance: which algorithm produced it and any
                            solver-specific metadata (iteration history,
                            routing counts, ...).

``to_dict()``/``from_dict()`` round-trip losslessly through JSON so plans
can be shipped over the serving control plane, cached, or diffed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import numpy as np

from .network import ComputeNetwork

# Explicit hop lists: paths[j][l] = ((u, v), ...) for layer-l output of job j.
PathTable = dict[int, list[list[tuple[int, int]]]]

_PLAN_VERSION = 1


def _jsonable(x: Any) -> Any:
    """Best-effort conversion of metadata values to JSON-native types."""
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return x


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """A complete multi-job routing solution (any solver)."""

    assign: np.ndarray                 # [J, Lmax] int32
    priority: np.ndarray               # [J] int32, slot of each job
    bounds: np.ndarray                 # [J] float64 fictitious bounds
    solver: str = "unknown"
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    paths: PathTable | None = None
    net: ComputeNetwork | None = None  # final queue state after commit

    def __post_init__(self):
        object.__setattr__(self, "assign",
                           np.asarray(self.assign, np.int32))
        object.__setattr__(self, "priority",
                           np.asarray(self.priority, np.int32))
        object.__setattr__(self, "bounds",
                           np.asarray(self.bounds, np.float64))
        J = self.priority.shape[0]
        if self.assign.shape[0] != J or self.bounds.shape[0] != J:
            raise ValueError("assign/priority/bounds disagree on J")
        if sorted(self.priority.tolist()) != list(range(J)):
            raise ValueError("priority must be a permutation of 0..J-1")

    # -- structure ----------------------------------------------------------
    @property
    def num_jobs(self) -> int:
        return self.priority.shape[0]

    @property
    def order(self) -> np.ndarray:
        """[J] job index per priority slot (slot 0 = highest)."""
        order = np.empty_like(self.priority)
        order[self.priority] = np.arange(self.num_jobs, dtype=np.int32)
        return order

    @property
    def makespan_bound(self) -> float:
        return float(np.max(self.bounds))

    def bound(self) -> float:
        """Fictitious-system makespan bound max_j C_j(Q_p)."""
        return self.makespan_bound

    def job_assign(self, j: int, num_layers: int) -> np.ndarray:
        """Unpadded per-layer assignment of job ``j``."""
        return self.assign[j, :num_layers]

    # -- evaluation ---------------------------------------------------------
    def simulate(self, net: ComputeNetwork, batch):
        """Event-driven actual-system simulation of this plan.

        Stored transfer paths (filled by :meth:`replay` or a replaying
        solver) are used as-is — they must have been derived against this
        same ``net``; for a different network, re-derive first
        (``plan.replay(net, batch).simulate(net, batch)``).  With no stored
        paths they are recomputed by replaying against ``net`` with queues
        reset.
        """
        from . import schedule
        return schedule.simulate(net, batch, self.assign, self.order,
                                 paths=self.paths)

    def commit(self, net: ComputeNetwork, batch) -> ComputeNetwork:
        """Queue state after committing every job in priority order."""
        from . import schedule
        _, _, final = schedule.replay_solution(net, batch, self.assign,
                                               self.order)
        return final

    def replay(self, net: ComputeNetwork, batch) -> "Plan":
        """Re-derive bounds, explicit paths, and final queues against ``net``.

        Returns a new Plan with the same (assign, priority) but with
        ``bounds``/``paths``/``net`` recomputed — the way both Alg. 1 and
        Alg. 2 score a solution, so a deserialized or hand-edited plan can
        be re-validated before deployment.
        """
        from . import schedule
        bounds, paths, final = schedule.replay_solution(
            net, batch, self.assign, self.order)
        return dataclasses.replace(self, bounds=bounds, paths=paths,
                                   net=final)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        """Lossless JSON-native representation.

        assign/priority are exact (ints); bounds are float64 and JSON
        numbers are IEEE doubles, so the round-trip is bit-exact.  Queue
        state (float32) survives exactly for the same reason.
        """
        d: dict[str, Any] = {
            "version": _PLAN_VERSION,
            "solver": self.solver,
            "assign": self.assign.tolist(),
            "priority": self.priority.tolist(),
            "bounds": self.bounds.tolist(),
            "meta": _jsonable(self.meta),
        }
        if self.paths is not None:
            d["paths"] = {str(j): [[list(h) for h in layer] for layer in p]
                          for j, p in self.paths.items()}
        if self.net is not None:
            d["net"] = {
                "mu_node": np.asarray(self.net.mu_node).tolist(),
                "mu_link": np.asarray(self.net.mu_link).tolist(),
                "q_node": np.asarray(self.net.q_node).tolist(),
                "q_link": np.asarray(self.net.q_link).tolist(),
                "clock": float(np.asarray(self.net.clock)),
            }
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Plan":
        if int(d.get("version", 1)) != _PLAN_VERSION:
            raise ValueError(f"unsupported plan version {d.get('version')}")
        paths: PathTable | None = None
        if "paths" in d:
            paths = {int(j): [[tuple(h) for h in layer] for layer in p]
                     for j, p in d["paths"].items()}
        net = None
        if "net" in d:
            import jax.numpy as jnp
            nd = d["net"]
            net = ComputeNetwork.of(
                mu_node=jnp.asarray(nd["mu_node"], jnp.float32),
                mu_link=jnp.asarray(nd["mu_link"], jnp.float32),
                q_node=jnp.asarray(nd["q_node"], jnp.float32),
                q_link=jnp.asarray(nd["q_link"], jnp.float32),
                clock=float(nd.get("clock", 0.0)),
            )
        return cls(
            assign=np.asarray(d["assign"], np.int32),
            priority=np.asarray(d["priority"], np.int32),
            bounds=np.asarray(d["bounds"], np.float64),
            solver=str(d.get("solver", "unknown")),
            meta=dict(d.get("meta", {})),
            paths=paths,
            net=net,
        )

    @classmethod
    def from_order(cls, assign, order, bounds, **kw) -> "Plan":
        """Build a Plan from slot->job ``order`` (inverts it to priority)."""
        order = np.asarray(order, np.int32)
        priority = np.empty_like(order)
        priority[order] = np.arange(order.shape[0], dtype=np.int32)
        return cls(assign=assign, priority=priority, bounds=bounds, **kw)
