"""Shortest-path machinery on the physical network, per DNN layer.

For layer l, every intra-layer edge (u, v) of the layered graph costs

    w_l(u, v) = (d_l + Q_uv) / mu_uv        (service + waiting, paper §III-B)

``transfer_closure`` returns the [L+1, V, V] tensor T where T[l, u, v] is the
cheapest way to move layer-l output from u to v (possibly multi-hop).  It is
the min-plus closure of w_l, the kernel hot-spot (see kernels/minplus.py).

``reconstruct_hop`` recovers an explicit hop from the closure: from u toward
v, the next hop is argmin_w  w_l(u, w) + T[l, w, v].  Walking this greedy
next-hop V-1 times yields a shortest path; it is used to commit link loads in
the greedy algorithm and to hand explicit paths to the event simulator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .network import INF, ComputeNetwork, link_invrate, link_wait


def layer_edge_weights(net: ComputeNetwork, data_sizes: jax.Array) -> jax.Array:
    """[L+1, V, V] per-layer intra-layer edge weights.

    data_sizes: [L+1] bytes (d_0 .. d_L). Absent edges get INF; the diagonal
    is 0 (staying put is free).
    """
    inv = link_invrate(net)  # [V, V], INF off-graph, 0 diag
    wait = link_wait(net)    # [V, V], 0 diag
    w = data_sizes[:, None, None] * inv[None] + wait[None]
    return jnp.minimum(w, INF)


def transfer_closure(net: ComputeNetwork, data_sizes: jax.Array,
                     *, use_pallas: bool | None = None) -> jax.Array:
    """[L+1, V, V] min-cost transfer tensor T_l = closure(w_l)."""
    w = layer_edge_weights(net, data_sizes)
    return ops.minplus_closure(w, use_pallas=use_pallas)


def reconstruct_path(w: jax.Array, t: jax.Array, src: jax.Array, dst: jax.Array,
                     max_hops: int) -> jax.Array:
    """Explicit path from src to dst under edge weights w and closure t.

    Returns hops [max_hops, 2] int32 (u, v) pairs, padded with (-1, -1) once
    dst is reached. jit/vmap friendly (fixed max_hops).
    """

    def body(carry, _):
        cur, done = carry
        # next hop minimizing edge + remaining distance; exclude the zero-cost
        # self-loop (diagonal) so ties never stall the walk
        cand = (w[cur] + t[:, dst]).at[cur].set(INF)
        nxt = jnp.argmin(cand).astype(jnp.int32)
        arrived = cur == dst
        hop = jnp.where(done | arrived, -1, 1)
        u = jnp.where(hop < 0, -1, cur)
        v = jnp.where(hop < 0, -1, nxt)
        new_cur = jnp.where(done | arrived, cur, nxt)
        return (new_cur, done | arrived), jnp.stack([u, v])

    (_, _), hops = jax.lax.scan(
        body, (src.astype(jnp.int32), jnp.asarray(False)), None, length=max_hops)
    return hops
