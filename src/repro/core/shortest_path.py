"""Shortest-path machinery on the physical network, per DNN layer.

For layer l, every intra-layer edge (u, v) of the layered graph costs

    w_l(u, v) = (d_l + Q_uv) / mu_uv        (service + waiting, paper §III-B)

``transfer_closure`` returns the [L+1, V, V] tensor T where T[l, u, v] is the
cheapest way to move layer-l output from u to v (possibly multi-hop).  It is
the min-plus closure of w_l, the kernel hot-spot (see kernels/minplus.py).

:class:`Closures` bundles (w, T) for one (net, data) so the stack is built
**once** per queue state and shared by everything that needs it — routing,
commit, cost evaluation, path extraction.  ``build_closures`` /
``build_closures_batch`` are the counted host-level builders (the greedy
driver calls them once per round; ``closure_build_count`` powers the
regression test asserting exactly that); ``closures_for`` is the uncounted
pure builder safe to call under jit/scan tracing.

``reconstruct_path`` recovers an explicit hop list from the closure: from u
toward v, the next hop is argmin_w  w_l(u, w) + T[l, w, v].  Walking this
greedy next-hop V-1 times yields a shortest path; it is used to commit link
loads in the greedy algorithm and to hand explicit paths to the event
simulator.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from .network import INF, ComputeNetwork, link_invrate, link_wait


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Closures:
    """Per-layer edge weights and their min-plus closures for one queue state.

    ``w``/``t`` are [Lmax+1, V, V] for a single data-size vector, or carry a
    leading [J] axis when built for a batch (``build_closures_batch``); the
    batched stack vmaps straight through ``route_single``.

    ``w`` may be ``None``: it is elementwise-cheap to recompute from
    (net, data), so batch-stacked artifacts omit it rather than materialize
    a J-fold gather that only ever serves one job's commit — consumers that
    need ``w`` (commit, path extraction) rebuild it from the job's data when
    absent.  ``t`` — the expensive part — is always present.
    """

    w: jax.Array | None  # layer edge weights w_l(u, v), or None (recompute)
    t: jax.Array         # min-plus closure T_l = closure(w_l)

    def job(self, j) -> "Closures":
        """Slice one job's closures out of a batch-stacked artifact."""
        return Closures(w=None if self.w is None else self.w[j], t=self.t[j])


_n_builds = 0


def closure_build_count() -> int:
    """Host-level closure builds since the last reset (one per
    ``build_closures``/``build_closures_batch`` call; in-jit fallback builds
    are not counted)."""
    return _n_builds


def reset_closure_build_count() -> None:
    global _n_builds
    _n_builds = 0


def layer_edge_weights(net: ComputeNetwork, data_sizes: jax.Array) -> jax.Array:
    """[..., L+1, V, V] per-layer intra-layer edge weights.

    data_sizes: [..., L+1] bytes (d_0 .. d_L; leading batch dims allowed).
    Absent edges get INF; the diagonal is 0 (staying put is free).
    """
    inv = link_invrate(net)  # [V, V], INF off-graph, 0 diag
    # Computed in the paper's literal form (d_l + Q_uv) / mu_uv rather than
    # d_l/mu + Q/mu: the multiply is the LAST rounding, so there is no
    # mul-feeding-add for LLVM to contract into an FMA.  The split form is
    # contraction-unstable — whether XLA/LLVM fuses ``d*inv + wait`` into
    # an FMA depends on the surrounding program, so the fused round scan,
    # the standalone closure build, and eager execution each rounded the
    # last ulp differently once queues were nonzero, breaking bitwise
    # solver parity (lax.optimization_barrier does not stop the
    # contraction on CPU).  At Q == 0 this form reproduces ``d * inv``
    # bit-for-bit, so pre-change golden traces are unaffected.  Lint rule
    # RL001 (contraction-hazard) enforces this multiply-last form across
    # every numerics module — `python -m repro.lint --list-rules`.
    w = (data_sizes[..., :, None, None] + net.q_link) * inv
    return jnp.minimum(w, INF)


def closures_for(net: ComputeNetwork, data_sizes: jax.Array,
                 *, use_pallas: bool | None = None) -> Closures:
    """Uncounted :class:`Closures` builder (safe under jit/scan tracing)."""
    w = layer_edge_weights(net, data_sizes)
    return Closures(w=w, t=ops.minplus_closure(w, use_pallas=use_pallas))


def build_closures(net: ComputeNetwork, data_sizes: jax.Array,
                   *, use_pallas: bool | None = None) -> Closures:
    """Counted host-level :class:`Closures` build for one data-size vector."""
    global _n_builds
    _n_builds += 1
    return closures_for(net, data_sizes, use_pallas=use_pallas)


def dedupe_data(batch) -> tuple[jax.Array, jax.Array]:
    """(unique [U, Lmax+1] data rows, [J] inverse index) for a job batch.

    Host-level (needs concrete ``batch.data``); constant across greedy
    rounds, so drivers hoist it out of the round loop.
    """
    data = np.asarray(jax.device_get(batch.data))
    uniq, inv = np.unique(data, axis=0, return_inverse=True)
    # explicit staging: keeps solver drivers transfer_guard("disallow")-clean
    return (jax.device_put(uniq),
            jax.device_put(inv.reshape(-1).astype(np.int32)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DedupePlan:
    """Host-precomputed dedupe structure for one job batch.

    Row level: ``uniq [U, Lmax+1]`` unique data rows with ``inv [J]``
    mapping jobs back (exactly :func:`dedupe_data`).  Scalar level:
    ``w_l(u, v) = d_l * inv_rate(u, v) + wait(u, v)`` depends on the data
    size *scalar* d_l only, so two (row, layer) slots sharing a d value
    have bitwise-identical weight matrices — and hence bitwise-identical
    closures — under **every** queue state.  ``d_vals [D]`` are the unique
    scalars and ``d_idx [U, Lmax+1]`` gathers them back; the fused solver
    closes [D, V, V] matrices per round instead of [U, Lmax+1, V, V]
    (model-serving batches share layer widths, so D is typically an order
    of magnitude below U * (Lmax+1)).  Queue-state independent, so solvers
    hoist one plan out of the round loop.
    """

    uniq: jax.Array    # [U, Lmax+1] unique data rows
    inv: jax.Array     # [J] int32: job -> row in uniq
    d_vals: jax.Array  # [D] unique data-size scalars
    d_idx: jax.Array   # [U, Lmax+1] int32: (row, layer) -> slot in d_vals


def dedupe_plan(batch) -> DedupePlan:
    """Build the two-level :class:`DedupePlan` for a job batch (host-level)."""
    uniq, inv = dedupe_data(batch)
    uniq_h = np.asarray(uniq)
    d_vals, d_idx = np.unique(uniq_h, return_inverse=True)
    return DedupePlan(
        uniq=uniq, inv=inv, d_vals=jax.device_put(d_vals),
        d_idx=jax.device_put(d_idx.reshape(uniq_h.shape).astype(np.int32)))


def closures_for_dedup(net: ComputeNetwork, plan: DedupePlan,
                       *, use_pallas: bool | None = None) -> Closures:
    """Uncounted batch-stacked closure build through a :class:`DedupePlan`.

    jit/scan-safe (the fused solver's round body calls it with traced
    queues).  Closes the [D, V, V] unique-scalar stack and gathers back to
    [J, Lmax+1, V, V]; the closure of each weight matrix is computed
    independently, so the gathered stack is bitwise identical to
    ``build_closures_batch``'s.  ``w`` is dropped as usual (cheap to
    recompute per job).
    """
    t_d = ops.minplus_closure(layer_edge_weights(net, plan.d_vals),
                              use_pallas=use_pallas)      # [D, V, V]
    t_u = t_d[plan.d_idx]                                 # [U, Lmax+1, V, V]
    return Closures(w=None, t=t_u[plan.inv])              # [J, ...]


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _closures_gathered(net: ComputeNetwork, uniq: jax.Array, inv: jax.Array,
                       *, use_pallas: bool | None = None) -> Closures:
    """One fused program: close the unique stack, gather back to [J, ...].

    Only ``t`` is gathered; ``w`` is dropped (cheap to recompute per job,
    and gathering it J-fold would double the artifact's footprint).
    """
    cl = closures_for(net, uniq, use_pallas=use_pallas)
    return Closures(w=None, t=cl.t[inv])


def build_closures_batch(net: ComputeNetwork, batch,
                         *, use_pallas: bool | None = None,
                         dedupe: tuple[jax.Array, jax.Array] | None = None,
                         ) -> Closures:
    """[J, Lmax+1, V, V] stacked :class:`Closures` for a job batch.

    Jobs sharing a data-size vector dedupe to a single closure computation:
    the [U, Lmax+1, V, V] unique stack is closed in one batched kernel call
    and gathered back to [J, ...].  ``dedupe`` takes a precomputed
    :func:`dedupe_data` result (it is queue-state independent, so round
    loops hoist it).  Counted as one build.
    """
    global _n_builds
    _n_builds += 1
    uniq, inv = dedupe_data(batch) if dedupe is None else dedupe
    return _closures_gathered(net, uniq, inv, use_pallas=use_pallas)


def transfer_closure(net: ComputeNetwork, data_sizes: jax.Array,
                     *, use_pallas: bool | None = None) -> jax.Array:
    """[L+1, V, V] min-cost transfer tensor T_l = closure(w_l)."""
    return closures_for(net, data_sizes, use_pallas=use_pallas).t


def reconstruct_path(w: jax.Array, t: jax.Array, src: jax.Array, dst: jax.Array,
                     max_hops: int) -> jax.Array:
    """Explicit path from src to dst under edge weights w and closure t.

    Returns hops [max_hops, 2] int32 (u, v) pairs, padded with (-1, -1) once
    dst is reached. jit/vmap friendly (fixed max_hops).

    A fixed-length ``scan`` with ``unroll=4``: the fused solver walks every
    layer of every round on device (plus a [P, Lmax+1] batched post-pass for
    ``plan.paths``), so per-step loop overhead — not the few-hop arithmetic —
    is the cost, and unrolling beats both the plain scan and a
    ``while_loop`` early exit (whose batched ``cond`` pays its own
    per-iteration carry).  Unrolling is contraction-safe here: the body is
    gathers, adds, and an argmin — no multiply feeding an add, so there is
    no FMA for LLVM to contract differently across unroll factors.
    Post-arrival steps emit exactly the (-1, -1) padding, so the output is
    bit-identical regardless of loop form.  Lint rule RL002
    (unsafe-unroll) admits ``unroll > 1`` only for contraction-free
    bodies like this one.
    """

    def step(state, _):
        cur, done = state
        # next hop minimizing edge + remaining distance; exclude the zero-cost
        # self-loop (diagonal) so ties never stall the walk
        cand = (w[cur] + t[:, dst]).at[cur].set(INF)
        nxt = jnp.argmin(cand).astype(jnp.int32)
        arrived = cur == dst
        dead = done | arrived
        hop = jnp.stack([jnp.where(dead, -1, cur), jnp.where(dead, -1, nxt)])
        return (jnp.where(dead, cur, nxt), dead), hop

    (_, _), hops = jax.lax.scan(
        step, (src.astype(jnp.int32), jnp.asarray(False)),
        None, length=max_hops, unroll=4)
    return hops
