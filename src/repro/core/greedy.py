"""Algorithm 1: greedy multi-job routing — fused single-dispatch solver.

The default :func:`greedy_route` folds the whole solve into **one jitted
``lax.scan`` over priority rounds**: per round the closure stack is rebuilt
for the current queues (through the two-level dedupe of
``shortest_path.dedupe_plan`` — unique data rows, then unique data-size
scalars), every job is routed against it (a vmapped batch of single-job
DPs), the earliest-finishing unrouted job takes the next priority slot, and
its load is committed to the queues — all on device, exactly one dispatch
per solve and one host sync for the results.  ``extract_paths=True`` adds
one batched post-pass (``_paths_post``) that replays the reference path
extraction against the scan's own per-round queue snapshots and closure
stacks — see the note there on the FMA-proof edge-weight form that keeps
it bit-identical to ``greedy_route_ref``.

:func:`greedy_route_ref` keeps the previous host-driven round loop (one
closure build + one jitted round per priority level, with per-round
``int(j)``/``float(cost)`` syncs) — the parity reference the property tests
and CI gate the fused solver against, bit-identical in assign/order/bounds
and committed queues.  ``lazy=True`` / ``share_closures=False`` delegate to
it (the lazy probe loop is inherently data-dependent and the no-reuse mode
exists only to benchmark the closure-reuse win).

:func:`greedy_route_windows` is the cross-arrival entry: W queued arrival
windows solved in one padded multi-window dispatch (an outer scan threads
the committed queues from each window into the next), bit-identical to W
sequential fused solves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .network import INF, ComputeNetwork, link_invrate
from .jobs import JobBatch
from .plan import Plan
from . import routing
from . import shortest_path as SP

# Deprecated alias (one release): greedy now returns the canonical Plan.
GreedySolution = Plan


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _round(net: ComputeNetwork, batch: JobBatch, routed: jax.Array,
           closures: SP.Closures | None = None,
           *, use_pallas: bool | None = None):
    r = routing.route_batch(net, batch, closures=closures,
                            use_pallas=use_pallas)
    # Mask routed jobs with true inf, not the finite INF sentinel: an
    # unroutable job's cost clips to exactly INF and would tie with (and at
    # a lower index, win over) the mask, double-committing a routed job.
    costs = jnp.where(routed, jnp.inf, r.cost)
    j = jnp.argmin(costs).astype(jnp.int32)
    cl_j = None if closures is None else closures.job(j)
    net2 = routing.commit_assignment(
        net, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
        batch.num_layers[j], r.assign[j], closures=cl_j)
    return j, r.cost[j], r.assign[j], net2


def _job_paths(pre_net: ComputeNetwork, batch: JobBatch, j: int, assign_row,
               closures):
    """Explicit transfer hops for job ``j`` against the pre-commit state.

    Reuses the round's already-built closure stack, so a solve that wants
    paths pays one extraction pass per round — not the full
    ``replay_solution`` (closure rebuild + bound re-eval + re-commit) the
    serving scheduler otherwise runs per arrival to fill ``plan.paths``.
    The hops are chosen against the queue state seen at the job's priority
    level, exactly the Alg. 1 / Alg. 2 semantics ``replay_solution``
    implements — the parity test asserts equality.
    """
    cl = None if closures is None else closures.job(j)
    return routing.extract_paths(
        pre_net, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
        batch.num_layers[j], assign_row, closures=cl)


# ---------------------------------------------------------------------------
# Fused single-dispatch solver
# ---------------------------------------------------------------------------

# Host-level dispatch telemetry for the fused path: one increment per
# ``_fused_solve``/``_fused_solve_many`` *execution* (unlike trace-time
# counters — see kernels/ops.dispatch_counts — these count real dispatches,
# so the one-dispatch-per-solve property is directly assertable).  Two
# lint rules guard this contract statically: RL003 (host-sync-in-device)
# keeps syncs out of the scanned round loop, and RL006
# (dispatch-accounting) makes every solver thread these numbers into
# plan.meta; tests/test_fused.py adds the runtime transfer_guard check.
_n_fused_dispatches = 0


def fused_dispatch_count() -> int:
    """Fused-solver dispatches since the last reset (one per solve)."""
    return _n_fused_dispatches


def reset_fused_dispatch_count() -> None:
    global _n_fused_dispatches
    _n_fused_dispatches = 0


def _bump_dispatch(fn) -> bool:
    """Count one dispatch; report whether it will trigger a compile.

    jax caches compiled executables per abstract signature; a growing
    cache size after the call means this signature was new.  The *pre*
    -call size is recorded here and compared by :func:`_took_compile`.
    """
    global _n_fused_dispatches
    _n_fused_dispatches += 1
    try:
        return fn._cache_size()
    except AttributeError:      # jit cache introspection unavailable
        return -1


def _took_compile(fn, size_before: int) -> bool:
    if size_before < 0:
        return False
    try:
        return fn._cache_size() > size_before
    except AttributeError:
        return False


def _fused_rounds(net0: ComputeNetwork, batch: JobBatch,
                  dplan: SP.DedupePlan, routed0: jax.Array,
                  *, use_pallas: bool | None = None):
    """The on-device Alg. 1 round loop (scan body shared by both solvers).

    Jobs flagged in ``routed0`` are treated as already placed (the
    multi-window solver marks padding jobs this way).  Rounds after every
    real job is routed are no-ops: the commit is computed but the queue
    carry keeps its old values (a select between equal floats is exact,
    so live rounds are bit-identical to the unguarded loop) and the
    emitted job index is -1.

    Besides (job, cost, assign) each round also emits its pre-commit link
    queues and the chosen job's closure stack — the inputs
    :func:`_paths_post` needs to replay the reference path extraction
    without re-running the solve.
    """
    J = batch.num_jobs

    def body(carry, _):
        q_node, q_link, routed = carry
        cur = net0.with_queues(q_node, q_link)
        cl = SP.closures_for_dedup(cur, dplan, use_pallas=use_pallas)
        # Forward DP only: the sequential backpointer walk is the one
        # non-vectorizable piece of the routing, and the round commits a
        # single job — so walk exactly one table, not all J (the walk is
        # pure integer gathers, bit-identical to route_batch's row).
        cost, total, bps = routing.route_batch_fwd(cur, batch, closures=cl)
        # True inf mask (not the finite INF sentinel): see _round above.
        costs = jnp.where(routed, jnp.inf, cost)
        j = jnp.argmin(costs).astype(jnp.int32)
        assign_j = routing.assign_from_backpointers(total[j], bps[j])
        any_left = jnp.any(~routed)
        net2 = routing.commit_assignment(
            cur, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
            batch.num_layers[j], assign_j, closures=cl.job(j))
        qn2 = jnp.where(any_left, net2.q_node, q_node)
        ql2 = jnp.where(any_left, net2.q_link, q_link)
        out_j = jnp.where(any_left, j, jnp.int32(-1))
        return ((qn2, ql2, routed.at[j].set(True)),
                (out_j, cost[j], assign_j, q_link, cl.t[j]))

    (q_node, q_link, _), ys = jax.lax.scan(
        body, (net0.q_node, net0.q_link, routed0), None, length=J)
    return ys, q_node, q_link


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _fused_solve(net: ComputeNetwork, batch: JobBatch, dplan: SP.DedupePlan,
                 routed0: jax.Array, *, use_pallas: bool | None = None):
    return _fused_rounds(net, batch, dplan, routed0, use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _fused_solve_many(net: ComputeNetwork, batches: JobBatch,
                      dplans: SP.DedupePlan, valid: jax.Array,
                      *, use_pallas: bool | None = None):
    """W windows in one program: an outer scan carries the queues across
    windows (window w+1 solves against window w's committed state)."""

    def solve_window(carry, xs):
        q_node, q_link = carry
        batch_w, dplan_w, valid_w = xs
        cur = net.with_queues(q_node, q_link)
        ys, qn2, ql2 = _fused_rounds(cur, batch_w, dplan_w, ~valid_w,
                                     use_pallas=use_pallas)
        return (qn2, ql2), (ys, qn2, ql2)

    _, outs = jax.lax.scan(solve_window, (net.q_node, net.q_link),
                           (batches, dplans, valid))
    return outs


def _fused_meta(J: int, *, rounds: int, windows: int = 1,
                compiled: bool = False, paths: bool = False) -> dict:
    # n_routings/rounds_per_dispatch report the *padded* scan work (what
    # the device actually ran), "jobs" the real window size.
    return {"n_routings": rounds * rounds, "jobs": J, "fused": True,
            "dispatches": 1, "rounds_per_dispatch": windows * rounds,
            "windows_per_dispatch": windows, "path_dispatches": int(paths),
            "jit_compiled": bool(compiled)}


@functools.partial(jax.jit, static_argnames=("max_hops",))
def _walk_paths(data: jax.Array, ql_pre: jax.Array, inv: jax.Array,
                t: jax.Array, starts: jax.Array, ends: jax.Array,
                *, max_hops: int) -> jax.Array:
    """[P, Lmax+1] batched path walks -> hops [P, Lmax+1, max_hops, 2].

    Rebuilds the per-round edge weights in the same program as the walks
    (one dispatch instead of a chain of eager ops feeding a jit call).
    The ``(d + Q) * inv`` expression matches
    ``shortest_path.layer_edge_weights`` exactly — its last rounding is
    the multiply, so it is contraction-proof in any program context and
    the weights stay bit-identical to the reference extraction's.
    """
    w = jnp.minimum((data[:, :, None, None] + ql_pre[:, None]) * inv, INF)
    fn = functools.partial(routing.reconstruct_path, max_hops=max_hops)
    return jax.vmap(jax.vmap(fn))(w, t, starts, ends)


def _paths_post(net0: ComputeNetwork, batch: JobBatch, order, assigns,
                ql_pre, t_sel, num_layers_h) -> dict[int, list]:
    """One batched post-pass: ``plan.paths`` for every round of a solve.

    Replays exactly what :func:`greedy_route_ref` does per round
    (``routing.extract_paths`` against the pre-commit queues) from the
    scan's emitted snapshots: per-round link queues ``ql_pre [P, V, V]``
    and the committed job's closure stack ``t_sel [P, Lmax+1, V, V]``.

    The edge weights are rebuilt inside :func:`_walk_paths` (one jit
    dispatch for weights + walks) with ``layer_edge_weights``'s exact
    ``(d + Q) * inv`` expression against each round's pre-commit queues —
    that form's last rounding is the multiply, so LLVM cannot contract it
    into an FMA and the rebuild is bit-identical to the reference
    extraction's weights under every program context.  ``t`` (no
    contractible pattern) is taken from the scan and matches the
    reference's jit-built closures bit-for-bit.
    """
    v = net0.num_nodes
    order = np.asarray(order)
    if order.size == 0:
        return {}
    assigns = np.asarray(assigns)
    lmax = batch.max_layers
    src_h, dst_h, data_h = (np.asarray(jax.device_get(x))
                            for x in (batch.src, batch.dst, batch.data))
    L_sel = np.asarray(num_layers_h)[order]
    src_sel, dst_sel = src_h[order], dst_h[order]
    # Per-layer walk endpoints: node_l -> node_{l+1} with node_0 = src and
    # dst from layer num_layers on (layers past num_layers are dropped by
    # the formatter, their walks are dead weight in the batched call).
    starts = np.concatenate([src_sel[:, None], assigns], 1).astype(np.int32)
    ends = np.concatenate([assigns, dst_sel[:, None]], 1)
    ends = np.where(np.arange(lmax + 1)[None, :] >= L_sel[:, None],
                    dst_sel[:, None], ends).astype(np.int32)

    # device_put (not jnp.asarray): staging is an *explicit* transfer so
    # the solver path stays clean under jax.transfer_guard("disallow")
    # (the runtime complement of lint rule RL003; see tests/test_fused.py).
    hops = jax.device_get(_walk_paths(
        jax.device_put(data_h[order]), jax.device_put(ql_pre),
        link_invrate(net0), jax.device_put(t_sel), jax.device_put(starts),
        jax.device_put(ends), max_hops=v))
    return {int(j): routing.hops_to_paths(hops[p], int(L_sel[p]))
            for p, j in enumerate(order)}


def _assemble_plan(batch: JobBatch, net: ComputeNetwork, order, costs,
                   assigns, paths, meta: dict) -> Plan:
    """Host-side Plan assembly from one window's stacked round outputs."""
    J, lmax = batch.num_jobs, batch.max_layers
    order = np.asarray(order[:J])
    assign = np.zeros((J, lmax), np.int32)
    bounds = np.zeros((J,), np.float64)
    assign[order] = np.asarray(assigns[:J])
    bounds[order] = np.asarray(costs[:J], np.float64)
    return Plan.from_order(assign, order, bounds, solver="greedy",
                           meta=meta, net=net, paths=paths)


def greedy_route(net: ComputeNetwork, batch: JobBatch,
                 *, use_pallas: bool | None = None,
                 lazy: bool = False, share_closures: bool = True,
                 extract_paths: bool = False) -> Plan:
    """Run Algorithm 1 to completion — one device dispatch, one host sync.

    Semantics (and bit-exact results) match :func:`greedy_route_ref`;
    ``lazy=True`` and ``share_closures=False`` delegate to it (the lazy
    probe loop is host-driven by design, and no-reuse mode exists only to
    benchmark the closure-reuse win).  ``extract_paths=True`` fills
    ``plan.paths`` in one batched post-pass over the scan's emitted
    snapshots (see :func:`_paths_post`).  ``plan.meta`` reports the
    fused-dispatch accounting (``fused``/``dispatches``/
    ``rounds_per_dispatch``/``path_dispatches``) plus ``jit_compiled`` —
    True when this call traced+compiled a new shape signature, the wall
    the serving warm-up exists to keep out of latency models.
    """
    if lazy or not share_closures:
        return greedy_route_ref(net, batch, use_pallas=use_pallas,
                                lazy=lazy, share_closures=share_closures,
                                extract_paths=extract_paths)
    J = batch.num_jobs
    j_pad = _next_pow2(J)
    padded = _pad_batch(batch, j_pad)
    dplan = _bucket_dplan(SP.dedupe_plan(padded))
    routed0 = jax.device_put(np.arange(j_pad) >= J)  # dummies pre-routed
    size0 = _bump_dispatch(_fused_solve)
    out = _fused_solve(net, padded, dplan, routed0, use_pallas=use_pallas)
    compiled = _took_compile(_fused_solve, size0)
    (order, costs, assigns, ql_pre, t_sel), q_node, q_link = out
    order, costs, assigns, num_layers_h = jax.device_get(
        (order, costs, assigns, batch.num_layers))
    # drop padding rounds; every round is real in the common unpadded
    # serving case, where the mask gathers would be pure eager overhead
    keep = slice(None) if (order >= 0).all() else order >= 0
    paths = None
    if extract_paths:
        # host copies before mask-slicing: indexing a device array with a
        # numpy mask is an implicit h2d of the indices (trips the
        # transfer_guard("disallow") the parity tests run under)
        ql_h, t_h = jax.device_get((ql_pre, t_sel))
        paths = _paths_post(net, batch, order[keep], assigns[keep],
                            ql_h[keep], t_h[keep], num_layers_h)
    return _assemble_plan(
        batch, net.with_queues(q_node, q_link), order[keep], costs[keep],
        assigns[keep], paths,
        meta=_fused_meta(J, rounds=j_pad, compiled=compiled,
                         paths=extract_paths))


def _next_pow2(n: int) -> int:
    """Smallest power of two >= n (shape-bucketing for jit signatures).

    Serving windows arrive at every size in [1, max_batch]; without
    bucketing each distinct (J, U, D) triple would compile its own fused
    program (seconds each).  Rounding all three up to powers of two caps
    the signature count at a handful per deployment — and padding is
    bit-exact: dummy jobs are pre-routed and duplicated dedupe rows gather
    onto the same values (the parity suite runs padded next to unpadded).
    """
    return 1 << max(0, (n - 1).bit_length())


def _pad_batch(batch: JobBatch, j_to: int) -> JobBatch:
    """Pad a window's batch to ``j_to`` jobs with inert dummies (zero
    compute/data, src=dst=0) — they are pre-routed in the fused scan, so
    they never route, commit, or perturb real jobs' values."""
    J = batch.num_jobs
    if J == j_to:
        return batch
    pad = j_to - J

    def pad0(x):
        width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jax.device_put(np.pad(np.asarray(x), width))

    return JobBatch(src=pad0(batch.src), dst=pad0(batch.dst),
                    comp=pad0(batch.comp), data=pad0(batch.data),
                    num_layers=pad0(batch.num_layers) + jax.device_put(
                        np.array([0] * J + [1] * pad, np.int32)))


def _pad_dplan(dplan: SP.DedupePlan, u_to: int, d_to: int) -> SP.DedupePlan:
    """Pad a dedupe plan to common unique-row/-scalar counts.  Padding rows
    duplicate existing entries, so the closure work grows but every real
    gather lands on the same values — bit-identical results."""
    uniq, inv = np.asarray(dplan.uniq), np.asarray(dplan.inv)
    d_vals, d_idx = np.asarray(dplan.d_vals), np.asarray(dplan.d_idx)
    u_pad, d_pad = u_to - uniq.shape[0], d_to - d_vals.shape[0]
    if u_pad:
        uniq = np.concatenate([uniq, np.repeat(uniq[:1], u_pad, axis=0)])
        d_idx = np.concatenate([d_idx, np.repeat(d_idx[:1], u_pad, axis=0)])
    if d_pad:
        d_vals = np.concatenate([d_vals, np.repeat(d_vals[:1], d_pad)])
    return SP.DedupePlan(uniq=jax.device_put(uniq),
                         inv=jax.device_put(inv),
                         d_vals=jax.device_put(d_vals),
                         d_idx=jax.device_put(d_idx.astype(np.int32)))


def _bucket_dplan(dplan: SP.DedupePlan) -> SP.DedupePlan:
    """Round the dedupe plan's unique-row/-scalar counts up to powers of
    two (see :func:`_next_pow2`) so batches with slightly different model
    mixes share one compiled program."""
    u, d = np.asarray(dplan.uniq).shape[0], np.asarray(dplan.d_vals).shape[0]
    return _pad_dplan(dplan, _next_pow2(u), _next_pow2(d))


def greedy_route_windows(net: ComputeNetwork, batches: list[JobBatch],
                         *, use_pallas: bool | None = None,
                         extract_paths: bool = False) -> list[Plan]:
    """Cross-arrival batching: W windows, one dispatch, W chained plans.

    Window w+1 is solved against window w's committed queues — exactly the
    state W sequential :func:`greedy_route` calls would thread through —
    and each returned plan is bit-identical to its sequential counterpart
    (ragged window sizes are padded with inert jobs; each plan's ``net``
    carries that window's post-commit queues).  All windows must share the
    layer width (``batch_jobs(pad_to=)``).
    """
    if not batches:
        return []
    if len(batches) == 1:
        return [greedy_route(net, batches[0], use_pallas=use_pallas,
                             extract_paths=extract_paths)]
    lmax = {b.max_layers for b in batches}
    if len(lmax) != 1:
        raise ValueError(
            f"windows must share a padded layer width (batch_jobs(pad_to=)); "
            f"got {sorted(lmax)}")
    j_max = _next_pow2(max(b.num_jobs for b in batches))
    padded = [_pad_batch(b, j_max) for b in batches]
    dplans = [SP.dedupe_plan(b) for b in padded]
    u_max = _next_pow2(max(np.asarray(d.uniq).shape[0] for d in dplans))
    d_max = _next_pow2(max(np.asarray(d.d_vals).shape[0] for d in dplans))
    dplans = [_pad_dplan(d, u_max, d_max) for d in dplans]
    stack = lambda xs: jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves), *xs)
    valid = jax.device_put(np.array(
        [[1] * b.num_jobs + [0] * (j_max - b.num_jobs) for b in batches],
        bool))
    size0 = _bump_dispatch(_fused_solve_many)
    outs = _fused_solve_many(net, stack(padded), stack(dplans), valid,
                             use_pallas=use_pallas)
    compiled = _took_compile(_fused_solve_many, size0)
    (orders, costs, assigns, ql_pre, t_sel), q_nodes, q_links = outs
    orders, costs, assigns = jax.device_get((orders, costs, assigns))
    # host copies: per-window numpy indexing is free (d2h is zero-copy on
    # CPU), while indexing the device arrays with python ints / numpy
    # masks would implicitly stage the indices — tripping the
    # transfer_guard("disallow") the parity tests run under
    q_nodes, q_links = jax.device_get((q_nodes, q_links))
    if extract_paths:
        ql_pre, t_sel = jax.device_get((ql_pre, t_sel))
    plans = []
    for w, batch in enumerate(batches):
        J = batch.num_jobs
        keep = orders[w] >= 0
        order_w = orders[w][keep]
        paths = None
        if extract_paths:
            paths = _paths_post(
                net, padded[w], order_w, assigns[w][keep], ql_pre[w][keep],
                t_sel[w][keep],
                np.asarray(jax.device_get(padded[w].num_layers)))
        plans.append(_assemble_plan(
            batch, net.with_queues(jax.device_put(q_nodes[w]),
                                   jax.device_put(q_links[w])), order_w,
            costs[w][keep], assigns[w][keep], paths,
            meta=_fused_meta(J, rounds=j_max, windows=len(batches),
                             compiled=compiled, paths=extract_paths)))
    return plans


# ---------------------------------------------------------------------------
# Reference host-driven loop (parity gate) + lazy greedy
# ---------------------------------------------------------------------------

def greedy_route_ref(net: ComputeNetwork, batch: JobBatch,
                     *, use_pallas: bool | None = None,
                     lazy: bool = False, share_closures: bool = True,
                     extract_paths: bool = False) -> Plan:
    """Host-driven Algorithm 1 round loop (the fused solver's parity
    reference).

    Each round builds the batched closure stack once
    (``build_closures_batch``), routes every job in one jitted ``_round``,
    and syncs the selected job back to the host — ~4 dispatches and two
    scalar transfers per round.  ``share_closures=True`` (default) shares
    that stack between routing and commit; ``False`` reproduces the seed
    behavior (every call rebuilds its own closures) — kept for
    benchmarking the reuse win, not for production use.

    ``extract_paths=True`` additionally fills ``plan.paths`` (explicit
    per-layer transfer hops) during the solve, one extraction per round
    against the round's closures.

    ``lazy=True`` is the beyond-paper *lazy greedy* (EXPERIMENTS.md §Perf):
    queues only grow, so every job's completion bound is monotone
    non-decreasing across rounds — a stale cached bound is a valid lower
    bound.  Each round re-routes only the cached argmin until it proves
    itself fresh-minimal, committing after O(1) expected re-routes instead
    of re-routing all J jobs.  Produces a solution with the same guarantee
    (it IS Algorithm 1 up to tie-breaking).
    """
    if lazy:
        return _greedy_lazy(net, batch, use_pallas=use_pallas,
                            share_closures=share_closures,
                            extract_paths=extract_paths)
    J, lmax = batch.num_jobs, batch.max_layers
    routed = jnp.zeros((J,), bool)
    order = np.zeros((J,), np.int32)
    assign = np.zeros((J, lmax), np.int32)
    bounds = np.zeros((J,), np.float64)
    paths: dict[int, list] | None = {} if extract_paths else None
    cur = net
    dedupe = SP.dedupe_data(batch) if share_closures else None
    for p in range(J):
        closures = (SP.build_closures_batch(cur, batch, dedupe=dedupe,
                                            use_pallas=use_pallas)
                    if share_closures else None)
        j, cost, a, nxt = _round(cur, batch, routed, closures,
                                 use_pallas=use_pallas)
        j = int(j)
        order[p] = j
        bounds[j] = float(cost)
        assign[j] = np.asarray(a)
        if paths is not None:
            paths[j] = _job_paths(cur, batch, j, assign[j], closures)
        cur = nxt
        routed = routed.at[j].set(True)
    return Plan.from_order(assign, order, bounds, solver="greedy",
                           meta={"n_routings": J * J}, net=cur, paths=paths)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _route_one(net, batch, j, closures=None, *, use_pallas=None):
    cl = None if closures is None else closures.job(j)
    r = routing.route_single(net, batch.comp[j], batch.data[j], batch.src[j],
                             batch.dst[j], batch.num_layers[j], closures=cl,
                             use_pallas=use_pallas)
    return r.cost, r.assign


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _commit_one(net, batch, j, assign, closures=None, *, use_pallas=None):
    cl = None if closures is None else closures.job(j)
    return routing.commit_assignment(
        net, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
        batch.num_layers[j], jnp.asarray(assign), closures=cl)


def _greedy_lazy(net: ComputeNetwork, batch: JobBatch,
                 *, use_pallas: bool | None = None,
                 share_closures: bool = True,
                 extract_paths: bool = False) -> Plan:
    J, lmax = batch.num_jobs, batch.max_layers
    dedupe = SP.dedupe_data(batch) if share_closures else None

    def fresh_closures(n):
        return (SP.build_closures_batch(n, batch, dedupe=dedupe,
                                        use_pallas=use_pallas)
                if share_closures else None)

    closures = fresh_closures(net)
    paths: dict[int, list] | None = {} if extract_paths else None
    r0 = routing.route_batch(net, batch, closures=closures,
                             use_pallas=use_pallas)
    # Cached lower bounds stay on device; selection is a device argmin over
    # the masked vector (one scalar transfer per probe, no J-wide ping-pong).
    cost = jnp.asarray(r0.cost)                      # [J] cached lower bounds
    assign_c = np.array(r0.assign)                   # (writable host copy)
    fresh = np.ones((J,), bool)
    active = jnp.ones((J,), bool)

    order = np.zeros((J,), np.int32)
    assign = np.zeros((J, lmax), np.int32)
    bounds = np.zeros((J,), np.float64)
    cur = net
    n_routings = J
    for p in range(J):
        while True:
            # inf (not the finite INF sentinel) so routed jobs can never tie
            # with an unroutable active job's clipped-to-INF bound
            j = int(jnp.argmin(jnp.where(active, cost, jnp.inf)))
            if fresh[j]:
                break
            c, a = _route_one(cur, batch, j, closures, use_pallas=use_pallas)
            cost = cost.at[j].set(c)
            assign_c[j] = np.asarray(a)
            fresh[j] = True
            n_routings += 1
        order[p] = j
        bounds[j] = float(cost[j])
        assign[j] = assign_c[j]
        if paths is not None:
            paths[j] = _job_paths(cur, batch, j, assign_c[j], closures)
        active = active.at[j].set(False)
        cur = _commit_one(cur, batch, j, assign_c[j], closures,
                          use_pallas=use_pallas)
        if p + 1 < J:
            closures = fresh_closures(cur)
            fresh[:] = False
            fresh[j] = True  # routed jobs are never probed again
    return Plan.from_order(assign, order, bounds, solver="lazy",
                           meta={"n_routings": n_routings}, net=cur,
                           paths=paths)
