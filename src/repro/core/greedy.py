"""Algorithm 1: greedy multi-job routing.

Each round routes *every* unrouted job optimally against the current queue
state (a vmapped batch of single-job DPs -> one batched stack of min-plus
closures, the kernel hot-spot), gives the earliest-finishing job the next
priority slot, and commits its load to the queues (Alg. 1 line 3).

The round body is jit-compiled once per (J, Lmax, V) shape; the J-round loop
runs in Python so solutions stream out incrementally (and J is small next to
the per-round tensor work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .network import INF, ComputeNetwork
from .jobs import JobBatch
from .plan import Plan
from . import routing

# Deprecated alias (one release): greedy now returns the canonical Plan.
GreedySolution = Plan


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _round(net: ComputeNetwork, batch: JobBatch, routed: jax.Array,
           *, use_pallas: bool | None = None):
    r = routing.route_batch(net, batch, use_pallas=use_pallas)
    costs = jnp.where(routed, INF, r.cost)
    j = jnp.argmin(costs).astype(jnp.int32)
    net2 = routing.commit_assignment(
        net, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
        batch.num_layers[j], r.assign[j])
    return j, r.cost[j], r.assign[j], net2


def greedy_route(net: ComputeNetwork, batch: JobBatch,
                 *, use_pallas: bool | None = None,
                 lazy: bool = False) -> Plan:
    """Run Algorithm 1 to completion.

    ``lazy=True`` is the beyond-paper *lazy greedy* (EXPERIMENTS.md §Perf):
    queues only grow, so every job's completion bound is monotone
    non-decreasing across rounds — a stale cached bound is a valid lower
    bound.  Each round re-routes only the cached argmin until it proves
    itself fresh-minimal, committing after O(1) expected re-routes instead
    of re-routing all J jobs.  Produces a solution with the same guarantee
    (it IS Algorithm 1 up to tie-breaking).
    """
    if lazy:
        return _greedy_lazy(net, batch, use_pallas=use_pallas)
    J, lmax = batch.num_jobs, batch.max_layers
    routed = jnp.zeros((J,), bool)
    order = np.zeros((J,), np.int32)
    assign = np.zeros((J, lmax), np.int32)
    bounds = np.zeros((J,), np.float64)
    cur = net
    for p in range(J):
        j, cost, a, cur = _round(cur, batch, routed, use_pallas=use_pallas)
        j = int(j)
        order[p] = j
        bounds[j] = float(cost)
        assign[j] = np.asarray(a)
        routed = routed.at[j].set(True)
    return Plan.from_order(assign, order, bounds, solver="greedy",
                           meta={"n_routings": J * J}, net=cur)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _route_one(net, batch, j, *, use_pallas=None):
    r = routing.route_single(net, batch.comp[j], batch.data[j], batch.src[j],
                             batch.dst[j], batch.num_layers[j],
                             use_pallas=use_pallas)
    return r.cost, r.assign


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _commit_one(net, batch, j, assign, *, use_pallas=None):
    return routing.commit_assignment(
        net, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
        batch.num_layers[j], jnp.asarray(assign))


def _greedy_lazy(net: ComputeNetwork, batch: JobBatch,
                 *, use_pallas: bool | None = None) -> Plan:
    J, lmax = batch.num_jobs, batch.max_layers
    r0 = routing.route_batch(net, batch, use_pallas=use_pallas)
    cost = np.array(r0.cost, np.float64)             # cached lower bounds
    assign_c = np.array(r0.assign)                   # (writable copies)
    fresh = np.ones((J,), bool)

    order = np.zeros((J,), np.int32)
    assign = np.zeros((J, lmax), np.int32)
    bounds = np.zeros((J,), np.float64)
    remaining = set(range(J))
    cur = net
    n_routings = J
    for p in range(J):
        while True:
            j = min(remaining, key=lambda x: cost[x])
            if fresh[j]:
                break
            c, a = _route_one(cur, batch, j, use_pallas=use_pallas)
            cost[j], assign_c[j] = float(c), np.asarray(a)
            fresh[j] = True
            n_routings += 1
        order[p] = j
        bounds[j] = cost[j]
        assign[j] = assign_c[j]
        remaining.discard(j)
        cur = _commit_one(cur, batch, j, assign_c[j], use_pallas=use_pallas)
        for x in remaining:
            fresh[x] = False
    return Plan.from_order(assign, order, bounds, solver="lazy",
                           meta={"n_routings": n_routings}, net=cur)
