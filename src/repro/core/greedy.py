"""Algorithm 1: greedy multi-job routing.

Each round builds the batched closure stack **once** for the current queue
state (``shortest_path.build_closures_batch`` — jobs sharing a data-size
vector dedupe to one closure; the kernel hot-spot), routes every unrouted
job against it (a vmapped batch of single-job DPs), gives the
earliest-finishing job the next priority slot, and commits its load to the
queues (Alg. 1 line 3) *reusing the same closures* — no recomputation
between routing and commit.

The round body is jit-compiled once per (J, Lmax, V) shape; the J-round loop
runs in Python so solutions stream out incrementally (and J is small next to
the per-round tensor work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .network import INF, ComputeNetwork
from .jobs import JobBatch
from .plan import Plan
from . import routing
from . import shortest_path as SP

# Deprecated alias (one release): greedy now returns the canonical Plan.
GreedySolution = Plan


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _round(net: ComputeNetwork, batch: JobBatch, routed: jax.Array,
           closures: SP.Closures | None = None,
           *, use_pallas: bool | None = None):
    r = routing.route_batch(net, batch, closures=closures,
                            use_pallas=use_pallas)
    # Mask routed jobs with true inf, not the finite INF sentinel: an
    # unroutable job's cost clips to exactly INF and would tie with (and at
    # a lower index, win over) the mask, double-committing a routed job.
    costs = jnp.where(routed, jnp.inf, r.cost)
    j = jnp.argmin(costs).astype(jnp.int32)
    cl_j = None if closures is None else closures.job(j)
    net2 = routing.commit_assignment(
        net, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
        batch.num_layers[j], r.assign[j], closures=cl_j)
    return j, r.cost[j], r.assign[j], net2


def _job_paths(pre_net: ComputeNetwork, batch: JobBatch, j: int, assign_row,
               closures):
    """Explicit transfer hops for job ``j`` against the pre-commit state.

    Reuses the round's already-built closure stack, so a solve that wants
    paths pays one extraction pass per round — not the full
    ``replay_solution`` (closure rebuild + bound re-eval + re-commit) the
    serving scheduler otherwise runs per arrival to fill ``plan.paths``.
    The hops are chosen against the queue state seen at the job's priority
    level, exactly the Alg. 1 / Alg. 2 semantics ``replay_solution``
    implements — the parity test asserts equality.
    """
    cl = None if closures is None else closures.job(j)
    return routing.extract_paths(
        pre_net, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
        batch.num_layers[j], assign_row, closures=cl)


def greedy_route(net: ComputeNetwork, batch: JobBatch,
                 *, use_pallas: bool | None = None,
                 lazy: bool = False, share_closures: bool = True,
                 extract_paths: bool = False) -> Plan:
    """Run Algorithm 1 to completion.

    ``share_closures=True`` (default) builds one batched closure stack per
    round and shares it between routing and commit; ``False`` reproduces the
    seed behavior (every routing/commit call rebuilds its own closures) —
    kept for benchmarking the reuse win, not for production use.

    ``extract_paths=True`` additionally fills ``plan.paths`` (explicit
    per-layer transfer hops) during the solve, one extraction per round
    against the round's closures.  Callers that need paths anyway (the
    exact-drain ledger, the event simulator) skip a full
    ``replay_solution`` this way; bounds are untouched.

    ``lazy=True`` is the beyond-paper *lazy greedy* (EXPERIMENTS.md §Perf):
    queues only grow, so every job's completion bound is monotone
    non-decreasing across rounds — a stale cached bound is a valid lower
    bound.  Each round re-routes only the cached argmin until it proves
    itself fresh-minimal, committing after O(1) expected re-routes instead
    of re-routing all J jobs.  Produces a solution with the same guarantee
    (it IS Algorithm 1 up to tie-breaking).
    """
    if lazy:
        return _greedy_lazy(net, batch, use_pallas=use_pallas,
                            share_closures=share_closures,
                            extract_paths=extract_paths)
    J, lmax = batch.num_jobs, batch.max_layers
    routed = jnp.zeros((J,), bool)
    order = np.zeros((J,), np.int32)
    assign = np.zeros((J, lmax), np.int32)
    bounds = np.zeros((J,), np.float64)
    paths: dict[int, list] | None = {} if extract_paths else None
    cur = net
    dedupe = SP.dedupe_data(batch) if share_closures else None
    for p in range(J):
        closures = (SP.build_closures_batch(cur, batch, dedupe=dedupe,
                                            use_pallas=use_pallas)
                    if share_closures else None)
        j, cost, a, nxt = _round(cur, batch, routed, closures,
                                 use_pallas=use_pallas)
        j = int(j)
        order[p] = j
        bounds[j] = float(cost)
        assign[j] = np.asarray(a)
        if paths is not None:
            paths[j] = _job_paths(cur, batch, j, assign[j], closures)
        cur = nxt
        routed = routed.at[j].set(True)
    return Plan.from_order(assign, order, bounds, solver="greedy",
                           meta={"n_routings": J * J}, net=cur, paths=paths)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _route_one(net, batch, j, closures=None, *, use_pallas=None):
    cl = None if closures is None else closures.job(j)
    r = routing.route_single(net, batch.comp[j], batch.data[j], batch.src[j],
                             batch.dst[j], batch.num_layers[j], closures=cl,
                             use_pallas=use_pallas)
    return r.cost, r.assign


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _commit_one(net, batch, j, assign, closures=None, *, use_pallas=None):
    cl = None if closures is None else closures.job(j)
    return routing.commit_assignment(
        net, batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
        batch.num_layers[j], jnp.asarray(assign), closures=cl)


def _greedy_lazy(net: ComputeNetwork, batch: JobBatch,
                 *, use_pallas: bool | None = None,
                 share_closures: bool = True,
                 extract_paths: bool = False) -> Plan:
    J, lmax = batch.num_jobs, batch.max_layers
    dedupe = SP.dedupe_data(batch) if share_closures else None

    def fresh_closures(n):
        return (SP.build_closures_batch(n, batch, dedupe=dedupe,
                                        use_pallas=use_pallas)
                if share_closures else None)

    closures = fresh_closures(net)
    paths: dict[int, list] | None = {} if extract_paths else None
    r0 = routing.route_batch(net, batch, closures=closures,
                             use_pallas=use_pallas)
    # Cached lower bounds stay on device; selection is a device argmin over
    # the masked vector (one scalar transfer per probe, no J-wide ping-pong).
    cost = jnp.asarray(r0.cost)                      # [J] cached lower bounds
    assign_c = np.array(r0.assign)                   # (writable host copy)
    fresh = np.ones((J,), bool)
    active = jnp.ones((J,), bool)

    order = np.zeros((J,), np.int32)
    assign = np.zeros((J, lmax), np.int32)
    bounds = np.zeros((J,), np.float64)
    cur = net
    n_routings = J
    for p in range(J):
        while True:
            # inf (not the finite INF sentinel) so routed jobs can never tie
            # with an unroutable active job's clipped-to-INF bound
            j = int(jnp.argmin(jnp.where(active, cost, jnp.inf)))
            if fresh[j]:
                break
            c, a = _route_one(cur, batch, j, closures, use_pallas=use_pallas)
            cost = cost.at[j].set(c)
            assign_c[j] = np.asarray(a)
            fresh[j] = True
            n_routings += 1
        order[p] = j
        bounds[j] = float(cost[j])
        assign[j] = assign_c[j]
        if paths is not None:
            paths[j] = _job_paths(cur, batch, j, assign_c[j], closures)
        active = active.at[j].set(False)
        cur = _commit_one(cur, batch, j, assign_c[j], closures,
                          use_pallas=use_pallas)
        if p + 1 < J:
            closures = fresh_closures(cur)
            fresh[:] = False
            fresh[j] = True  # routed jobs are never probed again
    return Plan.from_order(assign, order, bounds, solver="lazy",
                           meta={"n_routings": n_routings}, net=cur,
                           paths=paths)
