"""Physical computing network model G_p = (V_p, E_p).

Since the time-aware state split (see :mod:`repro.core.state`) the network
is two pytrees composed:

  * :class:`~repro.core.state.Topology` — immutable capacities ``mu_node``
    [V] (FLOP/s) and ``mu_link`` [V, V] (bytes/s),
  * :class:`~repro.core.state.QueueState` — backlogs ``q_node`` [V]
    (FLOPs), ``q_link`` [V, V] (bytes) and a scalar ``clock``, with a fluid
    ``advance(dt)`` that drains each resource at rate mu.

:class:`ComputeNetwork` is the thin composed *view* the jitted paths take:
``net.mu_node`` etc. delegate to the parts, so every consumer written
against the fused seed layout keeps working, while schedulers hold one
``Topology`` and thread ``QueueState`` explicitly (``topo.view(state)``
composes them with zero array rebuilds).

Absent links have ``mu_link == 0``; :func:`link_weight` maps them to ``INF``.
``INF`` is a large *finite* sentinel (not ``jnp.inf``) so that min-plus
arithmetic never produces NaNs (``inf - inf``) and argmins stay well-defined.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .state import QueueState, Topology, advance as _advance
from .validation import check_finite_nonneg as _check_finite_nonneg

INF = jnp.float32(1e30)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ComputeNetwork:
    """Zero-copy view composing a :class:`Topology` with a :class:`QueueState`."""

    topology: Topology
    state: QueueState

    # -- flat accessors (the seed's fused field layout) ---------------------
    @property
    def mu_node(self) -> jax.Array:
        return self.topology.mu_node

    @property
    def mu_link(self) -> jax.Array:
        return self.topology.mu_link

    @property
    def q_node(self) -> jax.Array:
        return self.state.q_node

    @property
    def q_link(self) -> jax.Array:
        return self.state.q_link

    @property
    def clock(self) -> jax.Array:
        return self.state.clock

    @property
    def num_nodes(self) -> int:
        return self.topology.num_nodes

    @classmethod
    def of(cls, mu_node, mu_link, q_node, q_link,
           clock: float = 0.0) -> "ComputeNetwork":
        """Build a view from flat arrays (the pre-split constructor shape)."""
        return cls(topology=Topology(mu_node=mu_node, mu_link=mu_link),
                   state=QueueState(q_node=q_node, q_link=q_link,
                                    clock=jnp.float32(clock)))

    def with_queues(self, q_node: jax.Array, q_link: jax.Array) -> "ComputeNetwork":
        """New backlogs, same topology and clock."""
        return dataclasses.replace(
            self, state=self.state.with_queues(q_node, q_link))

    def reset_queues(self) -> "ComputeNetwork":
        return self.with_queues(jnp.zeros_like(self.q_node),
                                jnp.zeros_like(self.q_link))

    def advance(self, dt) -> "ComputeNetwork":
        """Fluid drain: every resource works off backlog at rate mu for dt s."""
        return dataclasses.replace(
            self, state=_advance(self.topology, self.state, dt))


def make_network(
    num_nodes: int,
    edges: Iterable[tuple[int, int, float]],
    node_caps: Sequence[float],
    *,
    bidirectional: bool = True,
) -> ComputeNetwork:
    """Build a :class:`ComputeNetwork` from an edge list.

    Args:
      num_nodes: |V_p|.
      edges: (u, v, capacity bytes/s) triples.
      node_caps: [V] compute capacities in FLOP/s.
      bidirectional: mirror every edge (the paper assumes bidirectional links).

    Raises ``ValueError`` naming the offending field for negative/NaN
    capacities, out-of-range endpoints, or a mis-shaped ``node_caps``.
    """
    if num_nodes <= 0:
        raise ValueError(f"num_nodes must be positive, got {num_nodes}")
    mu_link = np.zeros((num_nodes, num_nodes), np.float32)
    for i, (u, v, cap) in enumerate(edges):
        if not (0 <= u < num_nodes and 0 <= v < num_nodes):
            raise ValueError(
                f"edges[{i}]=({u}, {v}): endpoint out of range [0, {num_nodes})")
        if u == v:
            raise ValueError(f"edges[{i}]: self-loop ({u}, {v}) not allowed")
        if not np.isfinite(cap) or cap < 0:
            raise ValueError(
                f"edges[{i}]=({u}, {v}): capacity {cap!r} must be finite and >= 0")
        mu_link[u, v] = cap
        if bidirectional:
            mu_link[v, u] = cap
    mu_node = np.asarray(node_caps, np.float32)
    if mu_node.shape != (num_nodes,):
        raise ValueError(
            f"node_caps must have shape ({num_nodes},), got {mu_node.shape}")
    _check_finite_nonneg("node_caps", mu_node)
    return ComputeNetwork.of(
        mu_node=jnp.asarray(mu_node),
        mu_link=jnp.asarray(mu_link),
        q_node=jnp.zeros((num_nodes,), jnp.float32),
        q_link=jnp.zeros((num_nodes, num_nodes), jnp.float32),
    )


@jax.jit
def link_invrate(net: ComputeNetwork) -> jax.Array:
    """[V,V] reciprocal link capacity; INF where there is no link.

    The diagonal is 0: staying at a node costs nothing to "transfer".
    Jitted so the scalar constants are baked at trace time — the eager
    form implicitly staged them per call, tripping the
    transfer_guard("disallow") the fused parity tests run under (every op
    here is elementwise-exact, so jitting cannot change a bit).
    """
    v = net.num_nodes
    inv = jnp.where(net.mu_link > 0, 1.0 / jnp.maximum(net.mu_link, 1e-30), INF)
    return inv.at[jnp.arange(v), jnp.arange(v)].set(0.0)


def link_wait(net: ComputeNetwork) -> jax.Array:
    """[V,V] per-traversal waiting time Q_uv / mu_uv; 0 on the diagonal."""
    v = net.num_nodes
    w = jnp.where(net.mu_link > 0, net.q_link / jnp.maximum(net.mu_link, 1e-30), 0.0)
    return w.at[jnp.arange(v), jnp.arange(v)].set(0.0)


def node_invrate(net: ComputeNetwork) -> jax.Array:
    """[V] reciprocal compute capacity; INF where the node has no compute."""
    return jnp.where(net.mu_node > 0, 1.0 / jnp.maximum(net.mu_node, 1e-30), INF)


def node_wait(net: ComputeNetwork) -> jax.Array:
    """[V] compute waiting time Q_u / mu_u; 0 for compute-less nodes."""
    return jnp.where(net.mu_node > 0, net.q_node / jnp.maximum(net.mu_node, 1e-30), 0.0)


def edge_list(net: ComputeNetwork) -> list[tuple[int, int]]:
    """Directed edges (host-side helper)."""
    mu = np.asarray(net.mu_link)
    us, vs = np.nonzero(mu > 0)
    return list(zip(us.tolist(), vs.tolist()))


# ---------------------------------------------------------------------------
# The paper's two evaluation topologies.
# ---------------------------------------------------------------------------

def small_topology(*, capacity_scale: float = 1.0) -> tuple[ComputeNetwork, list[str]]:
    """The 5-node topology of Fig. 2 / §V.

    Nodes: s, u, w, v, t with compute capacities 200/70/50/50/30 GFLOP/s.
    Links: s-u, s-w, u-w, u-v, w-v, w-t, v-t with capacities 125 or 375 MB/s.
    ``capacity_scale`` multiplies the *link* capacities (the paper scans a
    universal scale factor, e.g. 1e-4).
    """
    names = ["s", "u", "w", "v", "t"]
    G = 1e9
    MB = 1e6
    node_caps = [200 * G, 70 * G, 50 * G, 50 * G, 30 * G]
    edges = [
        (0, 1, 375 * MB), (0, 2, 125 * MB), (1, 2, 125 * MB),
        (1, 3, 375 * MB), (2, 3, 125 * MB), (2, 4, 375 * MB),
        (3, 4, 125 * MB),
    ]
    edges = [(u, v, c * capacity_scale) for u, v, c in edges]
    return make_network(5, edges, node_caps), names


_US_BACKBONE_EDGES = [
    # 24-node US backbone (USNET-style, 43 bidirectional links).  The paper's
    # Fig. 4 is an image; this is the standard USNET connectivity (documented
    # approximation, see DESIGN.md §5).
    (0, 1), (0, 5), (1, 2), (1, 5), (2, 3), (2, 4), (3, 4), (3, 6),
    (4, 7), (5, 8), (5, 10), (6, 7), (6, 9), (7, 9), (8, 9), (8, 10),
    (9, 12), (10, 11), (10, 13), (11, 12), (11, 14), (12, 15), (13, 14),
    (13, 16), (14, 15), (14, 18), (15, 19), (16, 17), (16, 20), (17, 18),
    (17, 21), (18, 19), (18, 22), (19, 23), (20, 21), (21, 22), (22, 23),
    (2, 6), (9, 13), (12, 14), (20, 22), (4, 6), (11, 15),
]


def us_backbone(*, capacity_scale: float = 1.0, seed: int = 0) -> tuple[ComputeNetwork, list[str]]:
    """The 24-node US backbone of Fig. 4.

    Node compute capacities follow the paper: [30, 50, 200, 100, 70] repeating
    in increasing node order. Link capacities use the same {125, 375} MB/s mix
    as the small topology (deterministic per-edge choice by parity of u+v).
    """
    G = 1e9
    MB = 1e6
    caps_cycle = [30, 50, 200, 100, 70]
    node_caps = [caps_cycle[i % 5] * G for i in range(24)]
    edges = []
    for (u, v) in _US_BACKBONE_EDGES:
        cap = (375 if (u + v) % 2 == 0 else 125) * MB
        edges.append((u, v, cap * capacity_scale))
    names = [f"n{i}" for i in range(24)]
    return make_network(24, edges, node_caps), names
