"""Physical computing network model G_p = (V_p, E_p).

Nodes carry compute capacity ``mu_node`` (FLOP/s) and a compute queue
``q_node`` (FLOPs of unfinished higher-priority work).  Directed links carry
transmission capacity ``mu_link`` (bytes/s) and a transmission queue
``q_link`` (bytes).  Everything is stored densely as ``[V]`` / ``[V, V]``
arrays so the whole structure is a JAX pytree and can flow through jit/vmap.

Absent links have ``mu_link == 0``; :func:`link_weight` maps them to ``INF``.
``INF`` is a large *finite* sentinel (not ``jnp.inf``) so that min-plus
arithmetic never produces NaNs (``inf - inf``) and argmins stay well-defined.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.float32(1e30)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ComputeNetwork:
    """Dense representation of the physical computing network."""

    mu_node: jax.Array  # [V] FLOP/s  (0 = no compute resources at node)
    mu_link: jax.Array  # [V, V] bytes/s (0 = no link)
    q_node: jax.Array   # [V] FLOPs queued
    q_link: jax.Array   # [V, V] bytes queued

    @property
    def num_nodes(self) -> int:
        return self.mu_node.shape[0]

    def with_queues(self, q_node: jax.Array, q_link: jax.Array) -> "ComputeNetwork":
        return dataclasses.replace(self, q_node=q_node, q_link=q_link)

    def reset_queues(self) -> "ComputeNetwork":
        return self.with_queues(jnp.zeros_like(self.q_node), jnp.zeros_like(self.q_link))


def make_network(
    num_nodes: int,
    edges: Iterable[tuple[int, int, float]],
    node_caps: Sequence[float],
    *,
    bidirectional: bool = True,
) -> ComputeNetwork:
    """Build a :class:`ComputeNetwork` from an edge list.

    Args:
      num_nodes: |V_p|.
      edges: (u, v, capacity bytes/s) triples.
      node_caps: [V] compute capacities in FLOP/s.
      bidirectional: mirror every edge (the paper assumes bidirectional links).
    """
    mu_link = np.zeros((num_nodes, num_nodes), np.float32)
    for u, v, cap in edges:
        mu_link[u, v] = cap
        if bidirectional:
            mu_link[v, u] = cap
    mu_node = np.asarray(node_caps, np.float32)
    if mu_node.shape != (num_nodes,):
        raise ValueError(f"node_caps must have shape ({num_nodes},)")
    return ComputeNetwork(
        mu_node=jnp.asarray(mu_node),
        mu_link=jnp.asarray(mu_link),
        q_node=jnp.zeros((num_nodes,), jnp.float32),
        q_link=jnp.zeros((num_nodes, num_nodes), jnp.float32),
    )


def link_invrate(net: ComputeNetwork) -> jax.Array:
    """[V,V] reciprocal link capacity; INF where there is no link.

    The diagonal is 0: staying at a node costs nothing to "transfer".
    """
    v = net.num_nodes
    inv = jnp.where(net.mu_link > 0, 1.0 / jnp.maximum(net.mu_link, 1e-30), INF)
    return inv.at[jnp.arange(v), jnp.arange(v)].set(0.0)


def link_wait(net: ComputeNetwork) -> jax.Array:
    """[V,V] per-traversal waiting time Q_uv / mu_uv; 0 on the diagonal."""
    v = net.num_nodes
    w = jnp.where(net.mu_link > 0, net.q_link / jnp.maximum(net.mu_link, 1e-30), 0.0)
    return w.at[jnp.arange(v), jnp.arange(v)].set(0.0)


def node_invrate(net: ComputeNetwork) -> jax.Array:
    """[V] reciprocal compute capacity; INF where the node has no compute."""
    return jnp.where(net.mu_node > 0, 1.0 / jnp.maximum(net.mu_node, 1e-30), INF)


def node_wait(net: ComputeNetwork) -> jax.Array:
    """[V] compute waiting time Q_u / mu_u; 0 for compute-less nodes."""
    return jnp.where(net.mu_node > 0, net.q_node / jnp.maximum(net.mu_node, 1e-30), 0.0)


def edge_list(net: ComputeNetwork) -> list[tuple[int, int]]:
    """Directed edges (host-side helper)."""
    mu = np.asarray(net.mu_link)
    us, vs = np.nonzero(mu > 0)
    return list(zip(us.tolist(), vs.tolist()))


# ---------------------------------------------------------------------------
# The paper's two evaluation topologies.
# ---------------------------------------------------------------------------

def small_topology(*, capacity_scale: float = 1.0) -> tuple[ComputeNetwork, list[str]]:
    """The 5-node topology of Fig. 2 / §V.

    Nodes: s, u, w, v, t with compute capacities 200/70/50/50/30 GFLOP/s.
    Links: s-u, s-w, u-w, u-v, w-v, w-t, v-t with capacities 125 or 375 MB/s.
    ``capacity_scale`` multiplies the *link* capacities (the paper scans a
    universal scale factor, e.g. 1e-4).
    """
    names = ["s", "u", "w", "v", "t"]
    G = 1e9
    MB = 1e6
    node_caps = [200 * G, 70 * G, 50 * G, 50 * G, 30 * G]
    edges = [
        (0, 1, 375 * MB), (0, 2, 125 * MB), (1, 2, 125 * MB),
        (1, 3, 375 * MB), (2, 3, 125 * MB), (2, 4, 375 * MB),
        (3, 4, 125 * MB),
    ]
    edges = [(u, v, c * capacity_scale) for u, v, c in edges]
    return make_network(5, edges, node_caps), names


_US_BACKBONE_EDGES = [
    # 24-node US backbone (USNET-style, 43 bidirectional links).  The paper's
    # Fig. 4 is an image; this is the standard USNET connectivity (documented
    # approximation, see DESIGN.md §5).
    (0, 1), (0, 5), (1, 2), (1, 5), (2, 3), (2, 4), (3, 4), (3, 6),
    (4, 7), (5, 8), (5, 10), (6, 7), (6, 9), (7, 9), (8, 9), (8, 10),
    (9, 12), (10, 11), (10, 13), (11, 12), (11, 14), (12, 15), (13, 14),
    (13, 16), (14, 15), (14, 18), (15, 19), (16, 17), (16, 20), (17, 18),
    (17, 21), (18, 19), (18, 22), (19, 23), (20, 21), (21, 22), (22, 23),
    (2, 6), (9, 13), (12, 14), (20, 22), (4, 6), (11, 15),
]


def us_backbone(*, capacity_scale: float = 1.0, seed: int = 0) -> tuple[ComputeNetwork, list[str]]:
    """The 24-node US backbone of Fig. 4.

    Node compute capacities follow the paper: [30, 50, 200, 100, 70] repeating
    in increasing node order. Link capacities use the same {125, 375} MB/s mix
    as the small topology (deterministic per-edge choice by parity of u+v).
    """
    G = 1e9
    MB = 1e6
    caps_cycle = [30, 50, 200, 100, 70]
    node_caps = [caps_cycle[i % 5] * G for i in range(24)]
    edges = []
    for (u, v) in _US_BACKBONE_EDGES:
        cap = (375 if (u + v) % 2 == 0 else 125) * MB
        edges.append((u, v, cap * capacity_scale))
    names = [f"n{i}" for i in range(24)]
    return make_network(24, edges, node_caps), names
