"""Per-plan committed-work ledger + exact (event-accurate) queue drain.

The fluid drain (:meth:`repro.core.state.QueueState.advance`) serves every
resource independently at full rate: ``q <- max(q - mu * dt, 0)``.  That is
the *most optimistic* work-conserving service model — it drains link bytes
for layers whose producing compute hasn't finished, and node FLOPs out of
priority order.  The paper's queues Q charge waiting time against
*committed work* served by a preempt-resume priority system (the model
``core.schedule.simulate`` implements exactly), so fluid-drained backlogs —
and every latency bound evaluated against them — are systematically
optimistic.

:class:`CommittedWork` closes that gap.  It is the host-side companion to
the :class:`~repro.core.state.QueueState` pytree: a ledger recording, per
committed plan, each job's per-resource work items with its global priority
and precedence (layer k's transfer cannot drain before layer k's compute
completes — the stage order of :func:`repro.core.schedule.job_stages`).
:func:`drain_exact` advances the ledger through the shared event loop
(:func:`repro.core.schedule.run_event_loop`) a ``dt`` window at a time —
the same preempt-resume semantics as the one-shot simulator, run
incrementally between online arrivals.  The ledger is deliberately *not* a
JAX pytree leaf container: the event loop is data-dependent control flow
that belongs on the host; only the residual per-resource work it implies
(:meth:`CommittedWork.queue_arrays`) is materialized back into the jitted
``QueueState`` the solvers consume.

All ledger operations are functional (they return new ledgers and never
mutate tasks in place), so a scheduler can snapshot a ledger by reference —
``replan_last``'s rollback does exactly that.

Priorities are ledger-global: plans committed earlier hold strictly higher
priority than later ones (each batch was solved against the queue state its
predecessors built), and within a plan jobs keep their solver-assigned
order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import schedule
from .state import QueueState, Topology


@dataclasses.dataclass(frozen=True)
class LedgerJob:
    """One committed job's work items and drain progress."""

    name: str
    prio: int                  # ledger-global priority (0 = served first)
    release: float             # absolute commit/arrival time (s)
    stages: tuple[schedule.Stage, ...]  # (resource, work) in precedence order
    ptr: int = 0               # completed-stage count
    remaining: float | None = None      # residual work of the current stage
    arrived: float = 0.0       # instant the job became ready at this stage

    @property
    def finished(self) -> bool:
        return self.ptr >= len(self.stages)


@dataclasses.dataclass(frozen=True)
class CommittedWork:
    """Ledger of committed-but-unfinished work across all committed plans.

    ``jobs`` holds live (unfinished) jobs; ``completed`` accumulates
    ``(name, absolute completion time)`` pairs as drains finish jobs.
    ``clock`` is the absolute time the ledger has been drained to — a
    never-drained ledger (a pure commit *log*) keeps its initial clock, and
    its jobs' ``release`` times drive the full-horizon replay instead.
    """

    num_nodes: int
    clock: float = 0.0
    jobs: tuple[LedgerJob, ...] = ()
    completed: tuple[tuple[str, float], ...] = ()
    next_prio: int = 0
    # Completion records are keyed by job name, so names must be unique for
    # the lifetime of the ledger; commit() enforces it against this set.
    names_seen: frozenset[str] = frozenset()

    @classmethod
    def empty(cls, num_nodes: int, clock: float = 0.0) -> "CommittedWork":
        return cls(num_nodes=int(num_nodes), clock=float(clock))

    # -- committing plans -----------------------------------------------------
    def commit(self, batch, plan, *, names=None,
               at: float | None = None) -> "CommittedWork":
        """Append one work item per job of a solved plan, released at ``at``.

        The plan must carry explicit transfer paths (``plan.paths``, filled
        by ``Plan.replay`` or ``schedule.replay_solution`` against the queue
        state the plan was solved for); the ledger charges each layer's
        bytes to exactly the hops the plan routed them over.  ``names`` (one
        per job, batch order) key the completion records, so they must be
        unique over the ledger's lifetime (a duplicate would silently
        overwrite an earlier job's completion time) — a repeat raises
        ``ValueError``; defaults to ``p<prio>``, unique by construction.
        The ledger clock is *not* moved — commits are events, drains move
        time.
        """
        at = self.clock if at is None else float(at)
        if at < self.clock - 1e-9:
            raise ValueError(
                f"cannot commit at t={at} behind the ledger clock {self.clock}")
        if plan.paths is None:
            raise ValueError(
                "plan must carry explicit paths to be committed to the "
                "ledger; derive them with plan.replay(net, batch) or "
                "schedule.replay_solution against the solve-time queue state")
        stages = schedule.job_stages(batch, plan.assign, plan.paths)
        order = plan.order
        jobs = list(self.jobs)
        seen = set(self.names_seen)
        for slot in range(plan.num_jobs):
            j = int(order[slot])
            prio = self.next_prio + slot
            name = names[j] if names is not None else f"p{prio}"
            if name in seen:
                raise ValueError(
                    f"duplicate job name {name!r}: completion tracking keys "
                    f"on job names, which must be unique per ledger — give "
                    f"requests/jobs distinct names")
            seen.add(name)
            jobs.append(LedgerJob(name=name, prio=prio, release=at,
                                  stages=tuple(stages[j]), arrived=at))
        return dataclasses.replace(
            self, jobs=tuple(jobs), next_prio=self.next_prio + plan.num_jobs,
            names_seen=frozenset(seen))

    def cleared(self) -> "CommittedWork":
        """Drop all live jobs without recording completions (a scheduler's
        hard reset — see ``RoutedScheduler.drain``)."""
        return dataclasses.replace(self, jobs=())

    # -- materializing state --------------------------------------------------
    def queue_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """Residual committed work per resource: (q_node [V], q_link [V, V]).

        The exact-model counterpart of the fluid backlogs: the current
        stage's residual plus every not-yet-started stage of every live
        job, charged to its resource.  float32, ready for
        ``QueueState.with_queues``.
        """
        qn = np.zeros((self.num_nodes,), np.float64)
        ql = np.zeros((self.num_nodes, self.num_nodes), np.float64)
        for job in self.jobs:
            for k in range(job.ptr, len(job.stages)):
                res, work = job.stages[k]
                w = (job.remaining
                     if k == job.ptr and job.remaining is not None else work)
                if res[0] == "node":
                    qn[res[1]] += w
                else:
                    ql[res[1], res[2]] += w
        return qn.astype(np.float32), ql.astype(np.float32)

    def queue_state(self, clock: float | None = None) -> QueueState:
        """Residual work as a :class:`QueueState` (clock defaults to the
        ledger clock)."""
        import jax.numpy as jnp
        qn, ql = self.queue_arrays()
        return QueueState(q_node=jnp.asarray(qn), q_link=jnp.asarray(ql),
                          clock=jnp.float32(self.clock if clock is None
                                            else clock))

    def backlog_seconds(self, topo: Topology) -> float:
        """Worst-resource residual wait under the exact model (see
        :func:`repro.core.state.backlog_seconds`)."""
        from .state import backlog_seconds as _bs
        return _bs(topo, self.queue_state())


def _tasks_of(ledger: CommittedWork) -> list[schedule.TaskRun]:
    return [schedule.TaskRun(stages=list(job.stages), prio=job.prio,
                             ptr=job.ptr, remaining=job.remaining,
                             arrived=job.arrived)
            for job in ledger.jobs]


def _fold(ledger: CommittedWork, tasks: list[schedule.TaskRun],
          clock: float) -> CommittedWork:
    """New ledger from post-loop task states (completions recorded)."""
    live: list[LedgerJob] = []
    done = list(ledger.completed)
    for job, task in zip(ledger.jobs, tasks):
        if task.done:
            done.append((job.name, float(task.completion)))
        else:
            live.append(dataclasses.replace(job, ptr=task.ptr,
                                            remaining=task.remaining,
                                            arrived=task.arrived))
    return dataclasses.replace(ledger, clock=float(clock), jobs=tuple(live),
                               completed=tuple(done))


def drain_exact(topo: Topology, ledger: CommittedWork, dt) -> CommittedWork:
    """Advance the ledger ``dt`` seconds with preempt-resume priority service.

    The exact counterpart of the fluid ``QueueState.advance``: every
    resource serves the highest-priority *ready* work item (precedence
    respected, preempting on arrival, work-conserving), via the same event
    loop as :func:`repro.core.schedule.simulate`.  Draining in chunks
    composes exactly: ``drain_exact(ledger, a)`` then ``b`` equals
    ``drain_exact(ledger, a + b)`` — the property tests assert it.

    ``topo`` is the *effective* topology (straggler-scaled rates apply for
    the whole window, the same piecewise-constant-health approximation the
    fluid drain makes).  Jobs finishing inside the window move to
    ``ledger.completed`` with their completion instants.
    """
    dt = float(dt)
    if dt < 0:
        raise ValueError(f"dt must be >= 0, got {dt}")
    t_end = ledger.clock + dt
    if dt == 0.0 or not ledger.jobs:
        return dataclasses.replace(ledger, clock=t_end)
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    tasks = _tasks_of(ledger)
    schedule.run_event_loop(tasks, mu_node, mu_link, t=ledger.clock,
                            t_end=t_end)
    return _fold(ledger, tasks, t_end)


def run_to_completion(topo: Topology,
                      ledger: CommittedWork) -> tuple[dict[str, float],
                                                      "CommittedWork"]:
    """Serve every committed job to completion; the ground-truth replay.

    Returns ``({name: absolute completion time} — including jobs already
    completed by earlier drains — , the fully drained ledger)``.  On a
    never-drained commit log this is the full-horizon event simulation of
    the whole arrival history (jobs start at their ``release`` times); on a
    live exact ledger it finishes the residual work — the two must agree,
    which the fidelity benchmark checks.
    """
    completions = dict(ledger.completed)
    if not ledger.jobs:
        return completions, ledger
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    tasks = _tasks_of(ledger)
    t = schedule.run_event_loop(tasks, mu_node, mu_link, t=ledger.clock)
    out = _fold(ledger, tasks, max(ledger.clock, t))
    completions.update({name: when for name, when in out.completed})
    return completions, out


def exact_backlog_trace(topo: Topology, log: CommittedWork,
                        times) -> np.ndarray:
    """Exact-model backlog (s) just before each epoch of a commit log.

    Replays the *same plans* the log records — released at their commit
    times — under :func:`drain_exact`, measuring the worst-resource
    residual wait immediately before each ``times[i]`` (jobs committed at
    exactly ``times[i]`` are excluded, matching the online trace's
    ``backlog_before``).  Comparing against the fluid run's backlogs
    isolates the drain semantics: policy decisions are held fixed.

    ``log`` must be an undrained ledger (``track_commits=True`` keeps one).
    """
    jobs = sorted(log.jobs, key=lambda j: j.prio)
    if any(j.ptr or j.remaining is not None for j in jobs):
        raise ValueError("exact_backlog_trace needs an undrained commit log")
    cur = dataclasses.replace(log, jobs=(), completed=())
    out = []
    k = 0
    for t in np.asarray(times, np.float64):
        add = []
        while k < len(jobs) and jobs[k].release < t - 1e-12:
            add.append(jobs[k])
            k += 1
        if add:
            cur = dataclasses.replace(cur, jobs=cur.jobs + tuple(add))
        cur = drain_exact(topo, cur, max(float(t) - cur.clock, 0.0))
        out.append(cur.backlog_seconds(topo))
    return np.asarray(out, np.float64)
