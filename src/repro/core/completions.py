"""Per-plan committed-work ledger + exact (event-accurate) queue drain.

The fluid drain (:meth:`repro.core.state.QueueState.advance`) serves every
resource independently at full rate: ``q <- max(q - mu * dt, 0)``.  That is
the *most optimistic* work-conserving service model — it drains link bytes
for layers whose producing compute hasn't finished, and node FLOPs out of
priority order.  The paper's queues Q charge waiting time against
*committed work* served by a preempt-resume priority system (the model
``core.schedule.simulate`` implements exactly), so fluid-drained backlogs —
and every latency bound evaluated against them — are systematically
optimistic.

:class:`CommittedWork` closes that gap.  It is the host-side companion to
the :class:`~repro.core.state.QueueState` pytree: a ledger recording, per
committed plan, each job's per-resource work items with its global priority
and precedence (layer k's transfer cannot drain before layer k's compute
completes — the stage order of :func:`repro.core.schedule.job_stages`).
:func:`drain_exact` advances the ledger with the same preempt-resume
semantics as the one-shot simulator, a ``dt`` window at a time,
incrementally between online arrivals.  The ledger is deliberately *not* a
JAX pytree leaf container: the event loop is data-dependent control flow
that belongs on the host; only the residual per-resource work it implies
(:meth:`CommittedWork.queue_arrays`) is materialized back into the jitted
``QueueState`` the solvers consume.

All ledger operations are functional (they return new ledgers and never
mutate tasks in place), so a scheduler can snapshot a ledger by reference —
``replan_last``'s rollback does exactly that.

Two engines drive the drain (``engine="indexed" | "ref"`` on every entry
point).  The default is the persistent indexed engine
(:mod:`repro.core.eventsim`): each drained/committed ledger carries a
*cache slot* pointing at the live engine, so consecutive windows reuse the
indexes instead of rebuilding every ``TaskRun`` per arrival.  The slot is
stamp-guarded and strictly linear — draining a ledger hands the engine to
the *result* ledger and invalidates the input's slot, so an old snapshot
(``replan_last``'s rollback, a branched what-if drain) simply rebuilds
lazily from its immutable job records.  ``engine="ref"`` runs the seed
linear-scan loop (:func:`repro.core.schedule.run_event_loop_ref`) — the
parity reference ``benchmarks/drain_bench.py`` gates against.

``health`` records infrastructure events ``(time, key, factor)`` on the
same log — ``report_slowdown`` factors on node keys, and (since the fault
layer) full *availability*: ``factor=inf`` marks the keyed node or
directed link down, any finite factor marks it up again at that slowdown
(recovery records ``1.0``).  ``removed`` records fault-policy withdrawals
``(time, name)``.  :func:`replay_piecewise` merges both histories and
replays the ground truth segment by segment at the effective topology
(and resource availability) actually in force — not a single end-state
topology for the whole horizon.

Priorities are ledger-global: plans committed earlier hold strictly higher
priority than later ones (each batch was solved against the queue state its
predecessors built), and within a plan jobs keep their solver-assigned
order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import eventsim, schedule
from .state import QueueState, Topology, effective_topology


@dataclasses.dataclass(frozen=True)
class LedgerJob:
    """One committed job's work items and drain progress."""

    name: str
    prio: int                  # ledger-global priority (0 = served first)
    release: float             # absolute commit/arrival time (s)
    stages: tuple[schedule.Stage, ...]  # (resource, work) in precedence order
    ptr: int = 0               # completed-stage count
    remaining: float | None = None      # residual work of the current stage
    arrived: float = 0.0       # instant the job became ready at this stage

    @property
    def finished(self) -> bool:
        return self.ptr >= len(self.stages)


@dataclasses.dataclass(frozen=True)
class CommittedWork:
    """Ledger of committed-but-unfinished work across all committed plans.

    ``jobs`` holds live (unfinished) jobs; ``completed`` accumulates
    ``(name, absolute completion time)`` pairs as drains finish jobs.
    ``clock`` is the absolute time the ledger has been drained to — a
    never-drained ledger (a pure commit *log*) keeps its initial clock, and
    its jobs' ``release`` times drive the full-horizon replay instead.
    """

    num_nodes: int
    clock: float = 0.0
    jobs: tuple[LedgerJob, ...] = ()
    completed: tuple[tuple[str, float], ...] = ()
    next_prio: int = 0
    # Completion records are keyed by job name, so names must be unique for
    # the lifetime of the ledger; commit() enforces it against this set.
    names_seen: frozenset[str] = frozenset()
    # Health history: (absolute time, key, factor) events in record order,
    # where key is a node index or a ("link", u, v) tuple.  A finite factor
    # is a slowdown (the node/link is up at mu/factor; 1.0 = full health);
    # factor=inf marks the resource *unavailable* — a node failure takes
    # its incident links down implicitly.  A pure annotation — drains
    # ignore it (the caller picks the effective topology per window);
    # replay_piecewise() consumes it.
    health: tuple[tuple[float, object, float], ...] = ()
    # Fault-policy withdrawals: (absolute time, job name).  Jobs stay in a
    # pure commit *log* until the replay reaches the removal instant; a
    # live ledger drops them immediately (remove_jobs) and records here.
    removed: tuple[tuple[float, str], ...] = ()

    @classmethod
    def empty(cls, num_nodes: int, clock: float = 0.0) -> "CommittedWork":
        return cls(num_nodes=int(num_nodes), clock=float(clock))

    def record_health(self, at: float, key, factor: float) -> "CommittedWork":
        """Annotate the log with a health event on ``key`` (a node index or
        ``("link", u, v)``).  ``factor`` follows the scheduler's "factor=2
        = half speed" convention; ``inf`` marks the resource down, any
        finite factor marks it up again at that slowdown."""
        if isinstance(key, tuple):
            if len(key) != 3 or key[0] != "link":
                raise ValueError(
                    f"health key must be a node index or ('link', u, v), "
                    f"got {key!r}")
            key = ("link", int(key[1]), int(key[2]))
        else:
            key = int(key)
        return dataclasses.replace(
            self, health=self.health + ((float(at), key, float(factor)),))

    def record_slowdown(self, at: float, node: int,
                        factor: float) -> "CommittedWork":
        """Annotate the log with a node health event (``factor=2`` = half
        speed, the scheduler's convention); replay_piecewise() replays
        segment by segment at the recorded factors."""
        return self.record_health(at, int(node), factor)

    def record_removal(self, at: float, names) -> "CommittedWork":
        """Annotate a commit *log* with fault-policy withdrawals: the named
        jobs were requeued/migrated/lost at ``at``.  The job records stay
        (the replay serves them up to the removal instant, then drops the
        residual); a *live* ledger removes jobs via :meth:`remove_jobs`."""
        return dataclasses.replace(
            self, removed=self.removed + tuple(
                (float(at), str(n)) for n in names))

    def remove_jobs(self, names, *, at: float | None = None,
                    missing_ok: bool = False,
                    record: bool = True) -> "CommittedWork":
        """Withdraw live jobs by name (a fault policy re-placing or
        shedding their residual work).  Served work stays served; no
        completion is recorded.  Unknown or already-completed names raise
        unless ``missing_ok`` (the replay path tolerates jobs that finished
        marginally before their recorded removal).  ``record=False`` skips
        the ``removed`` annotation (used by the replay itself, whose event
        list is already fixed)."""
        at = self.clock if at is None else float(at)
        want = set(map(str, names))
        live = {j.name for j in self.jobs}
        if not missing_ok and not want <= live:
            raise ValueError(
                f"cannot remove unknown/completed job(s) "
                f"{sorted(want - live)}: only live committed jobs can be "
                f"withdrawn (pass missing_ok=True to skip them)")
        hit = want & live
        new = dataclasses.replace(
            self,
            jobs=tuple(j for j in self.jobs if j.name not in hit),
            removed=self.removed + tuple(sorted((at, n) for n in hit))
            if record else self.removed)
        eng = _engine_of(self)
        if eng is not None:
            try:
                eng.remove(hit)
            except Exception:
                eng.stamp += 1     # poison the half-mutated index
                raise
            _attach(new, eng)
        return new

    # -- committing plans -----------------------------------------------------
    def commit(self, batch, plan, *, names=None,
               at: float | None = None) -> "CommittedWork":
        """Append one work item per job of a solved plan, released at ``at``.

        The plan must carry explicit transfer paths (``plan.paths``, filled
        by ``Plan.replay`` or ``schedule.replay_solution`` against the queue
        state the plan was solved for); the ledger charges each layer's
        bytes to exactly the hops the plan routed them over.  ``names`` (one
        per job, batch order) key the completion records, so they must be
        unique over the ledger's lifetime (a duplicate would silently
        overwrite an earlier job's completion time) — a repeat raises
        ``ValueError``; defaults to ``p<prio>``, unique by construction.
        The ledger clock is *not* moved — commits are events, drains move
        time.
        """
        at = self.clock if at is None else float(at)
        if at < self.clock - 1e-9:
            raise ValueError(
                f"cannot commit at t={at} behind the ledger clock {self.clock}")
        jobs = list(self.jobs)
        seen = set(self.names_seen)
        added = _plan_jobs(batch, plan, names=names, next_prio=self.next_prio,
                           at=at, seen=seen)
        jobs.extend(added)
        new = dataclasses.replace(
            self, jobs=tuple(jobs), next_prio=self.next_prio + plan.num_jobs,
            names_seen=frozenset(seen))
        eng = _engine_of(self)
        if eng is not None:
            try:
                eng.commit(added)  # extend the live index in place
            except Exception:
                eng.stamp += 1     # poison the half-extended index
                raise
            _attach(new, eng)
        return new

    def cleared(self) -> "CommittedWork":
        """Drop all live jobs without recording completions (a scheduler's
        hard reset — see ``RoutedScheduler.drain``)."""
        return dataclasses.replace(self, jobs=())

    # -- materializing state --------------------------------------------------
    def queue_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """Residual committed work per resource: (q_node [V], q_link [V, V]).

        The exact-model counterpart of the fluid backlogs: the current
        stage's residual plus every not-yet-started stage of every live
        job, charged to its resource.  float32, ready for
        ``QueueState.with_queues``.  A ledger carrying a live engine reads
        the incrementally maintained arrays (O(V^2), no job rescan).
        """
        eng = _engine_of(self)
        if eng is not None:
            qn, ql = eng.eng.queue_arrays()
            return qn.astype(np.float32), ql.astype(np.float32)
        qn = np.zeros((self.num_nodes,), np.float64)
        ql = np.zeros((self.num_nodes, self.num_nodes), np.float64)
        for job in self.jobs:
            for k in range(job.ptr, len(job.stages)):
                res, work = job.stages[k]
                w = (job.remaining
                     if k == job.ptr and job.remaining is not None else work)
                if res[0] == "node":
                    qn[res[1]] += w
                else:
                    ql[res[1], res[2]] += w
        return qn.astype(np.float32), ql.astype(np.float32)

    def queue_state(self, clock: float | None = None) -> QueueState:
        """Residual work as a :class:`QueueState` (clock defaults to the
        ledger clock)."""
        import jax.numpy as jnp
        qn, ql = self.queue_arrays()
        return QueueState(q_node=jnp.asarray(qn), q_link=jnp.asarray(ql),
                          clock=jnp.float32(self.clock if clock is None
                                            else clock))

    def backlog_seconds(self, topo: Topology) -> float:
        """Worst-resource residual wait under the exact model (see
        :func:`repro.core.state.backlog_seconds`)."""
        from .state import backlog_seconds as _bs
        return _bs(topo, self.queue_state())


def _plan_jobs(batch, plan, *, names, next_prio: int, at: float,
               seen: set) -> list[LedgerJob]:
    """Ledger records for one solved plan (shared by :meth:`CommittedWork.
    commit` and :func:`predict_completions`'s uncommitted candidates).

    ``seen`` is mutated in place so successive plans in one call share the
    uniqueness check.
    """
    if plan.paths is None:
        raise ValueError(
            "plan must carry explicit paths to be committed to the "
            "ledger; derive them with plan.replay(net, batch) or "
            "schedule.replay_solution against the solve-time queue state")
    stages = schedule.job_stages(batch, plan.assign, plan.paths)
    order = plan.order
    added: list[LedgerJob] = []
    for slot in range(plan.num_jobs):
        j = int(order[slot])
        prio = next_prio + slot
        name = names[j] if names is not None else f"p{prio}"
        if name in seen:
            raise ValueError(
                f"duplicate job name {name!r}: completion tracking keys "
                f"on job names, which must be unique per ledger — give "
                f"requests/jobs distinct names")
        seen.add(name)
        added.append(LedgerJob(name=name, prio=prio, release=at,
                               stages=tuple(stages[j]), arrived=at))
    return added


def _task_of(job: LedgerJob) -> schedule.TaskRun:
    return schedule.TaskRun(stages=list(job.stages), prio=job.prio,
                            ptr=job.ptr, remaining=job.remaining,
                            arrived=job.arrived)


def _tasks_of(ledger: CommittedWork) -> list[schedule.TaskRun]:
    return [_task_of(job) for job in ledger.jobs]


def _fold(ledger: CommittedWork, tasks: list[schedule.TaskRun],
          clock: float) -> CommittedWork:
    """New ledger from post-loop task states (completions recorded)."""
    live: list[LedgerJob] = []
    done = list(ledger.completed)
    for job, task in zip(ledger.jobs, tasks):
        if task.done:
            done.append((job.name, float(task.completion)))
        else:
            live.append(dataclasses.replace(job, ptr=task.ptr,
                                            remaining=task.remaining,
                                            arrived=task.arrived))
    return dataclasses.replace(ledger, clock=float(clock), jobs=tuple(live),
                               completed=tuple(done))


# -- the persistent engine cache ----------------------------------------------
#
# A drained/committed ledger may carry a live indexed engine in a slot set
# with object.__setattr__ (not a dataclass field: dataclasses.replace()
# must NOT copy it onto unrelated successors, and it never serializes).
# The slot is stamp-guarded: using the engine (drain, commit) hands it to
# the result ledger and bumps the stamp, so every stale snapshot — a
# replan rollback, a branched what-if drain — fails the stamp check and
# lazily rebuilds from its own immutable job records instead.

_ENGINE_SLOT = "_sim_engine"


class _LedgerEngine:
    """A persistent :class:`~repro.core.eventsim.EventEngine` plus the
    ledger-side bookkeeping (names, fold cursors) to turn its state back
    into :class:`CommittedWork` records."""

    def __init__(self, ledger: CommittedWork, mu_node: np.ndarray,
                 mu_link: np.ndarray, down: tuple = ()):
        self.eng = eventsim.EventEngine(mu_node, mu_link, clock=ledger.clock)
        self.jobs: list[LedgerJob] = list(ledger.jobs)
        self.names: list[str] = [j.name for j in self.jobs]
        self._live: list[int] = list(range(len(self.jobs)))
        self._folded = 0   # completions already folded into the chain
        self.stamp = 0
        # Failed resources must be marked before indexing: a ready task on
        # one would otherwise be seated at its (zeroed) effective rate.
        for res in down:
            self.eng.remove_resource(res)
        self.eng.add_tasks([_task_of(j) for j in ledger.jobs])

    def commit(self, added: list[LedgerJob]) -> None:
        base = len(self.jobs)
        self.jobs.extend(added)
        self.names.extend(j.name for j in added)
        self._live.extend(range(base, len(self.jobs)))
        self.eng.add_tasks([_task_of(j) for j in added])

    def remove(self, names) -> None:
        """Withdraw live tasks by name (see ``CommittedWork.remove_jobs``)."""
        self.eng.remove_tasks(
            [i for i in self._live
             if self.names[i] in names and not self.eng.tasks[i].done])

    def bloated(self) -> bool:
        """Completed-task shells now outweigh the live set: retaining the
        cache costs more memory than a lazy re-index of the live jobs, so
        the caller should drop it (amortized O(1) work per job — the
        engine would otherwise grow with every job ever served)."""
        dead = len(self.jobs) - len(self._live)
        return dead >= 2048 and dead > len(self._live)

    def fold(self, ledger: CommittedWork, clock: float) -> CommittedWork:
        """New ledger from the engine state — touches only live jobs, and
        reuses each untouched job's record by reference."""
        self.eng.materialize()
        new_done = [(self.names[i], float(t))
                    for i, t in self.eng.completions[self._folded:]]
        self.eng.completions.clear()   # folded into the ledger chain
        self._folded = 0
        live_idx: list[int] = []
        live_jobs: list[LedgerJob] = []
        for i in self._live:
            task = self.eng.tasks[i]
            if task.done:
                continue
            job = self.jobs[i]
            if (task.ptr != job.ptr or task.remaining != job.remaining
                    or task.arrived != job.arrived):
                job = dataclasses.replace(
                    job, ptr=task.ptr,
                    remaining=None if task.remaining is None
                    else float(task.remaining),
                    arrived=float(task.arrived))
                self.jobs[i] = job
            live_idx.append(i)
            live_jobs.append(job)
        self._live = live_idx
        return dataclasses.replace(ledger, clock=float(clock),
                                   jobs=tuple(live_jobs),
                                   completed=ledger.completed
                                   + tuple(new_done))


def _attach(ledger: CommittedWork, eng: _LedgerEngine) -> CommittedWork:
    eng.stamp += 1
    # The blessed stamp-guarded engine cache slot ("the persistent engine
    # cache" above): not a field, never a pytree leaf, and deliberately
    # dropped by dataclasses.replace.
    # repro-lint: disable=RL004 -- stamp-guarded cache slot, not a field
    object.__setattr__(ledger, _ENGINE_SLOT, (eng, eng.stamp))
    return ledger


def _engine_of(ledger: CommittedWork) -> _LedgerEngine | None:
    slot = getattr(ledger, _ENGINE_SLOT, None)
    if slot is None:
        return None
    eng, stamp = slot
    return eng if eng.stamp == stamp else None


def _check_engine(engine: str) -> None:
    if engine not in ("indexed", "ref"):
        raise ValueError(
            f"engine must be 'indexed' or 'ref', got {engine!r}")


def _live_engine(ledger: CommittedWork, mu_node: np.ndarray,
                 mu_link: np.ndarray, down: tuple = ()) -> _LedgerEngine:
    eng = _engine_of(ledger)
    if eng is None:
        eng = _LedgerEngine(ledger, mu_node, mu_link, down)
    return eng


def warm_engine(topo: Topology, ledger: CommittedWork) -> CommittedWork:
    """Attach a live indexed engine to ``ledger`` if it lacks one.

    The engine is otherwise born lazily at the first drain; the exact-mode
    scheduler warms it at commit time instead, so the very first arrival's
    queue materialization already reads the incremental index and every
    later commit extends it in place.
    """
    if _engine_of(ledger) is None:
        mu_node = np.asarray(topo.mu_node, np.float64)
        mu_link = np.asarray(topo.mu_link, np.float64)
        _attach(ledger, _LedgerEngine(ledger, mu_node, mu_link))
    return ledger


def down_keys(topo: Topology, avail_node, link_up=None) -> tuple:
    """Resource keys the event engines must treat as failed.

    Failed nodes, every *existing* link (base mu > 0) incident to one — a
    dead node cannot relay — and explicitly failed links.  The engine-side
    companion of :func:`repro.core.state.effective_topology`'s rate masks.
    """
    avail = np.asarray(avail_node, bool)
    mu_link = np.asarray(topo.mu_link)
    keys: list[tuple] = [("node", int(u)) for u in np.flatnonzero(~avail)]
    bad = ~avail[:, None] | ~avail[None, :]
    if link_up is not None:
        bad |= ~np.asarray(link_up, bool)
    for u, v in zip(*np.nonzero(bad & (mu_link > 0))):
        keys.append(("link", int(u), int(v)))
    return tuple(keys)


def drain_exact(topo: Topology, ledger: CommittedWork, dt, *,
                engine: str = "indexed", down: tuple = ()) -> CommittedWork:
    """Advance the ledger ``dt`` seconds with preempt-resume priority service.

    The exact counterpart of the fluid ``QueueState.advance``: every
    resource serves the highest-priority *ready* work item (precedence
    respected, preempting on arrival, work-conserving), with the same
    semantics as :func:`repro.core.schedule.simulate`.  Draining in chunks
    composes exactly: ``drain_exact(ledger, a)`` then ``b`` equals
    ``drain_exact(ledger, a + b)`` — the property tests assert it.

    ``topo`` is the *effective* topology (straggler-scaled rates apply for
    the whole window, the same piecewise-constant-health approximation the
    fluid drain makes).  Jobs finishing inside the window move to
    ``ledger.completed`` with their completion instants.

    ``engine="indexed"`` (default) runs on the persistent indexed engine —
    the returned ledger carries the live index, so the next drain/commit
    in the chain is incremental.  ``engine="ref"`` rebuilds ``TaskRun``
    records and runs the seed linear-scan loop (the parity reference).

    ``down`` is the authoritative set of resource keys failed *throughout
    this window* (work targeting them waits; served work stays served) —
    typically :func:`down_keys` of the scheduler's availability masks.
    Resources absent from it are restored on the persistent engine.
    """
    _check_engine(engine)
    dt = float(dt)
    if dt < 0:
        raise ValueError(f"dt must be >= 0, got {dt}")
    t_end = ledger.clock + dt
    if dt == 0.0 or not ledger.jobs:
        new = dataclasses.replace(ledger, clock=t_end)
        eng = _engine_of(ledger)
        if eng is not None:     # keep the index in step with the clock
            eng.eng.now = t_end
            _attach(new, eng)
        return new
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    if engine == "ref":
        tasks = _tasks_of(ledger)
        schedule.run_event_loop_ref(tasks, mu_node, mu_link, t=ledger.clock,
                                    t_end=t_end, down=down)
        return _fold(ledger, tasks, t_end)
    eng = _live_engine(ledger, mu_node, mu_link, down)
    try:
        eng.eng.sync(mu_node, mu_link, down)
        eng.eng.advance(t_end)
    except Exception:
        eng.stamp += 1   # poison the cache: rebuilds are always safe
        raise
    new = eng.fold(ledger, t_end)
    return new if eng.bloated() else _attach(new, eng)


def run_to_completion(topo: Topology, ledger: CommittedWork, *,
                      engine: str = "indexed",
                      down: tuple = ()) -> tuple[dict[str, float],
                                                 "CommittedWork"]:
    """Serve every committed job to completion; the ground-truth replay.

    Returns ``({name: absolute completion time} — including jobs already
    completed by earlier drains — , the fully drained ledger)``.  On a
    never-drained commit log this is the full-horizon event simulation of
    the whole arrival history (jobs start at their ``release`` times); on a
    live exact ledger it finishes the residual work — the two must agree,
    which the fidelity benchmark checks.

    ``down`` resources stay failed for the whole run: a job still needing
    one can never complete, so stuck work raises — clear it first
    (recovery policies requeue, migrate, or shed it).
    """
    _check_engine(engine)
    completions = dict(ledger.completed)
    if not ledger.jobs:
        return completions, ledger
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    if engine == "ref":
        tasks = _tasks_of(ledger)
        t = schedule.run_event_loop_ref(tasks, mu_node, mu_link,
                                        t=ledger.clock, down=down)
        out = _fold(ledger, tasks, max(ledger.clock, t))
    else:
        eng = _live_engine(ledger, mu_node, mu_link, down)
        try:
            eng.eng.sync(mu_node, mu_link, down)
            t = eng.eng.advance(np.inf)
        except Exception:
            eng.stamp += 1
            raise
        out = eng.fold(ledger, max(ledger.clock, float(t)))
        if not eng.bloated():
            _attach(out, eng)
    completions.update({name: when for name, when in out.completed})
    return completions, out


def predict_completions(topo: Topology, ledger: CommittedWork, *,
                        extra_plans=(), at: float | None = None,
                        down: tuple = (), horizon: float = np.inf,
                        engine: str = "indexed") -> dict[str, float]:
    """What-if forecast: per-job completion times if no further work arrives.

    Forks the ledger's live simulation (:meth:`~repro.core.eventsim.
    EventEngine.fork` — no ledger re-fold, no index rebuild) and serves the
    fork to quiescence *without committing anything*.  Returns ``{name:
    absolute completion time}`` for every job that finishes by ``horizon``,
    including jobs already completed — exactly what
    :func:`run_to_completion` would report, but leaving the ledger, its
    engine, and the committed state untouched.

    ``extra_plans`` scores uncommitted candidates: an iterable of
    ``(batch, plan)`` or ``(batch, plan, names)`` tuples (the same
    arguments :meth:`CommittedWork.commit` takes), released into the fork
    at ``at`` (default: the ledger clock) at the priorities they *would*
    receive if committed in order.  This is the admission controller's
    scoring primitive: predict a window's completions before deciding to
    commit it.

    Exactness: the fork replays the exact float operations of the live
    chain, so when nothing else arrives the predictions match the realized
    completions bit-for-bit — ``benchmarks/admission_bench.py`` gates on
    it.  ``down`` resources stay failed throughout; with work blocked on
    them an infinite ``horizon`` raises (as :func:`run_to_completion`
    does) — pass a finite horizon to forecast through an outage segment.
    """
    _check_engine(engine)
    at = ledger.clock if at is None else float(at)
    if at < ledger.clock - 1e-9:
        raise ValueError(
            f"cannot score candidates at t={at} behind the ledger clock "
            f"{ledger.clock}")
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    seen = set(ledger.names_seen)
    next_prio = ledger.next_prio
    extras: list[LedgerJob] = []
    for entry in extra_plans:
        batch, plan, names = entry if len(entry) == 3 else (*entry, None)
        extras.extend(_plan_jobs(batch, plan, names=names,
                                 next_prio=next_prio, at=at, seen=seen))
        next_prio += plan.num_jobs
    out = dict(ledger.completed)
    if engine == "ref":
        tasks = _tasks_of(ledger) + [_task_of(j) for j in extras]
        names_all = [j.name for j in ledger.jobs] + [j.name for j in extras]
        if tasks:
            schedule.run_event_loop_ref(tasks, mu_node, mu_link,
                                        t=ledger.clock, t_end=horizon,
                                        down=down)
        for name, task in zip(names_all, tasks):
            if task.done:
                out[name] = float(task.completion)
        return out
    base = _live_engine(ledger, mu_node, mu_link, down)
    if _engine_of(ledger) is None:
        _attach(ledger, base)   # warm the live chain; semantics-neutral
    fork = base.eng.fork()
    fork.sync(mu_node, mu_link, down)
    if at > fork.now:
        fork.advance(at)
    if extras:
        fork.add_tasks([_task_of(j) for j in extras])
    fork.advance(horizon)
    names_all = list(base.names) + [j.name for j in extras]
    for i, t in fork.completions:
        out[names_all[i]] = float(t)
    return out


def replay_piecewise(topo: Topology, log: CommittedWork, *,
                     engine: str = "indexed") -> tuple[dict[str, float],
                                                       "CommittedWork"]:
    """Ground-truth replay honouring the log's recorded health history.

    Drains the log segment by segment between its ``health`` and
    ``removed`` events — each window at the effective topology (and
    resource availability) actually in force — then serves the final
    segment to completion.  With an empty event history this is exactly
    :func:`run_to_completion` on the base topology.  Returns the same
    ``(completions, drained ledger)`` pair.

    Event semantics per key: a node's finite factor is a slowdown (and
    marks it up — recovery records ``1.0``), ``inf`` marks it down along
    with every incident link; a ``("link", u, v)`` key toggles that
    directed link (finite = up, ``inf`` = down).  A removal withdraws the
    named job's residual work at its recorded instant (the fault policy
    requeued/migrated/shed it; a requeue reappears as its own later
    commit).  At equal times health events apply before removals — the
    order the scheduler emits them in.

    The slowdown vector is maintained float32 and applied through
    :func:`repro.core.state.effective_topology` — bit-for-bit the
    scheduler's ``_effective_topology``, so the replay sees the same rates
    the online drains did.
    """
    V = log.num_nodes
    slow = np.ones((V,), np.float32)
    avail = np.ones((V,), bool)
    link_up = np.ones((V, V), bool)

    def _eff_down():
        if avail.all() and link_up.all():
            # pre-fault fast path: bit-identical to the health-only replay
            return effective_topology(topo, slow), ()
        return (effective_topology(topo, slow, avail, link_up),
                down_keys(topo, avail, link_up))

    events = [(float(at), 0, key, factor) for at, key, factor in log.health]
    events += [(float(at), 1, name, 0.0) for at, name in log.removed]
    cur = log
    for at, kind, key, factor in sorted(events, key=lambda e: (e[0], e[1])):
        eff, down = _eff_down()
        cur = drain_exact(eff, cur, max(at - cur.clock, 0.0),
                          engine=engine, down=down)
        if kind == 1:
            # tolerate a job that completed marginally before its removal
            cur = cur.remove_jobs([key], at=at, missing_ok=True,
                                  record=False)
        elif isinstance(key, tuple):
            link_up[key[1], key[2]] = np.isfinite(factor)
        elif np.isfinite(factor):
            slow[int(key)] = factor
            avail[int(key)] = True
        else:
            avail[int(key)] = False
    eff, down = _eff_down()
    return run_to_completion(eff, cur, engine=engine, down=down)


def _backlog_arrays(mu_node: np.ndarray, mu_link: np.ndarray,
                    qn: np.ndarray, ql: np.ndarray) -> float:
    """Worst-resource residual wait from raw numpy arrays (the host-side
    counterpart of :func:`repro.core.state.backlog_seconds`)."""
    node_wait = np.where(mu_node > 0, qn / np.maximum(mu_node, 1e-30), 0.0)
    link_wait = np.where(mu_link > 0, ql / np.maximum(mu_link, 1e-30), 0.0)
    return float(max(node_wait.max(initial=0.0), link_wait.max(initial=0.0)))


def exact_backlog_trace(topo: Topology, log: CommittedWork, times, *,
                        engine: str = "indexed") -> np.ndarray:
    """Exact-model backlog (s) just before each epoch of a commit log.

    Replays the *same plans* the log records — released at their commit
    times — under exact drain semantics, measuring the worst-resource
    residual wait immediately before each ``times[i]`` (jobs committed at
    exactly ``times[i]`` are excluded, matching the online trace's
    ``backlog_before``).  Comparing against the fluid run's backlogs
    isolates the drain semantics: policy decisions are held fixed.

    ``log`` must be an undrained ledger (``track_commits=True`` keeps one).
    The default engine makes this a *single forward pass*: one persistent
    index over the whole horizon, jobs fed in as their releases pass, the
    backlog read from the incrementally maintained queue arrays — the seed
    rebuilt and rescanned the full ledger at every sample time
    (``engine="ref"`` keeps that behaviour as the parity reference).
    """
    _check_engine(engine)
    jobs = sorted(log.jobs, key=lambda j: j.prio)
    if any(j.ptr or j.remaining is not None for j in jobs):
        raise ValueError("exact_backlog_trace needs an undrained commit log")
    if engine == "ref":
        cur = dataclasses.replace(log, jobs=(), completed=())
        out = []
        k = 0
        for t in np.asarray(times, np.float64):
            t = float(t)
            add = []
            while (k < len(jobs)
                   and jobs[k].release < t - schedule.time_eps(t)):
                add.append(jobs[k])
                k += 1
            if add:
                cur = dataclasses.replace(cur, jobs=cur.jobs + tuple(add))
            cur = drain_exact(topo, cur, max(t - cur.clock, 0.0),
                              engine="ref")
            out.append(cur.backlog_seconds(topo))
        return np.asarray(out, np.float64)
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    eng = eventsim.EventEngine(mu_node, mu_link, clock=log.clock)
    out = []
    k = 0
    for t in np.asarray(times, np.float64):
        t = float(t)
        add = []
        while k < len(jobs) and jobs[k].release < t - schedule.time_eps(t):
            add.append(jobs[k])
            k += 1
        if add:
            eng.add_tasks([_task_of(j) for j in add])
        eng.advance(max(t, eng.now))
        qn, ql = eng.queue_arrays()
        out.append(_backlog_arrays(mu_node, mu_link, qn, ql))
    return np.asarray(out, np.float64)
