"""Per-plan committed-work ledger + exact (event-accurate) queue drain.

The fluid drain (:meth:`repro.core.state.QueueState.advance`) serves every
resource independently at full rate: ``q <- max(q - mu * dt, 0)``.  That is
the *most optimistic* work-conserving service model — it drains link bytes
for layers whose producing compute hasn't finished, and node FLOPs out of
priority order.  The paper's queues Q charge waiting time against
*committed work* served by a preempt-resume priority system (the model
``core.schedule.simulate`` implements exactly), so fluid-drained backlogs —
and every latency bound evaluated against them — are systematically
optimistic.

:class:`CommittedWork` closes that gap.  It is the host-side companion to
the :class:`~repro.core.state.QueueState` pytree: a ledger recording, per
committed plan, each job's per-resource work items with its global priority
and precedence (layer k's transfer cannot drain before layer k's compute
completes — the stage order of :func:`repro.core.schedule.job_stages`).
:func:`drain_exact` advances the ledger with the same preempt-resume
semantics as the one-shot simulator, a ``dt`` window at a time,
incrementally between online arrivals.  The ledger is deliberately *not* a
JAX pytree leaf container: the event loop is data-dependent control flow
that belongs on the host; only the residual per-resource work it implies
(:meth:`CommittedWork.queue_arrays`) is materialized back into the jitted
``QueueState`` the solvers consume.

All ledger operations are functional (they return new ledgers and never
mutate tasks in place), so a scheduler can snapshot a ledger by reference —
``replan_last``'s rollback does exactly that.

Two engines drive the drain (``engine="indexed" | "ref"`` on every entry
point).  The default is the persistent indexed engine
(:mod:`repro.core.eventsim`): each drained/committed ledger carries a
*cache slot* pointing at the live engine, so consecutive windows reuse the
indexes instead of rebuilding every ``TaskRun`` per arrival.  The slot is
stamp-guarded and strictly linear — draining a ledger hands the engine to
the *result* ledger and invalidates the input's slot, so an old snapshot
(``replan_last``'s rollback, a branched what-if drain) simply rebuilds
lazily from its immutable job records.  ``engine="ref"`` runs the seed
linear-scan loop (:func:`repro.core.schedule.run_event_loop_ref`) — the
parity reference ``benchmarks/drain_bench.py`` gates against.

``health`` records ``report_slowdown`` events ``(time, node, factor)`` on
the same log, so :func:`replay_piecewise` can replay the ground truth
segment by segment at the topology that was actually in effect — not a
single end-state topology for the whole horizon.

Priorities are ledger-global: plans committed earlier hold strictly higher
priority than later ones (each batch was solved against the queue state its
predecessors built), and within a plan jobs keep their solver-assigned
order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import eventsim, schedule
from .state import QueueState, Topology


@dataclasses.dataclass(frozen=True)
class LedgerJob:
    """One committed job's work items and drain progress."""

    name: str
    prio: int                  # ledger-global priority (0 = served first)
    release: float             # absolute commit/arrival time (s)
    stages: tuple[schedule.Stage, ...]  # (resource, work) in precedence order
    ptr: int = 0               # completed-stage count
    remaining: float | None = None      # residual work of the current stage
    arrived: float = 0.0       # instant the job became ready at this stage

    @property
    def finished(self) -> bool:
        return self.ptr >= len(self.stages)


@dataclasses.dataclass(frozen=True)
class CommittedWork:
    """Ledger of committed-but-unfinished work across all committed plans.

    ``jobs`` holds live (unfinished) jobs; ``completed`` accumulates
    ``(name, absolute completion time)`` pairs as drains finish jobs.
    ``clock`` is the absolute time the ledger has been drained to — a
    never-drained ledger (a pure commit *log*) keeps its initial clock, and
    its jobs' ``release`` times drive the full-horizon replay instead.
    """

    num_nodes: int
    clock: float = 0.0
    jobs: tuple[LedgerJob, ...] = ()
    completed: tuple[tuple[str, float], ...] = ()
    next_prio: int = 0
    # Completion records are keyed by job name, so names must be unique for
    # the lifetime of the ledger; commit() enforces it against this set.
    names_seen: frozenset[str] = frozenset()
    # Health history: (absolute time, node, slowdown factor) events, in
    # record order.  A pure annotation — drains ignore it (the caller picks
    # the effective topology per window); replay_piecewise() consumes it.
    health: tuple[tuple[float, int, float], ...] = ()

    @classmethod
    def empty(cls, num_nodes: int, clock: float = 0.0) -> "CommittedWork":
        return cls(num_nodes=int(num_nodes), clock=float(clock))

    def record_slowdown(self, at: float, node: int,
                        factor: float) -> "CommittedWork":
        """Annotate the log with a health event (``factor=2`` = half speed,
        the scheduler's convention); replay_piecewise() replays segment by
        segment at the recorded factors."""
        return dataclasses.replace(
            self, health=self.health + ((float(at), int(node),
                                         float(factor)),))

    # -- committing plans -----------------------------------------------------
    def commit(self, batch, plan, *, names=None,
               at: float | None = None) -> "CommittedWork":
        """Append one work item per job of a solved plan, released at ``at``.

        The plan must carry explicit transfer paths (``plan.paths``, filled
        by ``Plan.replay`` or ``schedule.replay_solution`` against the queue
        state the plan was solved for); the ledger charges each layer's
        bytes to exactly the hops the plan routed them over.  ``names`` (one
        per job, batch order) key the completion records, so they must be
        unique over the ledger's lifetime (a duplicate would silently
        overwrite an earlier job's completion time) — a repeat raises
        ``ValueError``; defaults to ``p<prio>``, unique by construction.
        The ledger clock is *not* moved — commits are events, drains move
        time.
        """
        at = self.clock if at is None else float(at)
        if at < self.clock - 1e-9:
            raise ValueError(
                f"cannot commit at t={at} behind the ledger clock {self.clock}")
        if plan.paths is None:
            raise ValueError(
                "plan must carry explicit paths to be committed to the "
                "ledger; derive them with plan.replay(net, batch) or "
                "schedule.replay_solution against the solve-time queue state")
        stages = schedule.job_stages(batch, plan.assign, plan.paths)
        order = plan.order
        jobs = list(self.jobs)
        added: list[LedgerJob] = []
        seen = set(self.names_seen)
        for slot in range(plan.num_jobs):
            j = int(order[slot])
            prio = self.next_prio + slot
            name = names[j] if names is not None else f"p{prio}"
            if name in seen:
                raise ValueError(
                    f"duplicate job name {name!r}: completion tracking keys "
                    f"on job names, which must be unique per ledger — give "
                    f"requests/jobs distinct names")
            seen.add(name)
            added.append(LedgerJob(name=name, prio=prio, release=at,
                                   stages=tuple(stages[j]), arrived=at))
        jobs.extend(added)
        new = dataclasses.replace(
            self, jobs=tuple(jobs), next_prio=self.next_prio + plan.num_jobs,
            names_seen=frozenset(seen))
        eng = _engine_of(self)
        if eng is not None:
            try:
                eng.commit(added)  # extend the live index in place
            except Exception:
                eng.stamp += 1     # poison the half-extended index
                raise
            _attach(new, eng)
        return new

    def cleared(self) -> "CommittedWork":
        """Drop all live jobs without recording completions (a scheduler's
        hard reset — see ``RoutedScheduler.drain``)."""
        return dataclasses.replace(self, jobs=())

    # -- materializing state --------------------------------------------------
    def queue_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """Residual committed work per resource: (q_node [V], q_link [V, V]).

        The exact-model counterpart of the fluid backlogs: the current
        stage's residual plus every not-yet-started stage of every live
        job, charged to its resource.  float32, ready for
        ``QueueState.with_queues``.  A ledger carrying a live engine reads
        the incrementally maintained arrays (O(V^2), no job rescan).
        """
        eng = _engine_of(self)
        if eng is not None:
            qn, ql = eng.eng.queue_arrays()
            return qn.astype(np.float32), ql.astype(np.float32)
        qn = np.zeros((self.num_nodes,), np.float64)
        ql = np.zeros((self.num_nodes, self.num_nodes), np.float64)
        for job in self.jobs:
            for k in range(job.ptr, len(job.stages)):
                res, work = job.stages[k]
                w = (job.remaining
                     if k == job.ptr and job.remaining is not None else work)
                if res[0] == "node":
                    qn[res[1]] += w
                else:
                    ql[res[1], res[2]] += w
        return qn.astype(np.float32), ql.astype(np.float32)

    def queue_state(self, clock: float | None = None) -> QueueState:
        """Residual work as a :class:`QueueState` (clock defaults to the
        ledger clock)."""
        import jax.numpy as jnp
        qn, ql = self.queue_arrays()
        return QueueState(q_node=jnp.asarray(qn), q_link=jnp.asarray(ql),
                          clock=jnp.float32(self.clock if clock is None
                                            else clock))

    def backlog_seconds(self, topo: Topology) -> float:
        """Worst-resource residual wait under the exact model (see
        :func:`repro.core.state.backlog_seconds`)."""
        from .state import backlog_seconds as _bs
        return _bs(topo, self.queue_state())


def _task_of(job: LedgerJob) -> schedule.TaskRun:
    return schedule.TaskRun(stages=list(job.stages), prio=job.prio,
                            ptr=job.ptr, remaining=job.remaining,
                            arrived=job.arrived)


def _tasks_of(ledger: CommittedWork) -> list[schedule.TaskRun]:
    return [_task_of(job) for job in ledger.jobs]


def _fold(ledger: CommittedWork, tasks: list[schedule.TaskRun],
          clock: float) -> CommittedWork:
    """New ledger from post-loop task states (completions recorded)."""
    live: list[LedgerJob] = []
    done = list(ledger.completed)
    for job, task in zip(ledger.jobs, tasks):
        if task.done:
            done.append((job.name, float(task.completion)))
        else:
            live.append(dataclasses.replace(job, ptr=task.ptr,
                                            remaining=task.remaining,
                                            arrived=task.arrived))
    return dataclasses.replace(ledger, clock=float(clock), jobs=tuple(live),
                               completed=tuple(done))


# -- the persistent engine cache ----------------------------------------------
#
# A drained/committed ledger may carry a live indexed engine in a slot set
# with object.__setattr__ (not a dataclass field: dataclasses.replace()
# must NOT copy it onto unrelated successors, and it never serializes).
# The slot is stamp-guarded: using the engine (drain, commit) hands it to
# the result ledger and bumps the stamp, so every stale snapshot — a
# replan rollback, a branched what-if drain — fails the stamp check and
# lazily rebuilds from its own immutable job records instead.

_ENGINE_SLOT = "_sim_engine"


class _LedgerEngine:
    """A persistent :class:`~repro.core.eventsim.EventEngine` plus the
    ledger-side bookkeeping (names, fold cursors) to turn its state back
    into :class:`CommittedWork` records."""

    def __init__(self, ledger: CommittedWork, mu_node: np.ndarray,
                 mu_link: np.ndarray):
        self.eng = eventsim.EventEngine(mu_node, mu_link, clock=ledger.clock)
        self.jobs: list[LedgerJob] = list(ledger.jobs)
        self.names: list[str] = [j.name for j in self.jobs]
        self._live: list[int] = list(range(len(self.jobs)))
        self._folded = 0   # completions already folded into the chain
        self.stamp = 0
        self.eng.add_tasks([_task_of(j) for j in ledger.jobs])

    def commit(self, added: list[LedgerJob]) -> None:
        base = len(self.jobs)
        self.jobs.extend(added)
        self.names.extend(j.name for j in added)
        self._live.extend(range(base, len(self.jobs)))
        self.eng.add_tasks([_task_of(j) for j in added])

    def bloated(self) -> bool:
        """Completed-task shells now outweigh the live set: retaining the
        cache costs more memory than a lazy re-index of the live jobs, so
        the caller should drop it (amortized O(1) work per job — the
        engine would otherwise grow with every job ever served)."""
        dead = len(self.jobs) - len(self._live)
        return dead >= 2048 and dead > len(self._live)

    def fold(self, ledger: CommittedWork, clock: float) -> CommittedWork:
        """New ledger from the engine state — touches only live jobs, and
        reuses each untouched job's record by reference."""
        self.eng.materialize()
        new_done = [(self.names[i], float(t))
                    for i, t in self.eng.completions[self._folded:]]
        self.eng.completions.clear()   # folded into the ledger chain
        self._folded = 0
        live_idx: list[int] = []
        live_jobs: list[LedgerJob] = []
        for i in self._live:
            task = self.eng.tasks[i]
            if task.done:
                continue
            job = self.jobs[i]
            if (task.ptr != job.ptr or task.remaining != job.remaining
                    or task.arrived != job.arrived):
                job = dataclasses.replace(
                    job, ptr=task.ptr,
                    remaining=None if task.remaining is None
                    else float(task.remaining),
                    arrived=float(task.arrived))
                self.jobs[i] = job
            live_idx.append(i)
            live_jobs.append(job)
        self._live = live_idx
        return dataclasses.replace(ledger, clock=float(clock),
                                   jobs=tuple(live_jobs),
                                   completed=ledger.completed
                                   + tuple(new_done))


def _attach(ledger: CommittedWork, eng: _LedgerEngine) -> CommittedWork:
    eng.stamp += 1
    object.__setattr__(ledger, _ENGINE_SLOT, (eng, eng.stamp))
    return ledger


def _engine_of(ledger: CommittedWork) -> _LedgerEngine | None:
    slot = getattr(ledger, _ENGINE_SLOT, None)
    if slot is None:
        return None
    eng, stamp = slot
    return eng if eng.stamp == stamp else None


def _check_engine(engine: str) -> None:
    if engine not in ("indexed", "ref"):
        raise ValueError(
            f"engine must be 'indexed' or 'ref', got {engine!r}")


def _live_engine(ledger: CommittedWork, mu_node: np.ndarray,
                 mu_link: np.ndarray) -> _LedgerEngine:
    eng = _engine_of(ledger)
    if eng is None:
        eng = _LedgerEngine(ledger, mu_node, mu_link)
    return eng


def warm_engine(topo: Topology, ledger: CommittedWork) -> CommittedWork:
    """Attach a live indexed engine to ``ledger`` if it lacks one.

    The engine is otherwise born lazily at the first drain; the exact-mode
    scheduler warms it at commit time instead, so the very first arrival's
    queue materialization already reads the incremental index and every
    later commit extends it in place.
    """
    if _engine_of(ledger) is None:
        mu_node = np.asarray(topo.mu_node, np.float64)
        mu_link = np.asarray(topo.mu_link, np.float64)
        _attach(ledger, _LedgerEngine(ledger, mu_node, mu_link))
    return ledger


def drain_exact(topo: Topology, ledger: CommittedWork, dt, *,
                engine: str = "indexed") -> CommittedWork:
    """Advance the ledger ``dt`` seconds with preempt-resume priority service.

    The exact counterpart of the fluid ``QueueState.advance``: every
    resource serves the highest-priority *ready* work item (precedence
    respected, preempting on arrival, work-conserving), with the same
    semantics as :func:`repro.core.schedule.simulate`.  Draining in chunks
    composes exactly: ``drain_exact(ledger, a)`` then ``b`` equals
    ``drain_exact(ledger, a + b)`` — the property tests assert it.

    ``topo`` is the *effective* topology (straggler-scaled rates apply for
    the whole window, the same piecewise-constant-health approximation the
    fluid drain makes).  Jobs finishing inside the window move to
    ``ledger.completed`` with their completion instants.

    ``engine="indexed"`` (default) runs on the persistent indexed engine —
    the returned ledger carries the live index, so the next drain/commit
    in the chain is incremental.  ``engine="ref"`` rebuilds ``TaskRun``
    records and runs the seed linear-scan loop (the parity reference).
    """
    _check_engine(engine)
    dt = float(dt)
    if dt < 0:
        raise ValueError(f"dt must be >= 0, got {dt}")
    t_end = ledger.clock + dt
    if dt == 0.0 or not ledger.jobs:
        new = dataclasses.replace(ledger, clock=t_end)
        eng = _engine_of(ledger)
        if eng is not None:     # keep the index in step with the clock
            eng.eng.now = t_end
            _attach(new, eng)
        return new
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    if engine == "ref":
        tasks = _tasks_of(ledger)
        schedule.run_event_loop_ref(tasks, mu_node, mu_link, t=ledger.clock,
                                    t_end=t_end)
        return _fold(ledger, tasks, t_end)
    eng = _live_engine(ledger, mu_node, mu_link)
    try:
        eng.eng.set_rates(mu_node, mu_link)
        eng.eng.advance(t_end)
    except Exception:
        eng.stamp += 1   # poison the cache: rebuilds are always safe
        raise
    new = eng.fold(ledger, t_end)
    return new if eng.bloated() else _attach(new, eng)


def run_to_completion(topo: Topology, ledger: CommittedWork, *,
                      engine: str = "indexed") -> tuple[dict[str, float],
                                                        "CommittedWork"]:
    """Serve every committed job to completion; the ground-truth replay.

    Returns ``({name: absolute completion time} — including jobs already
    completed by earlier drains — , the fully drained ledger)``.  On a
    never-drained commit log this is the full-horizon event simulation of
    the whole arrival history (jobs start at their ``release`` times); on a
    live exact ledger it finishes the residual work — the two must agree,
    which the fidelity benchmark checks.
    """
    _check_engine(engine)
    completions = dict(ledger.completed)
    if not ledger.jobs:
        return completions, ledger
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    if engine == "ref":
        tasks = _tasks_of(ledger)
        t = schedule.run_event_loop_ref(tasks, mu_node, mu_link,
                                        t=ledger.clock)
        out = _fold(ledger, tasks, max(ledger.clock, t))
    else:
        eng = _live_engine(ledger, mu_node, mu_link)
        try:
            eng.eng.set_rates(mu_node, mu_link)
            t = eng.eng.advance(np.inf)
        except Exception:
            eng.stamp += 1
            raise
        out = eng.fold(ledger, max(ledger.clock, float(t)))
        if not eng.bloated():
            _attach(out, eng)
    completions.update({name: when for name, when in out.completed})
    return completions, out


def replay_piecewise(topo: Topology, log: CommittedWork, *,
                     engine: str = "indexed") -> tuple[dict[str, float],
                                                       "CommittedWork"]:
    """Ground-truth replay honouring the log's recorded health history.

    Drains the log segment by segment between its ``health`` events — each
    window at the effective (straggler-scaled) topology that was actually
    in force — then serves the final segment to completion.  With an empty
    health log this is exactly :func:`run_to_completion` on the base
    topology.  Returns the same ``(completions, drained ledger)`` pair.

    The slowdown vector is maintained float32 and applied as
    ``topo.scale_nodes(1 / factors)`` — bit-for-bit the scheduler's
    ``_effective_topology``, so the replay sees the same rates the online
    drains did.
    """
    import jax.numpy as jnp

    slow = np.ones((log.num_nodes,), np.float32)
    cur = log
    for at, node, factor in sorted(log.health, key=lambda e: e[0]):
        eff = topo.scale_nodes(1.0 / jnp.asarray(slow))
        cur = drain_exact(eff, cur, max(float(at) - cur.clock, 0.0),
                          engine=engine)
        slow[int(node)] = factor
    eff = topo.scale_nodes(1.0 / jnp.asarray(slow))
    return run_to_completion(eff, cur, engine=engine)


def _backlog_arrays(mu_node: np.ndarray, mu_link: np.ndarray,
                    qn: np.ndarray, ql: np.ndarray) -> float:
    """Worst-resource residual wait from raw numpy arrays (the host-side
    counterpart of :func:`repro.core.state.backlog_seconds`)."""
    node_wait = np.where(mu_node > 0, qn / np.maximum(mu_node, 1e-30), 0.0)
    link_wait = np.where(mu_link > 0, ql / np.maximum(mu_link, 1e-30), 0.0)
    return float(max(node_wait.max(initial=0.0), link_wait.max(initial=0.0)))


def exact_backlog_trace(topo: Topology, log: CommittedWork, times, *,
                        engine: str = "indexed") -> np.ndarray:
    """Exact-model backlog (s) just before each epoch of a commit log.

    Replays the *same plans* the log records — released at their commit
    times — under exact drain semantics, measuring the worst-resource
    residual wait immediately before each ``times[i]`` (jobs committed at
    exactly ``times[i]`` are excluded, matching the online trace's
    ``backlog_before``).  Comparing against the fluid run's backlogs
    isolates the drain semantics: policy decisions are held fixed.

    ``log`` must be an undrained ledger (``track_commits=True`` keeps one).
    The default engine makes this a *single forward pass*: one persistent
    index over the whole horizon, jobs fed in as their releases pass, the
    backlog read from the incrementally maintained queue arrays — the seed
    rebuilt and rescanned the full ledger at every sample time
    (``engine="ref"`` keeps that behaviour as the parity reference).
    """
    _check_engine(engine)
    jobs = sorted(log.jobs, key=lambda j: j.prio)
    if any(j.ptr or j.remaining is not None for j in jobs):
        raise ValueError("exact_backlog_trace needs an undrained commit log")
    if engine == "ref":
        cur = dataclasses.replace(log, jobs=(), completed=())
        out = []
        k = 0
        for t in np.asarray(times, np.float64):
            t = float(t)
            add = []
            while (k < len(jobs)
                   and jobs[k].release < t - schedule.time_eps(t)):
                add.append(jobs[k])
                k += 1
            if add:
                cur = dataclasses.replace(cur, jobs=cur.jobs + tuple(add))
            cur = drain_exact(topo, cur, max(t - cur.clock, 0.0),
                              engine="ref")
            out.append(cur.backlog_seconds(topo))
        return np.asarray(out, np.float64)
    mu_node = np.asarray(topo.mu_node, np.float64)
    mu_link = np.asarray(topo.mu_link, np.float64)
    eng = eventsim.EventEngine(mu_node, mu_link, clock=log.clock)
    out = []
    k = 0
    for t in np.asarray(times, np.float64):
        t = float(t)
        add = []
        while k < len(jobs) and jobs[k].release < t - schedule.time_eps(t):
            add.append(jobs[k])
            k += 1
        if add:
            eng.add_tasks([_task_of(j) for j in add])
        eng.advance(max(t, eng.now))
        qn, ql = eng.queue_arrays()
        out.append(_backlog_arrays(mu_node, mu_link, qn, ql))
    return np.asarray(out, np.float64)
