"""Single-job optimal routing on the layered graph (constructive Theorem 1).

The paper proves the single-job ILP (1)-(5) is totally unimodular, hence its
LP relaxation is integral and the optimum is a single s_0 -> t_L path in the
layered graph.  We compute that optimum directly with a layer dynamic
program over min-plus transfer closures:

    g_0[u]  = T_0[src, u] + nw[u]
    g_l[u]  = min( g_{l-1}[u],                       # continue the run at u
                   min_v g_{l-1}[v] + T_{l-1}[v, u]  # move, charge node wait
                       + nw[u] )
              + c_l * cinv[u]
    answer  = min_u g_L[u] + T_L[u, dst]

where T_l is the min-cost transfer closure for layer-l output (see
``shortest_path.transfer_closure``), ``nw[u] = Q_u / mu_u`` the node waiting
bound and ``cinv[u] = 1/mu_u``.  Moving into a node charges its waiting
term; continuing a consecutive run does not — this mirrors the ILP's z_u
(charged once per node).  The two objectives can differ only if the optimum
*returns* to a node for a non-adjacent layer (then the DP charges the wait
twice); ``exact.py`` provides a bitmask-exact oracle and the property tests
quantify the gap (zero on all randomized instances tried).  Spuriously
dominated candidates inside the min (e.g. a "move" from v == u) are never
uniquely optimal by the triangle inequality of the closure, so the DP value
is the optimum of its objective.

Everything is shape-static (Lmax padding, masks) => jit- and vmap-able; the
multi-job greedy vmaps :func:`route_single` over the job batch.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .network import INF, ComputeNetwork, node_invrate, node_wait
from .jobs import JobBatch
from .shortest_path import (Closures, closures_for, layer_edge_weights,
                            transfer_closure, reconstruct_path)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Route:
    cost: jax.Array        # scalar: upper bound on this job's completion time
    assign: jax.Array      # [Lmax] int32: compute node of each (real) layer


def _dp_fwd(t: jax.Array, comp: jax.Array, src: jax.Array, dst: jax.Array,
            num_layers: jax.Array, cinv: jax.Array, nw: jax.Array):
    """Forward half of the layer DP: cost + the backpointer tables.

    t: [Lmax+1, V, V]; comp: [Lmax]; cinv/nw: [V].  Returns
    ``(cost, total [V], bps [Lmax, V])`` — everything vectorized; the
    sequential per-job backpointer walk lives in :func:`_dp_back` so
    callers that only need the winning job's assignment (the fused greedy
    round: J forward DPs, ONE committed job) can skip J-1 walks.
    """
    lmax = comp.shape[0]
    g0 = t[0, src, :] + nw
    layer_ids = jnp.arange(1, lmax + 1)

    def step(g, xs):
        l, c_l, t_prev = xs
        active = l <= num_layers
        move = jnp.min(g[:, None] + t_prev, axis=0)          # [V]
        move_bp = jnp.argmin(g[:, None] + t_prev, axis=0)    # [V]
        moved = move + nw
        stay_wins = g <= moved
        # Golden-locked DP recurrence with no fused form (the mul adds to a
        # min, not a sum); the forward scan is never unrolled, and fused &
        # ref solvers trace this same function, so its rounding is common
        # to both sides of the parity gate.
        # repro-lint: disable=RL001 -- no fused form; rounding is shared
        new_g = jnp.minimum(g, moved) + c_l * cinv
        new_g = jnp.minimum(new_g, INF)
        bp = jnp.where(stay_wins, -1, move_bp).astype(jnp.int32)
        g_out = jnp.where(active, new_g, g)
        bp_out = jnp.where(active, bp, jnp.full_like(bp, -1))
        return g_out, bp_out

    g_final, bps = jax.lax.scan(step, g0, (layer_ids, comp, t[:-1]))
    t_last = jnp.take(t, num_layers, axis=0)                  # [V, V]
    total = g_final + t_last[:, dst]
    return jnp.minimum(jnp.min(total), INF), total, bps


def _dp_back(total: jax.Array, bps: jax.Array) -> jax.Array:
    """Walk backpointers Lmax..1 to recover the compute node of each layer.

    Integer gathers only — bit-identity with the full DP's assignment is
    structural, not a float-rounding question (which also makes the
    ``unroll`` safe: there is no float mul-add for LLVM to re-contract,
    so the unrolled loop is the same gather chain with less XLA:CPU
    loop machinery).  Lint rule RL002 (unsafe-unroll) admits exactly this
    kind of body — the *forward* DP must never unroll (RL001's pragma in
    ``_dp_fwd`` documents why)."""
    u_star = jnp.argmin(total).astype(jnp.int32)

    def back(cur, bp_l):
        prev = jnp.where(bp_l[cur] < 0, cur, bp_l[cur])
        return prev, cur

    _, assign_rev = jax.lax.scan(back, u_star, bps, reverse=True, unroll=8)
    return assign_rev


def _dp(t: jax.Array, comp: jax.Array, src: jax.Array, dst: jax.Array,
        num_layers: jax.Array, cinv: jax.Array, nw: jax.Array) -> Route:
    """Run the layer DP given the per-layer transfer closures ``t``.

    t: [Lmax+1, V, V]; comp: [Lmax]; cinv/nw: [V].
    """
    cost, total, bps = _dp_fwd(t, comp, src, dst, num_layers, cinv, nw)
    return Route(cost=cost, assign=_dp_back(total, bps))


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def route_single(net: ComputeNetwork, comp: jax.Array, data: jax.Array,
                 src: jax.Array, dst: jax.Array, num_layers: jax.Array,
                 *, closures: Closures | None = None,
                 use_pallas: bool | None = None) -> Route:
    """Optimally route one job (paper formulation (1)-(5)) given queues in ``net``.

    ``closures`` (if given) must have been built against this same
    (net, data) — pass it to share one closure stack across routing, commit,
    and path extraction instead of rebuilding it here.
    """
    if closures is None:
        closures = closures_for(net, data, use_pallas=use_pallas)
    return _dp(closures.t, comp, src, dst, num_layers, node_invrate(net),
               node_wait(net))


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def route_batch(net: ComputeNetwork, batch: JobBatch,
                *, closures: Closures | None = None,
                use_pallas: bool | None = None) -> Route:
    """vmap of :func:`route_single` over a padded job batch (shared queues).

    ``closures``: optional [J, ...]-stacked artifact from
    ``shortest_path.build_closures_batch`` (vmapped through per job).
    """
    fn = lambda c, d, s, t_, n, cl: route_single(
        net, c, d, s, t_, n, closures=cl, use_pallas=use_pallas)
    return jax.vmap(fn)(batch.comp, batch.data, batch.src, batch.dst,
                        batch.num_layers, closures)


def route_batch_fwd(net: ComputeNetwork, batch: JobBatch,
                    *, closures: Closures):
    """Forward-only :func:`route_batch`: costs + backpointer tables.

    Returns ``(cost [J], total [J, V], bps [J, Lmax, V])``.  The per-job
    backpointer *walk* (a sequential chain of scalar gathers — the only
    non-vectorizable piece of the DP) is deferred to
    :func:`assign_from_backpointers`, so a caller that commits a single
    job per round recovers exactly one assignment instead of J.
    """
    cinv, nw = node_invrate(net), node_wait(net)
    return jax.vmap(
        lambda c, s, t_, n, cl: _dp_fwd(cl.t, c, s, t_, n, cinv, nw)
    )(batch.comp, batch.src, batch.dst, batch.num_layers, closures)


def assign_from_backpointers(total: jax.Array, bps: jax.Array) -> jax.Array:
    """One job's [Lmax] assignment from its :func:`route_batch_fwd` row —
    bit-identical to the corresponding ``route_batch(...).assign`` row."""
    return _dp_back(total, bps)


@jax.jit
def cost_given_assignment(net: ComputeNetwork, comp: jax.Array, data: jax.Array,
                          src: jax.Array, dst: jax.Array, num_layers: jax.Array,
                          assign: jax.Array,
                          *, closures: Closures | None = None) -> jax.Array:
    """Objective (1) for a *fixed* compute-node assignment (paths free).

    Transfers between consecutive compute nodes take min-cost paths under the
    current queues; node waits are charged once per consecutive run.  Used by
    the simulated-annealing evaluator.
    """
    t = transfer_closure(net, data) if closures is None else closures.t
    cinv = node_invrate(net)
    nw = node_wait(net)
    lmax = comp.shape[0]

    a1 = assign[0]
    # repro-lint: disable=RL001 -- mirrors _dp_fwd's rounding term-for-term
    cost0 = t[0, src, a1] + nw[a1] + comp[0] * cinv[a1]

    def step(carry, xs):
        total, prev = carry
        l, c_l = xs                      # l in 2..Lmax, layer l at assign[l-1]
        cur = assign[l - 1]
        active = l <= num_layers
        # repro-lint: disable=RL001 -- mirrors _dp_fwd's rounding (as cost0)
        seg = t[l - 1, prev, cur] + jnp.where(cur == prev, 0.0, nw[cur]) \
            + c_l * cinv[cur]
        total = jnp.where(active, total + seg, total)
        prev = jnp.where(active, cur, prev)
        return (total, prev), None

    (total, last), _ = jax.lax.scan(
        step, (cost0, a1), (jnp.arange(2, lmax + 1), comp[1:]))
    t_last = jnp.take(t, num_layers, axis=0)
    return jnp.minimum(total + t_last[last, dst], INF)


def _commit_impl(net: ComputeNetwork, comp: jax.Array, data: jax.Array,
                 src: jax.Array, dst: jax.Array, num_layers: jax.Array,
                 assign: jax.Array, closures: Closures | None,
                 ) -> tuple[ComputeNetwork, jax.Array]:
    """Shared commit body; also returns the per-layer hop lists it charged.

    The hops come out of the *same* ``reconstruct_path`` calls inside the
    same per-layer scan that charges q_link, so emitting them changes no
    arithmetic — :func:`commit_assignment` discards them,
    :func:`commit_with_hops` hands them to callers that want
    ``plan.paths`` without a second extraction pass.
    """
    v = net.num_nodes
    if closures is None:
        closures = closures_for(net, data)
    t = closures.t                              # [Lmax+1, V, V]
    w = (layer_edge_weights(net, data) if closures.w is None
         else closures.w)                       # cheap when absent
    lmax = comp.shape[0]

    q_node = net.q_node
    for_l = jnp.arange(lmax + 1)
    # endpoints of the layer-l transfer: node_l -> node_{l+1} with node_0 =
    # src and node_{num_layers+1} = dst; layers beyond num_layers are masked.
    src32 = jnp.asarray(src, jnp.int32).reshape(1)
    dst32 = jnp.asarray(dst, jnp.int32)
    starts = jnp.concatenate([src32, assign]).astype(jnp.int32)   # node_l
    ends = jnp.concatenate([assign, dst32.reshape(1)]).astype(jnp.int32)
    ends = jnp.where(for_l == num_layers, dst32, ends)

    q_node = q_node + jnp.zeros_like(q_node).at[assign].add(
        jnp.where(jnp.arange(lmax) < num_layers, comp, 0.0))

    # Reconstruct all L+1 layer paths in one vmapped walk (the per-layer
    # walks are independent given (w, t)); the q_link charges then replay
    # layer-by-layer in the same scan order as before, so the accumulated
    # floats are bitwise identical to the per-layer sequential version.
    hops = jax.vmap(
        lambda wl, tl, a, bb: reconstruct_path(wl, tl, a, bb, max_hops=v)
    )(w, t, starts, ends)                       # [Lmax+1, V, 2]

    def add_layer(ql, xs):
        l, hops_l = xs
        active = l <= num_layers
        d_l = data[l]
        us, vs = hops_l[:, 0], hops_l[:, 1]
        valid = (us >= 0) & active & (us != vs)
        add = jnp.where(valid, d_l, 0.0)
        ql = ql.at[jnp.maximum(us, 0), jnp.maximum(vs, 0)].add(add)
        return ql, None

    # unroll=4: tiny per-layer bodies, same sequential charge order (and
    # therefore bitwise-identical accumulation) with less loop overhead.
    q_link, _ = jax.lax.scan(add_layer, net.q_link, (for_l, hops), unroll=4)
    return net.with_queues(q_node, q_link), hops


@jax.jit
def commit_assignment(net: ComputeNetwork, comp: jax.Array, data: jax.Array,
                      src: jax.Array, dst: jax.Array, num_layers: jax.Array,
                      assign: jax.Array,
                      *, closures: Closures | None = None) -> ComputeNetwork:
    """Algorithm 1 line 3: add the routed job's load to the queues.

    q_node[a_l] += c_l for each real layer l; q_link[u, v] += d_l for every
    hop of the min-cost path carrying layer-l output (l = 0..L, with node_0 =
    src and node_{L+1} = dst).  Pass ``closures`` to reuse the caller's
    (w, t) stack instead of recomputing both here.
    """
    net2, _ = _commit_impl(net, comp, data, src, dst, num_layers, assign,
                           closures)
    return net2


def commit_with_hops(net: ComputeNetwork, comp: jax.Array, data: jax.Array,
                     src: jax.Array, dst: jax.Array, num_layers: jax.Array,
                     assign: jax.Array,
                     *, closures: Closures | None = None,
                     ) -> tuple[ComputeNetwork, jax.Array]:
    """:func:`commit_assignment` that also returns its hop lists.

    ``hops`` is [Lmax+1, V, 2] int32 — for each layer the explicit (u, v)
    transfer hops the commit charged, padded with (-1, -1); exactly the
    rows :func:`reconstruct_path` walks, so formatting them with
    :func:`hops_to_paths` reproduces :func:`extract_paths` without a
    second reconstruction.  Not jitted here: the fused solver traces it
    inside its own program (jitting at this level would just add a
    dispatch for eager callers, who should prefer ``commit_assignment``).
    """
    return _commit_impl(net, comp, data, src, dst, num_layers, assign,
                        closures)


def hops_to_paths(hops, num_layers: int) -> list:
    """Format a concrete [Lmax+1, V, 2] hop tensor as ``plan.paths`` lists.

    Matches :func:`extract_paths` output exactly: one list of (u, v) int
    tuples per real layer 0..num_layers, truncated at the first (-1, -1)
    padding row.  One vectorized hop count, then ``tolist`` on the sliced
    *real* hops only — real paths are a few hops while the buffer holds V
    rows of mostly (-1, -1) padding, and the fused solver formats every
    layer of every round through here, so converting the padding to
    Python ints was a measurable slice of its path post-pass.
    """
    import numpy as np
    live = np.asarray(hops)[:int(num_layers) + 1]
    n_real = (live[:, :, 0] >= 0).sum(1).tolist()
    return [list(map(tuple, live[l, :n].tolist()))
            for l, n in enumerate(n_real)]


@functools.partial(jax.jit, static_argnames=("max_hops",))
def _paths_device(w: jax.Array, t: jax.Array, starts: jax.Array,
                  ends: jax.Array, *, max_hops: int) -> jax.Array:
    """vmap of :func:`reconstruct_path` over the layer axis -> [L+1, max_hops, 2]."""
    fn = functools.partial(reconstruct_path, max_hops=max_hops)
    return jax.vmap(fn)(w, t, starts, ends)


def extract_paths(net: ComputeNetwork, comp, data, src, dst, num_layers,
                  assign, *, closures: Closures | None = None):
    """Host-side helper: explicit per-layer hop lists for the event simulator.

    One vmapped ``reconstruct_path`` over all L+1 layers and a single
    ``device_get`` (the seed's per-hop host loop is kept as
    :func:`extract_paths_ref` for parity testing).
    """
    import numpy as np
    v = net.num_nodes
    if closures is None:
        closures = closures_for(net, data)
    w = (layer_edge_weights(net, data) if closures.w is None
         else closures.w)
    L = int(num_layers)
    assign_h = np.asarray(jax.device_get(assign))
    nodes = np.array([int(src)] + [int(assign_h[l]) for l in range(L)]
                     + [int(dst)], np.int32)
    hops = jax.device_get(_paths_device(
        w[: L + 1], closures.t[: L + 1],
        jnp.asarray(nodes[:-1]), jnp.asarray(nodes[1:]), max_hops=v))
    paths = []
    for l in range(L + 1):
        layer = hops[l]
        n_real = int((layer[:, 0] >= 0).sum())
        paths.append([(int(u), int(vv)) for u, vv in layer[:n_real]])
    return paths


def extract_paths_ref(net: ComputeNetwork, comp, data, src, dst, num_layers,
                      assign):
    """Reference per-hop host loop (seed implementation) for parity tests."""
    import numpy as np
    v = net.num_nodes
    w = jax.device_get(layer_edge_weights(net, data))
    t = jax.device_get(transfer_closure(net, data))
    assign = np.asarray(jax.device_get(assign))
    L = int(num_layers)
    nodes = [int(src)] + [int(assign[l]) for l in range(L)] + [int(dst)]
    paths = []
    for l in range(L + 1):
        a, b = nodes[l], nodes[l + 1]
        hops = []
        cur = a
        for _ in range(v):
            if cur == b:
                break
            cand = w[l][cur] + t[l][:, b]
            cand[cur] = np.inf  # never take the zero-cost self-loop
            nxt = int(np.argmin(cand))
            hops.append((cur, nxt))
            cur = nxt
        paths.append(hops)
    return paths
