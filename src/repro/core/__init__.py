# The paper's primary contribution: routing DNN inference jobs over a
# distributed computing network via the layered-graph model (§III) and the
# greedy / simulated-annealing algorithms (§IV), implemented as composable
# JAX modules (jit/vmap/lax throughout; min-plus closures back onto the
# Pallas tropical-matmul kernel in repro.kernels).
from .network import (ComputeNetwork, INF, make_network, small_topology,
                      us_backbone)
from .state import (QueueState, Topology, advance, backlog_seconds,
                    total_backlog)
from .jobs import InferenceJob, JobBatch, batch_jobs, synthetic_job
from . import arrivals
from .routing import (Route, route_single, route_batch,
                      cost_given_assignment, commit_assignment)
from .shortest_path import (Closures, build_closures, build_closures_batch,
                            closure_build_count, reset_closure_build_count)
from .plan import Plan
from .solvers import Solver, solve, register as register_solver, \
    available as available_solvers
from .greedy import GreedySolution, greedy_route
from .annealing import SAResult, anneal, evaluate_solution
from .schedule import SimResult, replay_solution, simulate
from .eventsim import EventEngine
from .completions import (CommittedWork, LedgerJob, drain_exact,
                          exact_backlog_trace, replay_piecewise,
                          run_to_completion)
from . import (bounds, completions, eventsim, exact, layered_graph,
               shortest_path, solvers)

__all__ = [
    "ComputeNetwork", "INF", "make_network", "small_topology", "us_backbone",
    "Topology", "QueueState", "advance", "backlog_seconds", "total_backlog",
    "arrivals",
    "InferenceJob", "JobBatch", "batch_jobs", "synthetic_job",
    "Route", "route_single", "route_batch", "cost_given_assignment",
    "commit_assignment",
    "Closures", "build_closures", "build_closures_batch",
    "closure_build_count", "reset_closure_build_count",
    "Plan", "Solver", "solve", "register_solver", "available_solvers",
    "GreedySolution", "greedy_route",  # deprecated alias + legacy name
    "SAResult", "anneal", "evaluate_solution",
    "SimResult", "replay_solution", "simulate", "EventEngine",
    "CommittedWork", "LedgerJob", "drain_exact", "exact_backlog_trace",
    "replay_piecewise", "run_to_completion",
    "bounds", "completions", "eventsim", "exact", "layered_graph",
    "shortest_path", "solvers",
]
