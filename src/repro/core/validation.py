"""Shared host-side constructor validation helpers."""
from __future__ import annotations

import numpy as np


def check_finite_nonneg(name: str, arr: np.ndarray) -> None:
    """Raise ``ValueError`` naming ``name`` if ``arr`` has NaN/inf or < 0."""
    if not np.isfinite(arr).all():
        raise ValueError(f"{name} contains non-finite values (NaN/inf)")
    if (arr < 0).any():
        raise ValueError(f"{name} contains negative values")
