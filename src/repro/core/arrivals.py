"""Arrival processes: request batches arriving on a clock.

The online serving loop (``repro.serving.online``) drains the network's
:class:`~repro.core.state.QueueState` to each arrival time before solving.
This module generates the arrival clocks.  Every process is a host-side
generator of sorted timestamps in ``[0, horizon)`` seconds:

  * :func:`poisson_times` — homogeneous Poisson with rate ``rate`` (1/s):
    the memoryless baseline every stability argument is phrased against.
  * :func:`bursty_times` — compound/batch Poisson: burst *epochs* arrive
    Poisson at ``rate / burst_size`` and each epoch carries ``burst_size``
    arrivals jittered ``within`` seconds apart, so the long-run rate is
    ``rate`` but the short-run load is spiky.
  * :func:`diurnal_times` — nonhomogeneous Poisson via thinning with a
    sinusoidal rate  lam(t) = base + (peak - base) * (1 - cos(2 pi t /
    period)) / 2  — a traffic "day" ramping from ``base_rate`` at t=0 to
    ``peak_rate`` at mid-period and back.

``make_process(name, **params)`` returns a ``(rng, horizon) -> times``
callable from a string name, so scenarios and benchmarks can pick a
process the same way they pick a solver.  :func:`stream_times` is the
*iterator view* over the same processes — the shape the streaming serving
pipeline (:mod:`repro.serving.stream`) consumes arrivals in, one at a
time, with an optional chunked mode so very long horizons never
materialize a full timestamp array.
"""
from __future__ import annotations

from typing import Callable, Iterator, Protocol

import numpy as np

ArrivalFn = Callable[[np.random.Generator, float], np.ndarray]


class ArrivalProcess(Protocol):
    """(rng, horizon seconds) -> sorted float64 arrival times in [0, horizon)."""

    def __call__(self, rng: np.random.Generator, horizon: float) -> np.ndarray:
        ...


def poisson_times(rng: np.random.Generator, rate: float,
                  horizon: float) -> np.ndarray:
    """Homogeneous Poisson arrivals: i.i.d. Exp(rate) gaps."""
    if rate <= 0:
        return np.zeros((0,), np.float64)
    # Draw ~horizon*rate + slack gaps in one shot, keep the prefix in range.
    n = max(8, int(horizon * rate * 1.5) + 8)
    times = np.cumsum(rng.exponential(1.0 / rate, size=n))
    while times.size and times[-1] < horizon:  # rare under-draw
        extra = np.cumsum(rng.exponential(1.0 / rate, size=n)) + times[-1]
        times = np.concatenate([times, extra])
    return times[times < horizon]


def bursty_times(rng: np.random.Generator, rate: float, horizon: float,
                 *, burst_size: int = 4, within: float = 1e-3) -> np.ndarray:
    """Batch-Poisson bursts: epochs at rate/burst_size, ``burst_size`` each."""
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    epochs = poisson_times(rng, rate / burst_size, horizon)
    offsets = rng.uniform(0.0, within, size=(epochs.size, burst_size))
    offsets[:, 0] = 0.0
    times = (epochs[:, None] + offsets).reshape(-1)
    return np.sort(times[times < horizon])


def diurnal_times(rng: np.random.Generator, base_rate: float, peak_rate: float,
                  horizon: float, *, period: float | None = None) -> np.ndarray:
    """Nonhomogeneous Poisson (thinning) with a sinusoidal daily profile."""
    if peak_rate < base_rate:
        raise ValueError(
            f"peak_rate {peak_rate} must be >= base_rate {base_rate}")
    period = horizon if period is None else period
    lam_max = max(peak_rate, 1e-12)
    cand = poisson_times(rng, lam_max, horizon)
    lam = base_rate + (peak_rate - base_rate) * 0.5 * (
        1.0 - np.cos(2.0 * np.pi * cand / period))
    keep = rng.uniform(0.0, 1.0, size=cand.size) < lam / lam_max
    return cand[keep]


_PROCESSES: dict[str, Callable[..., ArrivalFn]] = {}


def register_process(name: str):
    """Decorator: register an arrival-process factory under ``name``.

    The factory's keyword arguments are its process params — callers pass
    them via ``make_process(name, **params)`` (and, in the online loop,
    ``run_online(process=name, process_params={...})``; the ``rate``
    shorthand there only maps onto the built-ins).
    """
    def deco(factory):
        _PROCESSES[name] = factory
        return factory
    return deco


_register = register_process  # backwards-compatible internal alias


@_register("poisson")
def _poisson(rate: float = 1.0) -> ArrivalFn:
    return lambda rng, horizon: poisson_times(rng, rate, horizon)


@_register("bursty")
def _bursty(rate: float = 1.0, burst_size: int = 4,
            within: float = 1e-3) -> ArrivalFn:
    return lambda rng, horizon: bursty_times(
        rng, rate, horizon, burst_size=burst_size, within=within)


@_register("diurnal")
def _diurnal(base_rate: float = 0.2, peak_rate: float = 1.0,
             period: float | None = None) -> ArrivalFn:
    return lambda rng, horizon: diurnal_times(
        rng, base_rate, peak_rate, horizon, period=period)


def available() -> tuple[str, ...]:
    """Registered process names (built-ins + ``register_process`` extras)."""
    return tuple(sorted(_PROCESSES))


def make_process(name: str, **params) -> ArrivalFn:
    """Build an arrival-time generator by name (poisson | bursty | diurnal)."""
    try:
        factory = _PROCESSES[name]
    except KeyError:
        raise ValueError(
            f"unknown arrival process {name!r}; available: "
            f"{', '.join(available())}") from None
    return factory(**params)


def resolve_rate(process: str, rate: float | None,
                 params: dict | None) -> dict:
    """Map the ``rate`` shorthand onto a named process's own parameters.

    Explicit ``params`` always win over the shorthand.  The mapping is only
    defined for the built-ins — ``poisson`` / ``bursty`` take ``rate``
    directly, ``diurnal`` scales the whole profile (``peak_rate = rate``,
    ``base_rate = peak_rate / 5``, the module defaults' 5:1 ratio) — so any
    other *registered* process rejects ``rate`` with a ``ValueError``
    rather than silently ignoring it.  (An unregistered name passes
    through: :func:`make_process` raises its own "unknown process" error.)
    Shared by the serial online loop and the streaming pipeline so both
    drive bit-identical arrival streams from the same arguments.
    """
    out = dict(params or {})
    if rate is None:
        return out
    if process in ("poisson", "bursty"):
        out.setdefault("rate", rate)
    elif process == "diurnal":
        out.setdefault("peak_rate", rate)
        out.setdefault("base_rate", out["peak_rate"] / 5.0)
    elif process in available():
        raise ValueError(
            f"run_online(rate=...) has no defined mapping onto process "
            f"{process!r}; pass its rate parameters via process_params=")
    return out


def stream_times(process: str, rng: np.random.Generator, horizon: float,
                 *, chunk_s: float | None = None,
                 **params) -> Iterator[float]:
    """Iterator view over an arrival process.

    Materializing a whole horizon of timestamps up front is fine for a
    benchmark but the wrong shape for a serving pipeline that ingests
    arrivals one at a time; this yields them lazily.  By default the named
    process is drawn once (the stream is *identical* to
    ``make_process(process, **params)(rng, horizon)``); with ``chunk_s``
    the horizon is generated chunk-by-chunk — the process restarts at each
    chunk boundary, which is exact for the memoryless ``poisson`` and an
    approximation for processes with cross-boundary structure (a burst or
    diurnal phase does not span chunks) — so unbounded horizons never hold
    more than one chunk of timestamps in memory.
    """
    fn = make_process(process, **params)
    if chunk_s is None:
        yield from (float(t) for t in fn(rng, horizon))
        return
    if chunk_s <= 0:
        raise ValueError(f"chunk_s must be > 0, got {chunk_s}")
    t0 = 0.0
    while t0 < horizon:
        dt = min(chunk_s, horizon - t0)
        for t in fn(rng, dt):
            yield t0 + float(t)
        t0 += dt
