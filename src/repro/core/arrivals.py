"""Arrival processes: request batches arriving on a clock.

The online serving loop (``repro.serving.online``) drains the network's
:class:`~repro.core.state.QueueState` to each arrival time before solving.
This module generates the arrival clocks.  Every process is a host-side
generator of sorted timestamps in ``[0, horizon)`` seconds:

  * :func:`poisson_times` — homogeneous Poisson with rate ``rate`` (1/s):
    the memoryless baseline every stability argument is phrased against.
  * :func:`bursty_times` — compound/batch Poisson: burst *epochs* arrive
    Poisson at ``rate / burst_size`` and each epoch carries ``burst_size``
    arrivals jittered ``within`` seconds apart, so the long-run rate is
    ``rate`` but the short-run load is spiky.
  * :func:`diurnal_times` — nonhomogeneous Poisson via thinning with a
    sinusoidal rate  lam(t) = base + (peak - base) * (1 - cos(2 pi t /
    period)) / 2  — a traffic "day" ramping from ``base_rate`` at t=0 to
    ``peak_rate`` at mid-period and back.

``make_process(name, **params)`` returns a ``(rng, horizon) -> times``
callable from a string name, so scenarios and benchmarks can pick a
process the same way they pick a solver.
"""
from __future__ import annotations

from typing import Callable, Protocol

import numpy as np

ArrivalFn = Callable[[np.random.Generator, float], np.ndarray]


class ArrivalProcess(Protocol):
    """(rng, horizon seconds) -> sorted float64 arrival times in [0, horizon)."""

    def __call__(self, rng: np.random.Generator, horizon: float) -> np.ndarray:
        ...


def poisson_times(rng: np.random.Generator, rate: float,
                  horizon: float) -> np.ndarray:
    """Homogeneous Poisson arrivals: i.i.d. Exp(rate) gaps."""
    if rate <= 0:
        return np.zeros((0,), np.float64)
    # Draw ~horizon*rate + slack gaps in one shot, keep the prefix in range.
    n = max(8, int(horizon * rate * 1.5) + 8)
    times = np.cumsum(rng.exponential(1.0 / rate, size=n))
    while times.size and times[-1] < horizon:  # rare under-draw
        extra = np.cumsum(rng.exponential(1.0 / rate, size=n)) + times[-1]
        times = np.concatenate([times, extra])
    return times[times < horizon]


def bursty_times(rng: np.random.Generator, rate: float, horizon: float,
                 *, burst_size: int = 4, within: float = 1e-3) -> np.ndarray:
    """Batch-Poisson bursts: epochs at rate/burst_size, ``burst_size`` each."""
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    epochs = poisson_times(rng, rate / burst_size, horizon)
    offsets = rng.uniform(0.0, within, size=(epochs.size, burst_size))
    offsets[:, 0] = 0.0
    times = (epochs[:, None] + offsets).reshape(-1)
    return np.sort(times[times < horizon])


def diurnal_times(rng: np.random.Generator, base_rate: float, peak_rate: float,
                  horizon: float, *, period: float | None = None) -> np.ndarray:
    """Nonhomogeneous Poisson (thinning) with a sinusoidal daily profile."""
    if peak_rate < base_rate:
        raise ValueError(
            f"peak_rate {peak_rate} must be >= base_rate {base_rate}")
    period = horizon if period is None else period
    lam_max = max(peak_rate, 1e-12)
    cand = poisson_times(rng, lam_max, horizon)
    lam = base_rate + (peak_rate - base_rate) * 0.5 * (
        1.0 - np.cos(2.0 * np.pi * cand / period))
    keep = rng.uniform(0.0, 1.0, size=cand.size) < lam / lam_max
    return cand[keep]


_PROCESSES: dict[str, Callable[..., ArrivalFn]] = {}


def register_process(name: str):
    """Decorator: register an arrival-process factory under ``name``.

    The factory's keyword arguments are its process params — callers pass
    them via ``make_process(name, **params)`` (and, in the online loop,
    ``run_online(process=name, process_params={...})``; the ``rate``
    shorthand there only maps onto the built-ins).
    """
    def deco(factory):
        _PROCESSES[name] = factory
        return factory
    return deco


_register = register_process  # backwards-compatible internal alias


@_register("poisson")
def _poisson(rate: float = 1.0) -> ArrivalFn:
    return lambda rng, horizon: poisson_times(rng, rate, horizon)


@_register("bursty")
def _bursty(rate: float = 1.0, burst_size: int = 4,
            within: float = 1e-3) -> ArrivalFn:
    return lambda rng, horizon: bursty_times(
        rng, rate, horizon, burst_size=burst_size, within=within)


@_register("diurnal")
def _diurnal(base_rate: float = 0.2, peak_rate: float = 1.0,
             period: float | None = None) -> ArrivalFn:
    return lambda rng, horizon: diurnal_times(
        rng, base_rate, peak_rate, horizon, period=period)


def available() -> tuple[str, ...]:
    """Registered process names (built-ins + ``register_process`` extras)."""
    return tuple(sorted(_PROCESSES))


def make_process(name: str, **params) -> ArrivalFn:
    """Build an arrival-time generator by name (poisson | bursty | diurnal)."""
    try:
        factory = _PROCESSES[name]
    except KeyError:
        raise ValueError(
            f"unknown arrival process {name!r}; available: "
            f"{', '.join(available())}") from None
    return factory(**params)
