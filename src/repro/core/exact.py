"""Exact oracles (host-side numpy) used to validate the JAX routing DP.

``exact_route_bitmask`` solves the single-job ILP (1)-(5) *exactly*,
including the once-per-node z_u semantics, by dynamic programming over
(layer, node, set-of-wait-charged-nodes).  Exponential in |V_p| but exact —
the oracle for small randomized instances (V <= ~14).

``exact_plan`` lifts the single-job oracle to the multi-job problem (every
priority order x exact sequential routing) and returns a canonical
:class:`~repro.core.plan.Plan` — registered as ``solve(..., method="exact")``.

``brute_force_makespan`` enumerates (assignments x priorities) on tiny
instances and simulates the actual system, giving the true optimum T* for
approximation-ratio tests (Theorem 2 / Corollary 1).
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from .network import ComputeNetwork
from .jobs import JobBatch
from .plan import Plan

_INF = 1e30


def _np_closure(w: np.ndarray) -> np.ndarray:
    n = w.shape[-1]
    d = w.copy()
    idx = np.arange(n)
    d[..., idx, idx] = np.minimum(d[..., idx, idx], 0.0)
    for _ in range(max(1, int(np.ceil(np.log2(max(n - 1, 2)))))):
        d = np.min(d[..., :, :, None] + d[..., None, :, :], axis=-2)
    return d


def _net_np(net: ComputeNetwork):
    mu_n = np.asarray(net.mu_node, np.float64)
    mu_l = np.asarray(net.mu_link, np.float64)
    q_n = np.asarray(net.q_node, np.float64)
    q_l = np.asarray(net.q_link, np.float64)
    v = mu_n.shape[0]
    inv_l = np.where(mu_l > 0, 1.0 / np.maximum(mu_l, 1e-30), _INF)
    inv_l[np.arange(v), np.arange(v)] = 0.0
    wait_l = np.where(mu_l > 0, q_l / np.maximum(mu_l, 1e-30), 0.0)
    wait_l[np.arange(v), np.arange(v)] = 0.0
    inv_n = np.where(mu_n > 0, 1.0 / np.maximum(mu_n, 1e-30), _INF)
    wait_n = np.where(mu_n > 0, q_n / np.maximum(mu_n, 1e-30), 0.0)
    return inv_l, wait_l, inv_n, wait_n


def layer_weights_np(net: ComputeNetwork, data: np.ndarray) -> np.ndarray:
    inv_l, wait_l, _, _ = _net_np(net)
    w = data[:, None, None] * inv_l[None] + wait_l[None]
    return np.minimum(w, _INF)


def exact_route_bitmask(net: ComputeNetwork, comp: np.ndarray, data: np.ndarray,
                        src: int, dst: int) -> tuple[float, list[int]]:
    """Exact optimum of ILP (1)-(5): min over paths of service + once-per-node waits."""
    inv_l, wait_l, inv_n, wait_n = _net_np(net)
    v = inv_n.shape[0]
    if v > 16:
        raise ValueError("bitmask oracle is for small graphs")
    L = len(comp)
    t = _np_closure(layer_weights_np(net, np.asarray(data, np.float64)))

    full = 1 << v
    f = np.full((v, full), _INF)
    bp: dict[tuple[int, int, int], tuple[int, int]] = {}
    for u in range(v):
        s = 1 << u
        f[u, s] = t[0, src, u] + wait_n[u] + comp[0] * inv_n[u]
    for l in range(2, L + 1):
        g = np.full((v, full), _INF)
        for mask in range(full):
            row = f[:, mask]
            if np.all(row >= _INF):
                continue
            for u in range(v):
                if row[u] >= _INF:
                    continue
                for w_ in range(v):
                    nm = mask | (1 << w_)
                    extra = 0.0 if (mask >> w_) & 1 else wait_n[w_]
                    c = row[u] + t[l - 1, u, w_] + extra + comp[l - 1] * inv_n[w_]
                    if c < g[w_, nm] - 1e-15:
                        g[w_, nm] = c
                        bp[(l, w_, nm)] = (u, mask)
        f = g
    best = _INF
    arg = None
    for mask in range(full):
        for u in range(v):
            c = f[u, mask] + t[L, u, dst]
            if c < best - 1e-15:
                best, arg = c, (u, mask)
    assign = []
    if arg is not None:
        u, mask = arg
        assign = [u]
        for l in range(L, 1, -1):
            u, mask = bp[(l, u, mask)]
            assign.append(u)
        assign.reverse()
    return float(best), assign


def exact_plan(net: ComputeNetwork, batch: JobBatch, *,
               max_jobs: int = 7) -> Plan:
    """Exact solver for the multi-job fictitious-system objective.

    Enumerates every priority order (J! of them) and, within each order,
    routes each job *exactly* with the bitmask oracle against the queue
    state left by its higher-priority predecessors — i.e. the exact version
    of the sequential commit process that both Alg. 1 and Alg. 2 bound.
    Exponential in both J and |V_p|; intended for oracle checks on tiny
    instances (J <= ~6, V <= ~14).
    """
    from . import routing, shortest_path as SP

    J = batch.num_jobs
    if J > max_jobs:
        raise ValueError(f"exact solver is for <= {max_jobs} jobs, got {J}")
    if net.num_nodes > 16:
        raise ValueError("exact solver is for small graphs (V <= 16)")
    comp = np.asarray(batch.comp, np.float64)
    data = np.asarray(batch.data, np.float64)
    nl = np.asarray(batch.num_layers)
    src = np.asarray(batch.src)
    dst = np.asarray(batch.dst)
    lmax = batch.max_layers

    best_mk = np.inf
    best: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None
    n_routings = 0
    for perm in itertools.permutations(range(J)):
        cur = net
        assign = np.zeros((J, lmax), np.int32)
        bounds = np.zeros((J,), np.float64)
        for j in perm:
            L = int(nl[j])
            n_routings += 1
            cost, a = exact_route_bitmask(
                cur, comp[j, :L], data[j, : L + 1], int(src[j]), int(dst[j]))
            bounds[j] = cost
            assign[j, :L] = a
            if L:  # pad with the last compute node (masked out of all costs)
                assign[j, L:] = a[-1]
            cur = routing.commit_assignment(
                cur, batch.comp[j], batch.data[j], batch.src[j],
                batch.dst[j], batch.num_layers[j], assign[j],
                closures=SP.build_closures(cur, batch.data[j]))
            if bounds[j] >= best_mk:
                break  # this order can't beat the incumbent
        else:
            if bounds.max() < best_mk:
                best_mk = float(bounds.max())
                best = (assign, np.asarray(perm, np.int32), bounds)
    assert best is not None
    assign, order, bounds = best
    return Plan.from_order(assign, order, bounds, solver="exact",
                           meta={"orders_tried": math.factorial(J),
                                 "n_routings": n_routings})


def brute_force_makespan(net: ComputeNetwork, batch: JobBatch) -> float:
    """True optimum T*: enumerate (assignments x priorities), simulate.

    The oracle for approximation-ratio tests (Theorem 2 / Corollary 1).
    Doubly exponential — tiny instances only.
    """
    from . import schedule

    mu = np.asarray(net.mu_node)
    comp_nodes = np.nonzero(mu > 0)[0]
    J = batch.num_jobs
    Ls = [int(batch.num_layers[j]) for j in range(J)]
    best = np.inf
    for assigns in itertools.product(
            *[itertools.product(comp_nodes, repeat=Ls[j]) for j in range(J)]):
        a = np.zeros((J, batch.max_layers), np.int32)
        for j in range(J):
            a[j, :Ls[j]] = assigns[j]
            a[j, Ls[j]:] = assigns[j][-1] if Ls[j] else 0
        for perm in itertools.permutations(range(J)):
            sim = schedule.simulate(net, batch, a, np.asarray(perm))
            best = min(best, sim.makespan)
    return float(best)
