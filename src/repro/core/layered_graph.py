"""Explicit layered-graph construction and the ILP matrices of §III-B.

The routing DP never materializes the layered graph (it works on per-layer
closures), but the explicit construction is needed to (a) state the ILP
(1)-(5) in matrix form [A1; A2] and test Theorem 1's total-unimodularity
claim, and (b) cross-check the DP against path enumeration on tiny graphs.

Variable order matches Appendix A: y = [z (|V|); r_cross (L*|V|);
r_intra ((L+1)*|E_dir|)].
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .network import ComputeNetwork, edge_list


@dataclasses.dataclass(frozen=True)
class LayeredILP:
    a1: np.ndarray       # [L*V, n_y]   constraint (2):  r_cross - z <= 0
    a2: np.ndarray       # [(L+1)*V, n_y] flow conservation (3)
    b2: np.ndarray       # [(L+1)*V]
    c: np.ndarray        # [n_y] objective coefficients (1)
    num_nodes: int
    num_layers: int
    edges: list[tuple[int, int]]

    @property
    def n_z(self) -> int:
        return self.num_nodes

    @property
    def n_cross(self) -> int:
        return self.num_layers * self.num_nodes

    def cross_var(self, u: int, l: int) -> int:
        """Index of r_{u_{l-1} u_l}, l in 1..L."""
        return self.n_z + (l - 1) * self.num_nodes + u

    def intra_var(self, e: int, l: int) -> int:
        """Index of r_{(u_l, v_l)} for directed edge e, l in 0..L."""
        return self.n_z + self.n_cross + l * len(self.edges) + e


def build_ilp(net: ComputeNetwork, num_layers: int, src: int, dst: int,
              comp: np.ndarray, data: np.ndarray) -> LayeredILP:
    v = net.num_nodes
    L = num_layers
    edges = edge_list(net)
    E = len(edges)
    n_y = v + L * v + (L + 1) * E

    mu_n = np.asarray(net.mu_node, np.float64)
    mu_l = np.asarray(net.mu_link, np.float64)
    q_n = np.asarray(net.q_node, np.float64)
    q_l = np.asarray(net.q_link, np.float64)

    ilp = LayeredILP(a1=np.zeros((L * v, n_y)), a2=np.zeros(((L + 1) * v, n_y)),
                     b2=np.zeros(((L + 1) * v,)), c=np.zeros((n_y,)),
                     num_nodes=v, num_layers=L, edges=edges)

    # --- constraint (2): r_{u_{l-1}u_l} - z_u <= 0, grouped per node (Fig. 6)
    row = 0
    for u in range(v):
        for l in range(1, L + 1):
            ilp.a1[row, ilp.cross_var(u, l)] = 1.0
            ilp.a1[row, u] = -1.0
            row += 1

    # --- constraint (3): flow conservation at u_l, rows ordered u0..uL per node
    def fc_row(u: int, l: int) -> int:
        return u * (L + 1) + l

    for e, (a, b) in enumerate(edges):
        for l in range(L + 1):
            ilp.a2[fc_row(a, l), ilp.intra_var(e, l)] += 1.0   # out of a_l
            ilp.a2[fc_row(b, l), ilp.intra_var(e, l)] -= 1.0   # into b_l
    for u in range(v):
        for l in range(1, L + 1):
            ilp.a2[fc_row(u, l - 1), ilp.cross_var(u, l)] += 1.0  # out of u_{l-1}
            ilp.a2[fc_row(u, l), ilp.cross_var(u, l)] -= 1.0      # into u_l
    ilp.b2[fc_row(src, 0)] = 1.0
    ilp.b2[fc_row(dst, L)] = -1.0

    # --- objective (1)
    big = 1e30
    for u in range(v):
        ilp.c[u] = q_n[u] / mu_n[u] if mu_n[u] > 0 else 0.0  # z term
    for u in range(v):
        for l in range(1, L + 1):
            ilp.c[ilp.cross_var(u, l)] = (
                comp[l - 1] / mu_n[u] if mu_n[u] > 0 else big)
    for e, (a, b) in enumerate(edges):
        for l in range(L + 1):
            ilp.c[ilp.intra_var(e, l)] = (data[l] + q_l[a, b]) / mu_l[a, b]
    return ilp


def random_square_submatrix_dets(mat: np.ndarray, trials: int, max_k: int,
                                 seed: int = 0) -> np.ndarray:
    """Determinants of random square submatrices (TU spot-check, Thm 1)."""
    rng = np.random.default_rng(seed)
    m, n = mat.shape
    out = np.zeros((trials,))
    for i in range(trials):
        k = int(rng.integers(1, min(max_k, m, n) + 1))
        rows = rng.choice(m, size=k, replace=False)
        cols = rng.choice(n, size=k, replace=False)
        out[i] = np.linalg.det(mat[np.ix_(rows, cols)])
    return out
