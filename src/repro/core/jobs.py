"""DNN inference jobs.

A job j is the feedforward computation of a DNN model with L_j layers,
generated at a source node and whose result must be delivered to a
destination node.  ``comp[l]`` (FLOPs) is the load of computing layer l+1
(paper's c_{j,l+1}); ``data[l]`` (bytes) is the output size of layer l
(paper's d_{jl}), with ``data[0]`` the input data size and ``data[L]`` the
inference-result size.

For vmap-friendly multi-job routing, jobs are padded to a common max layer
count in :class:`JobBatch`; padded layers have zero compute and zero data and
are masked out of every cost term.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class InferenceJob:
    name: str
    src: int
    dst: int
    comp: np.ndarray  # [L] FLOPs per layer
    data: np.ndarray  # [L+1] bytes: input, per-layer outputs
    # Relative SLO: the job must complete within deadline_s of its arrival
    # (inf = no deadline).  Host-side metadata only — it never enters the
    # JobBatch pytree or any solver cost; the admission layer
    # (repro.serving.admission) is its sole consumer.
    deadline_s: float = float("inf")

    @property
    def num_layers(self) -> int:
        return int(self.comp.shape[0])

    def with_deadline(self, deadline_s: float) -> "InferenceJob":
        return dataclasses.replace(self, deadline_s=float(deadline_s))

    def __post_init__(self):
        # Normalize-then-validate: store the converted arrays so list inputs
        # fail here with a named ValueError, not later with AttributeError.
        comp = np.asarray(self.comp, np.float32)
        data = np.asarray(self.data, np.float32)
        object.__setattr__(self, "comp", comp)
        object.__setattr__(self, "data", data)
        if comp.ndim != 1 or comp.shape[0] < 1:
            raise ValueError(f"comp must be a non-empty [L] vector, got shape {comp.shape}")
        if data.shape != (comp.shape[0] + 1,):
            raise ValueError(
                f"data must have L+1={comp.shape[0] + 1} entries (input + L "
                f"layer outputs), got shape {data.shape}")
        from .validation import check_finite_nonneg
        check_finite_nonneg("comp", comp)
        check_finite_nonneg("data", data)
        if self.src < 0 or self.dst < 0:
            raise ValueError(f"src/dst must be >= 0, got ({self.src}, {self.dst})")
        d = float(self.deadline_s)
        if np.isnan(d) or d <= 0:
            raise ValueError(f"deadline_s must be > 0 (inf = none), got {d}")
        object.__setattr__(self, "deadline_s", d)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class JobBatch:
    """Padded batch of J jobs (a JAX pytree)."""

    src: jax.Array        # [J] int32
    dst: jax.Array        # [J] int32
    comp: jax.Array       # [J, Lmax] FLOPs (0 beyond L_j)
    data: jax.Array       # [J, Lmax+1] bytes (0 beyond L_j)
    num_layers: jax.Array  # [J] int32

    @property
    def num_jobs(self) -> int:
        return self.src.shape[0]

    @property
    def max_layers(self) -> int:
        return self.comp.shape[1]


def batch_jobs(jobs: Sequence[InferenceJob], *, pad_to: int | None = None) -> JobBatch:
    """Pad jobs to a common layer count (``pad_to`` pins the padded width so
    batches of varying composition share one jit shape)."""
    if not jobs:
        raise ValueError("empty job list")
    lmax = max(j.num_layers for j in jobs)
    if pad_to is not None:
        if pad_to < lmax:
            raise ValueError(
                f"pad_to={pad_to} is smaller than the longest job (L={lmax})")
        lmax = pad_to
    J = len(jobs)
    comp = np.zeros((J, lmax), np.float32)
    data = np.zeros((J, lmax + 1), np.float32)
    src = np.zeros((J,), np.int32)
    dst = np.zeros((J,), np.int32)
    nl = np.zeros((J,), np.int32)
    for i, j in enumerate(jobs):
        L = j.num_layers
        comp[i, :L] = j.comp
        data[i, : L + 1] = j.data
        # Padded "layers" carry the final output forward at zero cost: the
        # data entry stays 0 so transfers of padded layers are free and the
        # true final transfer d_L is handled by the masked DP epilogue.
        src[i], dst[i], nl[i] = j.src, j.dst, L
    return JobBatch(
        src=jnp.asarray(src), dst=jnp.asarray(dst), comp=jnp.asarray(comp),
        data=jnp.asarray(data), num_layers=jnp.asarray(nl),
    )


def synthetic_job(
    name: str, src: int, dst: int, num_layers: int, *, seed: int = 0,
    flops_scale: float = 1e9, bytes_scale: float = 1e6,
) -> InferenceJob:
    """Random job for property tests / the paper's hand-made third model."""
    rng = np.random.default_rng(seed)
    comp = rng.uniform(0.2, 2.0, size=num_layers).astype(np.float32) * flops_scale
    data = rng.uniform(0.1, 1.5, size=num_layers + 1).astype(np.float32) * bytes_scale
    return InferenceJob(name, src, dst, comp, data)
