"""AdamW with global-norm clipping (pure pytree implementation).

State tensors mirror the parameter tree, so parameter sharding rules apply
verbatim to optimizer state (the dry-run's memory analysis accounts for m/v
shards).  ``schedule`` is any step -> lr callable from
:mod:`repro.optim.schedules`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return {"m": zeros(params), "v": zeros(params),
                "step": jnp.zeros((), jnp.int32)}

    def apply(self, params, grads, state):
        step = state["step"] + 1
        lr = self.schedule(step)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / (1 - self.b1 ** step.astype(jnp.float32))
            vhat = v / (1 - self.b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, {
            "lr": lr, "grad_norm": gnorm}
