"""LR schedules: linear warmup + {cosine, WSD}.

WSD (warmup-stable-decay) is the MiniCPM schedule the minicpm-2b config
trains with: warmup to peak, hold stable, then a short 1-sqrt/exp decay tail.
"""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr, warmup_steps, total_steps, min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)


def wsd(step, *, peak_lr, warmup_steps, stable_steps, decay_steps,
        min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    decay_start = warmup_steps + stable_steps
    prog = jnp.clip((step - decay_start) / jnp.maximum(decay_steps, 1), 0.0, 1.0)
    decay = peak_lr * (min_ratio ** prog)        # exponential decay tail
    out = jnp.where(step < warmup_steps, warm, peak_lr)
    return jnp.where(step >= decay_start, decay, out)
