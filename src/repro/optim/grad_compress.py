"""Gradient compression for cross-pod (DCN) all-reduces.

The multi-pod design replicates parameters across pods and all-reduces
gradients over the slow 'pod' axis (DESIGN.md §3.4).  ``Int8Compressor``
quantizes each gradient leaf to int8 with a per-leaf scale before the pod
all-reduce and keeps the quantization residual as *error feedback* (Seide et
al. / Karimireddy et al.): the residual is added back into the next step's
gradient, so the compressed SGD trajectory provably tracks the exact one.

``topk_mask`` is a sparsification alternative (keeps the k largest-magnitude
entries per leaf, error feedback likewise).  Both are pure pytree transforms
usable inside jit; tests verify convergence parity on a quadratic problem.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def _quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    """int8 + error feedback. Use with a pod-axis psum:

        comp, state = compressor.compress(grads, state)
        comp = jax.lax.psum(comp_as_int32, 'pod')   # 4x fewer DCN bytes
        grads = compressor.decompress(comp)
    """

    def init(self, grads_like) -> Any:
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

    def compress(self, grads, err_state):
        def one(g, e):
            g = g.astype(jnp.float32) + e
            q, scale = _quantize_leaf(g)
            new_e = g - _dequantize_leaf(q, scale)
            return (q, scale), new_e

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err_state)
        qs, es = [], []
        for g, e in zip(flat_g, flat_e):
            (q, s), ne = one(g, e)
            qs.append((q, s))
            es.append(ne)
        comp = jax.tree.unflatten(treedef, qs)
        new_state = jax.tree.unflatten(treedef, es)
        return comp, new_state

    def decompress(self, comp):
        return jax.tree.map(lambda qs: _dequantize_leaf(*qs), comp,
                            is_leaf=lambda x: isinstance(x, tuple))

    def roundtrip(self, grads, err_state):
        """compress+decompress without a collective (single-host testing)."""
        comp, new_state = self.compress(grads, err_state)
        return self.decompress(comp), new_state

    @staticmethod
    def compressed_bytes(grads) -> int:
        return sum(int(g.size) for g in jax.tree.leaves(grads))  # 1B/elem

    @staticmethod
    def raw_bytes(grads) -> int:
        return sum(int(g.size) * 4 for g in jax.tree.leaves(grads))


def topk_mask(g: jax.Array, frac: float) -> jax.Array:
    """Keep the ``frac`` largest-|.| entries of a leaf (flattened)."""
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(frac * flat.size))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)
