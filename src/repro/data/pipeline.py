"""Deterministic synthetic data pipeline.

Produces reproducible token streams keyed by (seed, step, shard) so that
  * restarts resume bit-identically (checkpoint stores only the step),
  * each data-parallel shard draws a disjoint sub-batch (shard_id/num_shards),
  * no filesystem or network dependency (offline container).

The "corpus" is a mixture of Zipfian unigrams and short repeated n-gram
motifs — enough structure that a ~10M-param model's loss visibly drops
within a few hundred steps (examples/train_smollm.py), while remaining a
pure function of the key.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_prob: float = 0.5


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_a)
    return (p / p.sum()).astype(np.float32)


class SyntheticStream:
    """Host-side deterministic batch source (numpy; cheap per step)."""

    def __init__(self, cfg: DataConfig, *, shard_id: int = 0,
                 num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard_id = shard_id
        self.num_shards = num_shards
        self._probs = _zipf_probs(cfg)

    def batch_at(self, step: int) -> dict:
        """The shard's sub-batch for ``step`` (pure function of step)."""
        cfg = self.cfg
        b = cfg.global_batch // self.num_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 97 + self.shard_id)
        tokens = rng.choice(cfg.vocab_size, size=(b, cfg.seq_len + 1),
                            p=self._probs).astype(np.int32)
        # overwrite random spans with repeated motifs (learnable structure)
        n_motifs = int(cfg.motif_prob * b)
        for i in range(n_motifs):
            row = rng.integers(0, b)
            motif = rng.integers(0, cfg.vocab_size, size=cfg.motif_len)
            reps = cfg.seq_len // cfg.motif_len
            tokens[row, : reps * cfg.motif_len] = np.tile(motif, reps)[
                : reps * cfg.motif_len]
        return {"tokens": jnp.asarray(tokens[:, :-1]),
                "labels": jnp.asarray(tokens[:, 1:])}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
