"""gemma3-1b [dense]: 26L d1152 4H (GQA kv=1, head_dim=256) d_ff=6912 vocab=262144; 5:1 local:global sliding window [hf:google/gemma-3-1b-pt]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='gemma3-1b', family='dense', num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, head_dim=256, d_ff=6912, vocab_size=262144, local_global_pattern=6, sliding_window=512)


def smoke_config() -> ModelConfig:
    return ModelConfig(name='gemma3-1b-smoke', family='dense', num_layers=6, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=512, local_global_pattern=3, sliding_window=8, remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
