"""The assigned input-shape set (applies to every LM-family architecture).

Each shape names the step it lowers: train shapes lower ``train_step``,
decode shapes lower ``serve_step`` (one new token against a KV cache of
``seq_len``), prefill lowers the forward pass.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg, spec: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if spec.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention architecture: 500k-token decode needs "
                       "sub-quadratic attention (see DESIGN.md §4)")
    return True, ""
