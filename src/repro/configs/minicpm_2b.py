"""minicpm-2b [dense]: 40L d2304 36H (kv=36) d_ff=5760 vocab=122753; WSD schedule (llama-like arch) [arXiv:2404.06395; hf]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='minicpm-2b', family='dense', num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36, d_ff=5760, vocab_size=122753)


def smoke_config() -> ModelConfig:
    return ModelConfig(name='minicpm-2b-smoke', family='dense', num_layers=2, d_model=72, num_heads=6, num_kv_heads=6, d_ff=144, vocab_size=512, remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
