"""VGG19 — one of the paper's own evaluation models (cost profile only)."""
import numpy as np

from repro.core.jobs import InferenceJob
from repro.costs.convnets import vgg19_profile


def config():
    return {"name": "vgg19", "kind": "convnet", "input": (224, 224, 3)}


def smoke_config():
    return config()


def cost_profile(*, batch: int = 1):
    return vgg19_profile(batch=batch)


def make_job(name: str, src: int, dst: int, *, batch: int = 1) -> InferenceJob:
    comp, data = vgg19_profile(batch=batch)
    return InferenceJob(name, src, dst, comp.astype(np.float32),
                        data.astype(np.float32))
