"""smollm-135m [dense]: 30L d576 9H (GQA kv=3) d_ff=1536 vocab=49152; llama-arch small [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='smollm-135m', family='dense', num_layers=30, d_model=576, num_heads=9, num_kv_heads=3, d_ff=1536, vocab_size=49152)


def smoke_config() -> ModelConfig:
    return ModelConfig(name='smollm-135m-smoke', family='dense', num_layers=2, d_model=48, num_heads=3, num_kv_heads=1, d_ff=96, vocab_size=512, remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
