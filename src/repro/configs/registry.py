"""--arch registry: the 10 assigned architectures (+ paper's own conv nets).

Each entry provides:
  * ``config()``        exact full config from the assignment table
  * ``smoke_config()``  reduced same-family config for CPU smoke tests
  * ``input_specs(cfg, shape, mesh=None)`` ShapeDtypeStructs for the dry-run
  * ``cost_profile(cfg, ...)`` per-layer (c_jl FLOPs, d_jl bytes) for the
    routing framework (the paper's jobs)
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "olmo_1b", "smollm_135m", "minicpm_2b", "gemma3_1b", "xlstm_125m",
    "olmoe_1b_7b", "deepseek_v2_236b", "whisper_base", "zamba2_2_7b",
    "phi3_vision_4_2b",
]

# paper's own evaluation models (cost profiles only — conv nets)
PAPER_MODELS = ["vgg19", "resnet34"]


def get(arch: str):
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS + PAPER_MODELS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS + PAPER_MODELS}")
    return importlib.import_module(f"repro.configs.{arch}")


def cost_profile(arch: str, *, seq_len: int = 2048, batch: int = 1):
    """Per-layer (c_jl FLOPs, d_jl bytes) for any registered arch.

    Hides the signature split between the paper's conv nets (batch only)
    and the LM families (seq_len + batch) — the single dispatch point for
    the serving scheduler and the scenario traffic mixes.
    """
    arch = arch.replace("-", "_").replace(".", "_")
    mod = get(arch)
    if arch in PAPER_MODELS:
        return mod.cost_profile(batch=batch)
    return mod.cost_profile(seq_len=seq_len, batch=batch)


def config(arch: str):
    return get(arch).config()


def smoke_config(arch: str):
    return get(arch).smoke_config()
