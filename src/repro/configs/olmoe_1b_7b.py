"""olmoe-1b-7b [moe]: 16L d2048 16H (kv=16), 64 experts top-8, expert d_ff=1024, vocab=50304 [arXiv:2409.02060; hf]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='olmoe-1b-7b', family='moe', num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1024, vocab_size=50304, moe_num_experts=64, moe_top_k=8, moe_d_ff=1024)


def smoke_config() -> ModelConfig:
    return ModelConfig(name='olmoe-1b-7b-smoke', family='moe', num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=512, moe_num_experts=8, moe_top_k=2, moe_d_ff=64, remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
