"""ResNet34 — one of the paper's own evaluation models (cost profile only)."""
import numpy as np

from repro.core.jobs import InferenceJob
from repro.costs.convnets import resnet34_profile


def config():
    return {"name": "resnet34", "kind": "convnet", "input": (224, 224, 3)}


def smoke_config():
    return config()


def cost_profile(*, batch: int = 1):
    return resnet34_profile(batch=batch)


def make_job(name: str, src: int, dst: int, *, batch: int = 1) -> InferenceJob:
    comp, data = resnet34_profile(batch=batch)
    return InferenceJob(name, src, dst, comp.astype(np.float32),
                        data.astype(np.float32))
