"""whisper-base [audio]: 6L enc + 6L dec, d512 8H d_ff=2048 vocab=51865; conv frontend is a stub (precomputed frame embeddings) [arXiv:2212.04356]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='whisper-base', family='encdec', num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=51865, dec_layers=6, num_frames=1500, norm='layernorm')


def smoke_config() -> ModelConfig:
    return ModelConfig(name='whisper-base-smoke', family='encdec', num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512, dec_layers=2, num_frames=16, norm='layernorm', remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
