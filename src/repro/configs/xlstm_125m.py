"""xlstm-125m [ssm]: 12L d768 4H vocab=50304; alternating sLSTM + mLSTM blocks [arXiv:2405.04517]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='xlstm-125m', family='ssm', num_layers=12, d_model=768, num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304)


def smoke_config() -> ModelConfig:
    return ModelConfig(name='xlstm-125m-smoke', family='ssm', num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=512, remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
