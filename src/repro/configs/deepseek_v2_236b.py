"""deepseek-v2-236b [moe]: 60L d5120 128H, MLA kv_lora=512 q_lora=1536, 2 shared + 160 routed experts top-6 (expert d_ff=1536), vocab=102400 [arXiv:2405.04434; hf]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='deepseek-v2-236b', family='moe', num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128, d_ff=1536, vocab_size=102400, use_mla=True, kv_lora_rank=512, q_lora_rank=1536, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128, moe_num_experts=160, moe_top_k=6, moe_num_shared=2, moe_d_ff=1536, tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return ModelConfig(name='deepseek-v2-smoke', family='moe', num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=512, use_mla=True, kv_lora_rank=16, q_lora_rank=24, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16, moe_num_experts=8, moe_top_k=2, moe_num_shared=1, moe_d_ff=32, tie_embeddings=False, remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
