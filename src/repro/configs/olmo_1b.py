"""olmo-1b [dense]: 16L d2048 16H (kv=16) d_ff=8192 vocab=50304; non-parametric LN [arXiv:2402.00838; hf]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='olmo-1b', family='dense', num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, d_ff=8192, vocab_size=50304, norm='nonparam_ln')


def smoke_config() -> ModelConfig:
    return ModelConfig(name='olmo-1b-smoke', family='dense', num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512, norm='nonparam_ln', remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
