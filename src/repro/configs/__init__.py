from . import registry, shapes
from .registry import ARCH_IDS, PAPER_MODELS

__all__ = ["registry", "shapes", "ARCH_IDS", "PAPER_MODELS"]
