"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d2560 + shared attention block every 6 (32H kv=32, d_ff=10240), ssm_state=64, vocab=32000 [arXiv:2411.15242; hf]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='zamba2-2.7b', family='hybrid', num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000, ssm_state=64, mamba_headdim=160, attn_every=6)


def smoke_config() -> ModelConfig:
    return ModelConfig(name='zamba2-smoke', family='hybrid', num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512, ssm_state=8, mamba_headdim=32, attn_every=2, remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
