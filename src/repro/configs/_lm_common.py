"""Shared helpers for the LM-family architecture configs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.model import ModelConfig


def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for the step-function ``batch`` argument.

    Weak-type-correct, shardable, no device allocation (dry-run contract).
    """
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    if spec.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    elif spec.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode: one new token against a KV cache of length s
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.num_frames, cfg.d_model), cfg.dtype)
        if spec.kind == "decode":
            batch["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.num_frames, cfg.d_model), cfg.dtype)
            del batch["frames"]
    if cfg.family == "vlm" and spec.kind != "decode":
        batch["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), cfg.dtype)
    return batch
