"""phi-3-vision-4.2b [vlm]: 32L d3072 32H (kv=32) d_ff=8192 vocab=32064; phi3-mini backbone + CLIP patch-embedding stub [hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.models.model import ModelConfig
from repro.configs import _lm_common
from repro.costs import lm as lm_costs


def config() -> ModelConfig:
    return ModelConfig(name='phi-3-vision-4.2b', family='vlm', num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32064, num_patches=576, tie_embeddings=False)


def smoke_config() -> ModelConfig:
    return ModelConfig(name='phi3v-smoke', family='vlm', num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512, num_patches=8, tie_embeddings=False, remat=False)


def input_specs(spec, cfg=None):
    return _lm_common.input_specs(cfg or config(), spec)


def cost_profile(cfg=None, *, seq_len=2048, batch=1):
    return lm_costs.cost_profile(cfg or config(), seq_len=seq_len, batch=batch)
