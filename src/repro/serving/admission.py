"""Predictive admission control & SLO-guarded automatic re-planning.

The paper's framework minimizes end-to-end latency for the jobs it is
*given*; a production serving system must also refuse or defer work it
cannot finish in time, and notice when reality diverges from the plan.
Both decisions here are driven by the same primitive: the exact-drain
ledger's what-if fork (:func:`repro.core.completions.predict_completions`),
which serves a copy of the live event heap to quiescence and reports every
job's *predicted* completion time — bit-identical to what the real drain
will realize if no further work arrives.

Two policies live here:

  * :class:`AdmissionPolicy` / :class:`AdmissionController` — deadline-aware
    admission.  Each candidate window is pure-solved (no commit), released
    into a fork of the live simulation, and scored: arrivals whose predicted
    completion misses their ``deadline_s`` (an SLO relative to arrival) are
    shed (``policy="reject"``) or parked for a later, hopefully calmer,
    window (``policy="defer"``).  Sheds are first-class trace records —
    ``admission_reject`` / ``deadline_miss`` in ``summary()["shed_by_
    reason"]`` — and a deferred-then-expired arrival is charged from its
    ORIGINAL arrival time, the same rule the fault layer applies to
    requeues.  ``policy="admit_all"`` (default) disables gating but keeps
    the counters, so an A/B against gated runs shares one code path.
  * :class:`ReplanPolicy` / :class:`ReplanMonitor` — automatic re-planning
    with hysteresis.  The monitor compares the last committed batch's
    *predicted* completions (forked, under current health) against the
    bounds it was committed with; when the worst relative divergence
    crosses ``threshold`` it triggers ``replan_last(min_improvement=...)``.
    Cooldown plus exponential backoff bound the re-plan rate, so faults and
    slowdown storms cause a bounded number of re-placements instead of
    thrash; declined re-plans (``no_improvement``) are recorded, not
    retried immediately.

Neither policy touches device code: admission scoring is one extra pure
solve plus an O(tasks) engine fork per gated window, and the monitor is a
pure observer between events.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_POLICIES = ("admit_all", "reject", "defer")


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """How to gate arrivals against their SLOs.

    ``policy``: ``admit_all`` (no gating, counters only), ``reject`` (shed
    predicted misses immediately), ``defer`` (park predicted misses and
    re-assess them at later windows, until they expire).  ``margin_s``
    tightens every deadline by a safety margin: a job is admitted only if
    its predicted latency is <= ``deadline_s - margin_s``.
    """

    policy: str = "admit_all"
    margin_s: float = 0.0

    def __post_init__(self):
        if self.policy not in _POLICIES:
            raise ValueError(f"admission policy must be one of {_POLICIES}, "
                             f"got {self.policy!r}")
        if not np.isfinite(self.margin_s) or self.margin_s < 0:
            raise ValueError(f"margin_s must be finite and >= 0, "
                             f"got {self.margin_s}")


class AdmissionController:
    """Mutable admission state: the defer queue and the audit counters.

    Held by an :class:`~repro.serving.online.OnlineScheduler`; the
    scheduler's ``submit_window`` calls :meth:`pop_deferred` to merge due
    deferrals into the next window and runs the assessment itself (it owns
    the solver and the ledger).  ``counters`` is surfaced live in
    ``OnlineTrace.summary()["admission"]``.

    ``external_defer=True`` hands re-admission of deferred arrivals to an
    outer driver (the streaming pipeline, which must route them through its
    own windowing/backpressure accounting) — the scheduler then never
    self-merges.  ``final=True`` switches ``defer`` into drain-out mode: a
    predicted miss is shed (``deadline_miss``) instead of parked, so
    end-of-stream sweeps terminate.
    """

    def __init__(self, policy: AdmissionPolicy | str | None = None):
        if policy is None:
            policy = AdmissionPolicy()
        elif isinstance(policy, str):
            policy = AdmissionPolicy(policy=policy)
        self.policy = policy
        self.deferred: list[tuple] = []   # (InferenceJob, original arrival)
        self.external_defer = False
        self.final = False
        self.counters = {"assessed": 0, "admitted": 0, "rejected": 0,
                         "deferred": 0, "expired": 0}

    @property
    def gating(self) -> bool:
        return self.policy.policy != "admit_all"

    def active(self, jobs) -> bool:
        """Does this window need an assessment at all?"""
        return self.gating and any(np.isfinite(j.deadline_s) for j in jobs)

    def pop_deferred(self) -> list[tuple]:
        out, self.deferred = self.deferred, []
        return out

    def admits(self, predicted_latency: float, deadline_s: float) -> bool:
        return (not np.isfinite(deadline_s)
                or predicted_latency <= deadline_s - self.policy.margin_s)


@dataclasses.dataclass(frozen=True)
class ReplanPolicy:
    """Hysteresis for automatic re-planning.

    ``threshold``: relative divergence that triggers — the last batch's
    worst ``predicted latency / committed bound`` must exceed ``1 +
    threshold``.  ``cooldown_s`` (simulated seconds) silences the monitor
    after each trigger; every consecutive trigger multiplies the next
    cooldown by ``backoff`` (capped at ``max_cooldown_s``), and a calm
    check (divergence back under threshold) resets it — bounded re-plan
    storms, no thrash.  ``budget`` caps total triggers per run (None =
    unlimited).  ``min_improvement`` is forwarded to
    ``replan_last(min_improvement=...)``: the re-plan commits only if the
    re-solve beats the old assignment re-scored under current health by
    that relative margin.
    """

    threshold: float = 0.25
    cooldown_s: float = 1.0
    backoff: float = 2.0
    max_cooldown_s: float = 60.0
    budget: int | None = None
    min_improvement: float = 0.0

    def __post_init__(self):
        if not np.isfinite(self.threshold) or self.threshold < 0:
            raise ValueError(f"threshold must be finite and >= 0, "
                             f"got {self.threshold}")
        if self.cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, got {self.cooldown_s}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")
        if self.max_cooldown_s < self.cooldown_s:
            raise ValueError("max_cooldown_s must be >= cooldown_s")
        if self.budget is not None and self.budget < 0:
            raise ValueError(f"budget must be >= 0, got {self.budget}")
        if not (0.0 <= self.min_improvement < 1.0):
            raise ValueError(f"min_improvement must be in [0, 1), "
                             f"got {self.min_improvement}")


class ReplanMonitor:
    """SLO guard: watches plan divergence, triggers bounded re-planning.

    Stateful but tiny: next-allowed trigger time, current cooldown, trigger
    count.  :meth:`check` is called by the scheduler after window commits
    and by the drivers after fault events; it reads
    ``sched.plan_divergence()`` (a forked prediction — nothing committed)
    and calls ``sched.replan_last`` only past the hysteresis gates.
    """

    def __init__(self, policy: ReplanPolicy | None = None):
        self.policy = policy if policy is not None else ReplanPolicy()
        self._quiet_until = -np.inf
        self._cool = self.policy.cooldown_s
        self.checks = 0
        self.triggers = 0
        self.replans = 0
        self.last_divergence: float | None = None

    def check(self, sched) -> bool:
        """One observation; returns True iff a re-plan was committed."""
        self.checks += 1
        now = sched.now
        if now < self._quiet_until:
            return False
        if (self.policy.budget is not None
                and self.triggers >= self.policy.budget):
            return False
        div = sched.plan_divergence()
        self.last_divergence = div
        if div is None or div <= self.policy.threshold:
            self._cool = self.policy.cooldown_s   # calm: backoff resets
            return False
        self.triggers += 1
        self._quiet_until = now + self._cool
        self._cool = min(self._cool * self.policy.backoff,
                         self.policy.max_cooldown_s)
        sched.trace.events.append({"time": now, "event": "auto_replan",
                                   "divergence": float(div),
                                   "cooldown_s": float(self._quiet_until
                                                       - now)})
        out = sched.replan_last(
            min_improvement=self.policy.min_improvement)
        if out is not None:
            self.replans += 1
        return out is not None
