"""Batched decode engine (CPU-runnable reference implementation).

Drives ``serve_step`` one token at a time over a padded request batch with
greedy sampling.  Prompts are right-aligned to a common length so the whole
batch shares one scalar ``pos`` (the production TPU engine would use a
per-slot position vector + paged KV; this engine is the semantic reference
the examples and tests run end-to-end on CPU).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # [B, gen_len]
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class DecodeEngine:
    def __init__(self, cfg, params, *, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._step = jax.jit(
            functools.partial(M.serve_step, cfg))

    def generate(self, prompts: np.ndarray, gen_len: int,
                 *, extra_batch: dict | None = None) -> GenerationResult:
        """prompts: [B, P] int32 (a common prompt length P)."""
        b, p = prompts.shape
        cache = M.init_cache(self.cfg, b, self.max_len)
        assert p + gen_len <= self.max_len

        t0 = time.time()
        logits = None
        for i in range(p):  # prefill token-by-token (reference engine)
            batch = {"tokens": jnp.asarray(prompts[:, i: i + 1]),
                     "pos": jnp.int32(i), **(extra_batch or {})}
            logits, cache = self._step(self.params, cache, batch)
        jax.block_until_ready(logits)
        t1 = time.time()

        out = np.zeros((b, gen_len), np.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for j in range(gen_len):
            out[:, j] = np.asarray(tok[:, 0])
            batch = {"tokens": tok, "pos": jnp.int32(p + j),
                     **(extra_batch or {})}
            logits, cache = self._step(self.params, cache, batch)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(logits)
        t2 = time.time()
        return GenerationResult(
            tokens=out, prefill_s=t1 - t0, decode_s=t2 - t1,
            tokens_per_s=b * gen_len / max(t2 - t1, 1e-9))
