"""Batched decode engine (CPU-runnable reference implementation).

Drives ``serve_step`` over a padded request batch with greedy sampling.
Prompts are right-aligned to a common length so the whole batch shares one
scalar ``pos`` (the production TPU engine would use a per-slot position
vector + paged KV; this engine is the semantic reference the examples and
tests run end-to-end on CPU).

Prefill runs the whole prompt in **one jitted call**: a ``lax.scan`` over
the prompt positions composes the same per-token ``serve_step``, so one
dispatch replaces P host round-trips (and the XLA program sees the whole
loop).  The seed's token-by-token Python loop is kept as
``prefill_mode="per_token"`` — the parity-tested reference
(tests/test_serving.py asserts both modes emit identical tokens).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # [B, gen_len]
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class DecodeEngine:
    def __init__(self, cfg, params, *, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._step = jax.jit(
            functools.partial(M.serve_step, cfg))
        self._prefill = jax.jit(
            functools.partial(_prefill_scan, cfg))

    def generate(self, prompts: np.ndarray, gen_len: int,
                 *, extra_batch: dict | None = None,
                 prefill_mode: str = "fused") -> GenerationResult:
        """prompts: [B, P] int32 (a common prompt length P).

        ``prefill_mode``: ``"fused"`` (one jitted scan over the prompt,
        default) or ``"per_token"`` (the seed's reference loop).
        """
        b, p = prompts.shape
        cache = M.init_cache(self.cfg, b, self.max_len)
        assert p + gen_len <= self.max_len
        extra = extra_batch or {}

        t0 = time.time()
        if prefill_mode == "fused":
            logits, cache = self._prefill(self.params, cache,
                                          jnp.asarray(prompts), extra)
        elif prefill_mode == "per_token":
            logits = None
            for i in range(p):
                batch = {"tokens": jnp.asarray(prompts[:, i: i + 1]),
                         "pos": jnp.int32(i), **extra}
                logits, cache = self._step(self.params, cache, batch)
        else:
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        jax.block_until_ready(logits)
        t1 = time.time()

        out = np.zeros((b, gen_len), np.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for j in range(gen_len):
            out[:, j] = np.asarray(tok[:, 0])
            batch = {"tokens": tok, "pos": jnp.int32(p + j), **extra}
            logits, cache = self._step(self.params, cache, batch)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(logits)
        t2 = time.time()
        return GenerationResult(
            tokens=out, prefill_s=t1 - t0, decode_s=t2 - t1,
            tokens_per_s=b * gen_len / max(t2 - t1, 1e-9))


def _prefill_scan(cfg, params, cache, prompts, extra):
    """Whole-prompt prefill as one program: scan serve_step over positions.

    prompts: [B, P].  Returns (last-position logits [B, vocab], cache).
    Composing the identical per-token step keeps numerics bit-compatible
    with the reference loop while eliminating P host dispatches.  Only the
    latest logits ride in the scan carry, so peak memory stays O(B * vocab)
    like the reference loop (stacked ys would be [P, B, vocab]).
    """
    positions = jnp.arange(prompts.shape[1], dtype=jnp.int32)

    def step(carry, xs):
        cache, _ = carry
        tok, pos = xs                              # [B], scalar
        logits, cache = M.serve_step(
            cfg, params, cache, {"tokens": tok[:, None], "pos": pos, **extra})
        return (cache, logits), None

    logits0 = jnp.zeros((prompts.shape[0], cfg.padded_vocab), jnp.float32)
    (cache, logits), _ = jax.lax.scan(step, (cache, logits0),
                                      (prompts.T, positions))
    return logits, cache
