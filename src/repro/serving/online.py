"""Online serving loop: streamed arrivals against time-aware network state.

The static path solves one batch against a snapshot of the queues.  This
loop is the deployment setting: request batches arrive on a clock (Poisson,
bursty, diurnal — ``repro.core.arrivals``), and before each batch is solved
the scheduler **drains** the :class:`~repro.core.state.QueueState` to the
arrival time — the work committed by earlier batches has been getting
served in the meantime.  Two drain models are supported (``drain="fluid" |
"exact"``): the fluid model q <- max(q - mu dt, 0) serves every resource
independently at full rate (fast, optimistic), while the exact model
drains a :class:`~repro.core.completions.CommittedWork` ledger through the
event simulator's preempt-resume loop — exactly the committed jobs, with
priority and precedence.  Under sub-capacity load either keeps backlogs
(and hence latency bounds) bounded; the legacy no-drain commit loop
(``drain_queues=False``, the seed behaviour) only ever adds to Q and
diverges under any sustained traffic — ``benchmarks/online_bench.py``
captures both trajectories plus the fluid-vs-exact fidelity gap, and
``tests/test_online.py`` asserts the contrast.

``report_slowdown`` / ``replan_last`` are events on the same clock: a
straggler reported at time t degrades the *effective* topology from t on
(slower service and slower draining), and re-planning the last batch scores
it against the state at the current clock.

Per-arrival latency here is the fictitious-system completion bound of each
request measured from its arrival instant — the same quantity the solver
optimizes, now evaluated against a drained (time-correct) queue state.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import arrivals as A, completions as C, jobs as J, schedule
from repro.core.state import Topology, backlog_seconds
from .scheduler import Placement, Request, RoutedScheduler, requests_to_jobs


@dataclasses.dataclass(frozen=True)
class ArrivalRecord:
    """What happened at one arrival epoch."""

    time: float
    names: tuple[str, ...]
    latencies: tuple[float, ...]     # per-request completion bounds (s)
    backlog_before: float            # worst-resource wait (s) after draining
    backlog_after: float             # ... after committing this batch
    solve_s: float


@dataclasses.dataclass
class OnlineTrace:
    """Recorded trajectory of one online run.

    ``completions`` holds absolute completion times recorded by the exact
    drain (keyed by job name); ``replay_completions`` holds the
    ground-truth full-horizon event replay of the commit log (when the run
    tracked commits).  ``commit_log`` is that never-drained
    :class:`~repro.core.completions.CommittedWork` record itself — the
    fidelity benchmark replays it under exact semantics.
    """

    records: list[ArrivalRecord] = dataclasses.field(default_factory=list)
    events: list[dict] = dataclasses.field(default_factory=list)
    completions: dict[str, float] = dataclasses.field(default_factory=dict)
    replay_completions: dict[str, float] = dataclasses.field(
        default_factory=dict)
    commit_log: "C.CommittedWork | None" = None
    # Per-request *original* arrival instants (filled by submit_window):
    # a fault-requeued job is committed later under a new name but keeps
    # its original arrival here, so actual latency spans the outage.
    arrivals_by_name: dict[str, float] = dataclasses.field(
        default_factory=dict)
    # Fault-policy losses: (name, reason) for requests that will never
    # complete (shed by the lost policy, unreachable after a failure, ...).
    lost: list[tuple[str, str]] = dataclasses.field(default_factory=list)

    @property
    def times(self) -> np.ndarray:
        return np.array([r.time for r in self.records], np.float64)

    @property
    def backlogs(self) -> np.ndarray:
        """Post-commit worst-resource backlog (s) at each arrival."""
        return np.array([r.backlog_after for r in self.records], np.float64)

    @property
    def latencies(self) -> np.ndarray:
        return np.array([x for r in self.records for x in r.latencies],
                        np.float64)

    def percentile(self, q: float) -> float:
        lat = self.latencies
        return float(np.percentile(lat, q)) if lat.size else float("nan")

    def backlog_growth(self, tol: float = 1e-9) -> float:
        """max backlog over the run's second half / first half.

        ~1 for a stable (drained) system that has reached steady state;
        grows without bound for the no-drain commit loop.  A run whose
        backlog never exceeds ``tol`` in *either* half (low-load streams
        that fully drain between arrivals) is flat by definition and
        returns exactly 1.0 — dividing by the floor would report a
        meaningless ~1e12 "growth" from numerical dust.
        """
        b = self.backlogs
        if b.size < 4:
            return float("nan")
        half = b.size // 2
        first, second = float(b[:half].max()), float(b[half:].max())
        if first <= tol and second <= tol:
            return 1.0
        return float(second / max(first, 1e-12))

    def actual_latencies(self) -> np.ndarray:
        """Per-request *actual* latency (completion - arrival), aligned with
        :attr:`latencies` where completion times are known.

        Uses the exact drain's recorded completions, falling back to the
        ground-truth replay record; requests with no known completion are
        skipped (run with ``finish=True`` to complete every job).  Arrival
        instants come from :attr:`arrivals_by_name` where recorded (a
        fault-requeued job keeps its original arrival), else the commit
        record's time.
        """
        comps = self.completions or self.replay_completions
        return np.array(
            [comps[n] - self.arrivals_by_name.get(n, r.time)
             for r in self.records for n in r.names if n in comps],
            np.float64)

    def summary(self) -> dict:
        out = {
            "arrivals": len(self.records),
            "requests": int(self.latencies.size),
            "p50_latency_s": self.percentile(50),
            "p99_latency_s": self.percentile(99),
            "max_backlog_s": float(self.backlogs.max()) if self.records else 0.0,
            "final_backlog_s": self.records[-1].backlog_after if self.records else 0.0,
            "backlog_growth": self.backlog_growth(),
        }
        act = self.actual_latencies()
        if act.size:
            out["p50_actual_s"] = float(np.percentile(act, 50))
            out["p99_actual_s"] = float(np.percentile(act, 99))
        if self.lost:
            out["lost"] = len(self.lost)
        return out

    def to_dict(self) -> dict:
        # ``names``/``completions``/``replay_completions`` carry the exact
        # drain's results (PR 4/5): without them a serialized trace loses
        # every actual (ground-truth) completion time and the
        # actual-latency percentiles the summary derives from them.
        return {
            **self.summary(),
            "times": self.times.tolist(),
            "names": [list(r.names) for r in self.records],
            "backlogs": self.backlogs.tolist(),
            "latencies": self.latencies.tolist(),
            "actual_latencies": self.actual_latencies().tolist(),
            "completions": dict(self.completions),
            "replay_completions": dict(self.replay_completions),
            "events": self.events,
        }


class OnlineScheduler(RoutedScheduler):
    """RoutedScheduler + a clock: drains state to each event before acting.

    ``drain_queues=False`` reproduces the legacy behaviour (queues only
    grow) for divergence comparisons; ``drain="fluid" | "exact"`` picks the
    drain *model* (rate-capacity fluid vs per-plan completion tracking —
    see :mod:`repro.core.completions`); everything else is identical, so
    any gap between two runs is the drain semantics alone.
    """

    def __init__(self, net: Topology, *, method: str = "greedy",
                 drain_queues: bool = True, **solver_opts):
        super().__init__(net, method=method, **solver_opts)
        self.drain_queues = drain_queues
        self.trace = OnlineTrace()

    # -- clock --------------------------------------------------------------
    @property
    def now(self) -> float:
        """Event time == the scheduler's one authoritative clock."""
        return self.clock

    def advance_to(self, t: float) -> None:
        """Move the clock to absolute time ``t``, draining if enabled.

        The clock always advances — time passing and queue draining are
        independent; ``drain_queues=False`` freezes only the backlogs.
        """
        # Relative tolerance (schedule.time_eps): an absolute 1e-9 slack is
        # below one ulp of the clock once it passes ~2^20 s, so the guard
        # would start rejecting legitimate same-instant events at large
        # clocks (PR 5 converted the other absolute guards; this one was
        # missed).
        if t < self.now - schedule.time_eps(self.now):
            raise ValueError(f"time went backwards: {t} < {self.now}")
        dt = max(t - self.now, 0.0)
        if dt > 0 and self.drain_queues:
            # drains at effective (health-aware) rates, fluid or exact
            self._drain_state(dt)
        self._now = max(self._now, float(t))
        self._stamp_clock()

    # -- events -------------------------------------------------------------
    def submit_jobs(self, t: float, infer_jobs: Sequence[J.InferenceJob],
                    *, pad_to: int | None = None) -> list[Placement]:
        """Arrival event: drain to ``t``, place the batch, record the epoch."""
        return self.submit_window(t, infer_jobs, pad_to=pad_to)

    def submit_window(self, t: float, infer_jobs: Sequence[J.InferenceJob],
                      *, arrivals: Sequence[float] | None = None,
                      pad_to: int | None = None,
                      solve_mode: str = "batched",
                      method: str | None = None) -> list[Placement]:
        """Window-batched submission (the streaming pipeline's hook).

        ``t`` is the *commit* instant: the state drains to it and the whole
        window is placed there in one scheduler entry (one drain sync, one
        backlog accounting pass, one trace record).  ``solve_mode`` picks
        the solver shape inside that entry: ``"batched"`` runs one padded
        batched solve over the window (``batch_jobs(pad_to=)`` operand —
        the accelerator-friendly shape); ``"sequential"`` runs one width-1
        solve per request in window order against the evolving queue state
        — exactly the plans the serial loop would commit for coincident
        arrivals, with none of the padded batch's extra per-round
        evaluation work.  ``arrivals`` gives each request's own arrival
        instant (aligned with ``infer_jobs``); the recorded per-request
        latency is then queueing wait plus the solver's completion bound,
        ``(t - arrival_i) + bound_i`` — the quantity a batching window
        actually delivers.  With ``arrivals`` omitted every request
        arrived at ``t`` and this is exactly :meth:`submit_jobs`; names
        within a window must be unique (they key the wait accounting and
        the exact-drain completions).  After either mode ``last_solve_s``
        holds the window's total solve wall.
        """
        if solve_mode not in ("batched", "sequential"):
            raise ValueError(f"solve_mode must be 'batched' or "
                             f"'sequential', got {solve_mode!r}")
        wait = None
        if arrivals is not None:
            if len(arrivals) != len(infer_jobs):
                raise ValueError(
                    f"arrivals ({len(arrivals)}) must align with infer_jobs "
                    f"({len(infer_jobs)})")
            names = [j.name for j in infer_jobs]
            if len(set(names)) != len(names):
                raise ValueError("window job names must be unique")
            wait = {j.name: float(t) - float(a)
                    for j, a in zip(infer_jobs, arrivals)}
        self.advance_to(t)
        eff = self._effective_topology()
        before = backlog_seconds(eff, self.state)
        if solve_mode == "sequential" and len(infer_jobs) > 1:
            placements, walls = [], 0.0
            for job in infer_jobs:
                placements.extend(self.schedule_jobs([job], pad_to=pad_to,
                                                     method=method))
                walls += self.last_solve_s
            self.last_solve_s = walls
        else:
            placements = self.schedule_jobs(list(infer_jobs), pad_to=pad_to,
                                            method=method)
        after = backlog_seconds(eff, self.state)
        arrs = arrivals if arrivals is not None else [t] * len(infer_jobs)
        self.trace.arrivals_by_name.update(
            {j.name: float(a) for j, a in zip(infer_jobs, arrs)})
        self.trace.records.append(ArrivalRecord(
            time=t,
            names=tuple(p.job_name for p in placements),
            latencies=tuple(p.bound_s if wait is None
                            else wait[p.job_name] + p.bound_s
                            for p in placements),
            backlog_before=before,
            backlog_after=after,
            solve_s=self.last_solve_s,
        ))
        return placements

    def submit_windows(self, t: float,
                       windows: Sequence[Sequence[J.InferenceJob]],
                       *, arrivals: Sequence[Sequence[float]] | None = None,
                       pad_to: int | None = None,
                       method: str | None = None) -> list[list[Placement]]:
        """Cross-arrival fused submission: W queued windows, one dispatch.

        All windows commit at instant ``t`` (one drain sync), solved in
        order against each other's committed queues by
        :meth:`RoutedScheduler.schedule_windows` — the same plans W
        back-to-back :meth:`submit_window` calls at ``t`` would commit,
        in a single fused device program.  One :class:`ArrivalRecord` per
        window keeps the trace shape identical to the sequential path
        (per-window ``solve_s`` is the shared dispatch's per-window
        share); ``arrivals`` aligns per-window arrival instants exactly
        as in :meth:`submit_window`.
        """
        windows = [list(w) for w in windows]
        if arrivals is not None and len(arrivals) != len(windows):
            raise ValueError(f"arrivals ({len(arrivals)}) must align with "
                             f"windows ({len(windows)})")
        waits: list[dict[str, float] | None] = [None] * len(windows)
        if arrivals is not None:
            for w, (jobs, arrs) in enumerate(zip(windows, arrivals)):
                if len(arrs) != len(jobs):
                    raise ValueError(
                        f"window {w}: arrivals ({len(arrs)}) must align "
                        f"with jobs ({len(jobs)})")
                names = [j.name for j in jobs]
                if len(set(names)) != len(names):
                    raise ValueError("window job names must be unique")
                waits[w] = {j.name: float(t) - float(a)
                            for j, a in zip(jobs, arrs)}
        self.advance_to(t)
        eff = self._effective_topology()
        before = backlog_seconds(eff, self.state)
        per_window = self.schedule_windows(windows, pad_to=pad_to,
                                           method=method)
        walls = 0.0
        for w, (jobs, placements) in enumerate(zip(windows, per_window)):
            arrs = (arrivals[w] if arrivals is not None
                    else [t] * len(jobs))
            self.trace.arrivals_by_name.update(
                {j.name: float(a) for j, a in zip(jobs, arrs)})
            # Backlogs come from the scheduler's per-window post-commit
            # snapshots (ledger-synced in exact mode), so the recorded
            # telemetry matches what W submit_window calls would have read
            # — not the solver's fluid committed queues, which differ from
            # the ledger materialization in the last ulp.
            after = backlog_seconds(eff, self._window_states[w])
            solve_w = float(placements[0].plan.meta.get(
                "solve_share_s", placements[0].plan.meta.get("solve_s", 0.0)))
            walls += solve_w
            wait = waits[w]
            self.trace.records.append(ArrivalRecord(
                time=t,
                names=tuple(p.job_name for p in placements),
                latencies=tuple(p.bound_s if wait is None
                                else wait[p.job_name] + p.bound_s
                                for p in placements),
                backlog_before=before,
                backlog_after=after,
                solve_s=solve_w,
            ))
            before = after
        self.last_solve_s = walls
        return per_window

    def submit(self, t: float, requests: list[Request],
               *, pad_to: int | None = None) -> list[Placement]:
        return self.submit_jobs(t, requests_to_jobs(requests), pad_to=pad_to)

    def report_slowdown(self, node: int, factor: float,
                        *, at: float | None = None) -> None:
        """Straggler event on the clock: drain to ``at`` (default: now),
        then degrade the node's effective rate from that instant on
        (``factor=2`` means half speed; must be finite and > 0)."""
        self._check_slowdown(node, factor)  # reject before the clock moves
        if at is not None:
            self.advance_to(at)
        super().report_slowdown(node, factor)
        self.trace.events.append({"time": self.now, "event": "slowdown",
                                  "node": int(node), "factor": float(factor)})

    def report_recovery(self, node: int, *, at: float | None = None) -> None:
        """Recovery event on the clock: drain to ``at`` (default: now) at
        the still-degraded rates, then restore the node to full health."""
        self._check_slowdown(node, 1.0)     # reject before the clock moves
        if at is not None:
            self.advance_to(at)
        RoutedScheduler.report_slowdown(self, node, 1.0)
        self.trace.events.append({"time": self.now, "event": "recovery",
                                  "node": int(node)})

    def set_node_availability(self, node: int, up: bool,
                              *, at: float | None = None) -> None:
        """Availability event on the clock: drain to ``at`` (default: now)
        under the pre-event health, then fail/recover the node."""
        self._check_node(node)              # reject before the clock moves
        if at is not None:
            self.advance_to(at)
        super().set_node_availability(node, up)
        self.trace.events.append(
            {"time": self.now, "event": "node_up" if up else "node_down",
             "node": int(node)})

    def set_link_availability(self, u: int, v: int, up: bool,
                              *, at: float | None = None) -> None:
        """Directed-link availability event on the clock (see
        :meth:`set_node_availability`)."""
        self._check_node(u), self._check_node(v)
        if at is not None:
            self.advance_to(at)
        super().set_link_availability(u, v, up)
        self.trace.events.append(
            {"time": self.now, "event": "link_up" if up else "link_down",
             "link": (int(u), int(v))})

    def replan_last(self) -> list[Placement] | None:
        out = super().replan_last()
        if out is not None:
            self.trace.events.append({"time": self.now, "event": "replan",
                                      "bound_s": self.last_plan.bound()})
            # The last arrival record described the superseded plan; refresh
            # it so bound-vs-actual comparisons stay honest.  The new bound
            # is measured from *now*, so from the original arrival instant
            # the completion bound is (now - arrival) + new bound.
            rec = self.trace.records[-1] if self.trace.records else None
            if rec is not None and set(rec.names) == {p.job_name
                                                      for p in out}:
                bound_by_name = {p.job_name: p.bound_s for p in out}
                wait = self.now - rec.time
                self.trace.records[-1] = dataclasses.replace(
                    rec,
                    latencies=tuple(wait + bound_by_name[n]
                                    for n in rec.names),
                    backlog_after=backlog_seconds(
                        self._effective_topology(), self.state))
        return out

    # -- end-of-run accounting -----------------------------------------------
    def finish(self) -> dict[str, float]:
        """Serve all committed work to completion under exact semantics.

        Requires ``drain="exact"``.  The clock jumps to the last
        completion, the queues empty, and every job's absolute completion
        time lands in ``trace.completions`` (and is returned).
        """
        if self.ledger is None:
            raise ValueError("finish() requires drain='exact'")
        comps, self.ledger = C.run_to_completion(
            self._effective_topology(), self.ledger,
            engine=self.sim_engine, down=self._down_keys())
        self._sync_ledger_queues()
        if comps:
            self._now = max(self._now, max(comps.values()))
        self._stamp_clock()
        self.trace.completions.update(comps)
        return comps

    def replay_ground_truth(self) -> dict[str, float]:
        """Full-horizon event replay of every committed plan.

        Requires ``track_commits=True``.  Replays the never-drained commit
        log through the event simulator *piecewise*: every
        ``report_slowdown`` was recorded in the log's health history, and
        each segment replays at the effective topology actually in force
        during it (a log with no health events replays at base health in
        one segment).  Results land in ``trace.replay_completions``.
        """
        if self.commit_log is None:
            raise ValueError("replay_ground_truth() requires "
                             "track_commits=True")
        comps, _ = C.replay_piecewise(self.topology, self.commit_log,
                                      engine=self.sim_engine)
        self.trace.replay_completions.update(comps)
        self.trace.commit_log = self.commit_log
        return comps


def run_online(scenario, *, horizon: float, seed: int = 0,
               process: str = "poisson", rate: float | None = None,
               batch_size: int = 1, method: str = "greedy",
               drain_queues: bool = True, finish: bool = False,
               pad_to: int | None = None,
               process_params: dict | None = None,
               fault_schedule=None, recovery: str = "requeue",
               max_retries: int = 3,
               **solver_opts) -> OnlineTrace:
    """Drive a scenario through an arrival stream; return the trace.

    ``scenario`` is anything with ``.topology`` and
    ``.sample_jobs(rng, n) -> list[InferenceJob]`` —
    ``repro.scenarios.make_scenario(...)`` is the canonical source.

    **Process-params contract.**  ``process`` names an arrival process from
    ``repro.core.arrivals``; ``process_params`` are its keyword arguments,
    passed through verbatim and always winning over the ``rate`` shorthand.
    ``rate`` maps onto each built-in process's own parameters where the
    mapping is well-defined:

      * ``poisson`` / ``bursty`` — ``rate`` is the process's ``rate``;
      * ``diurnal`` — ``rate`` scales the whole profile: ``peak_rate =
        rate`` and ``base_rate = peak_rate / 5`` (the module defaults'
        5:1 peak:base ratio) unless given explicitly;
      * any other registered process — the shorthand is ambiguous, so
        passing ``rate`` raises ``ValueError``; use ``process_params``.

    ``drain_queues=False`` is the legacy no-drain baseline; pass
    ``drain="fluid" | "exact"`` / ``track_commits=True`` through to the
    scheduler to pick the drain model and keep a ground-truth commit log.
    ``finish=True`` completes the accounting after the last arrival: the
    exact ledger (if any) is served to completion into
    ``trace.completions`` and the commit log (if any) is replayed into
    ``trace.replay_completions``.

    ``fault_schedule`` (a :class:`~repro.serving.faults.FaultSchedule` or
    any iterable of :class:`~repro.serving.faults.FaultEvent`) injects
    infrastructure events between arrivals on the same clock; ``recovery``
    picks the policy for work caught on a failed resource (``"requeue"`` |
    ``"migrate"`` | ``"lost"``, with at most ``max_retries`` re-placements
    per job) — requires ``drain="exact"``.
    """
    rng = np.random.default_rng(seed)
    params = A.resolve_rate(process, rate, process_params)
    times = A.make_process(process, **params)(rng, horizon)
    sched = OnlineScheduler(scenario.topology, method=method,
                            drain_queues=drain_queues, **solver_opts)
    if pad_to is None:
        pad_to = getattr(scenario, "max_layers", None)
    injector, faults, fi = None, [], 0
    if fault_schedule is not None:
        from .faults import FaultInjector
        faults = sorted(fault_schedule, key=lambda ev: ev.time)
        injector = FaultInjector(sched, policy=recovery,
                                 max_retries=max_retries, pad_to=pad_to)
    for t in times:
        while fi < len(faults) and faults[fi].time <= float(t):
            injector.apply(faults[fi])
            fi += 1
        jobs = scenario.sample_jobs(rng, batch_size)
        if injector is not None and sched.degraded:
            jobs = injector.filter_arrivals(float(t), jobs)
            if not jobs:
                continue
        sched.submit_jobs(float(t), jobs, pad_to=pad_to)
    while fi < len(faults) and faults[fi].time <= horizon:
        injector.apply(faults[fi])
        fi += 1
    if finish:
        if sched.ledger is not None:
            sched.finish()
        if sched.commit_log is not None:
            sched.replay_ground_truth()
    sched.trace.commit_log = sched.commit_log
    return sched.trace
