"""Online serving loop: streamed arrivals against time-aware network state.

The static path solves one batch against a snapshot of the queues.  This
loop is the deployment setting: request batches arrive on a clock (Poisson,
bursty, diurnal — ``repro.core.arrivals``), and before each batch is solved
the scheduler **drains** the :class:`~repro.core.state.QueueState` to the
arrival time — the work committed by earlier batches has been getting
served in the meantime.  Two drain models are supported (``drain="fluid" |
"exact"``): the fluid model q <- max(q - mu dt, 0) serves every resource
independently at full rate (fast, optimistic), while the exact model
drains a :class:`~repro.core.completions.CommittedWork` ledger through the
event simulator's preempt-resume loop — exactly the committed jobs, with
priority and precedence.  Under sub-capacity load either keeps backlogs
(and hence latency bounds) bounded; the legacy no-drain commit loop
(``drain_queues=False``, the seed behaviour) only ever adds to Q and
diverges under any sustained traffic — ``benchmarks/online_bench.py``
captures both trajectories plus the fluid-vs-exact fidelity gap, and
``tests/test_online.py`` asserts the contrast.

``report_slowdown`` / ``replan_last`` are events on the same clock: a
straggler reported at time t degrades the *effective* topology from t on
(slower service and slower draining), and re-planning the last batch scores
it against the state at the current clock.

Per-arrival latency here is the fictitious-system completion bound of each
request measured from its arrival instant — the same quantity the solver
optimizes, now evaluated against a drained (time-correct) queue state.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import arrivals as A, completions as C, jobs as J, schedule
from repro.core.state import Topology, backlog_seconds
from .admission import (AdmissionController, AdmissionPolicy, ReplanMonitor,
                        ReplanPolicy)
from .scheduler import Placement, Request, RoutedScheduler, requests_to_jobs


@dataclasses.dataclass(frozen=True)
class ArrivalRecord:
    """What happened at one arrival epoch."""

    time: float
    names: tuple[str, ...]
    latencies: tuple[float, ...]     # per-request completion bounds (s)
    backlog_before: float            # worst-resource wait (s) after draining
    backlog_after: float             # ... after committing this batch
    solve_s: float


@dataclasses.dataclass
class OnlineTrace:
    """Recorded trajectory of one online run.

    ``completions`` holds absolute completion times recorded by the exact
    drain (keyed by job name); ``replay_completions`` holds the
    ground-truth full-horizon event replay of the commit log (when the run
    tracked commits).  ``commit_log`` is that never-drained
    :class:`~repro.core.completions.CommittedWork` record itself — the
    fidelity benchmark replays it under exact semantics.
    """

    records: list[ArrivalRecord] = dataclasses.field(default_factory=list)
    events: list[dict] = dataclasses.field(default_factory=list)
    completions: dict[str, float] = dataclasses.field(default_factory=dict)
    replay_completions: dict[str, float] = dataclasses.field(
        default_factory=dict)
    commit_log: "C.CommittedWork | None" = None
    # Per-request *original* arrival instants (filled by submit_window):
    # a fault-requeued job is committed later under a new name but keeps
    # its original arrival here, so actual latency spans the outage.
    arrivals_by_name: dict[str, float] = dataclasses.field(
        default_factory=dict)
    # Fault-policy losses: (name, reason) for requests that will never
    # complete (shed by the lost policy, unreachable after a failure, ...).
    lost: list[tuple[str, str]] = dataclasses.field(default_factory=list)
    # Requests dropped before commit, one dict each: {"time", "name",
    # "reason", "arrival", ...}.  The admission layer sheds here with
    # reasons ``admission_reject`` / ``deadline_miss`` (a deferred-then-
    # expired arrival is charged from its ORIGINAL arrival time); the
    # streaming pipeline adds ``solver_error`` / ``arrival_unroutable``.
    shed: list[dict] = dataclasses.field(default_factory=list)
    # Live view of the AdmissionController's audit counters (assessed /
    # admitted / rejected / deferred / expired) when admission is on.
    admission: dict = dataclasses.field(default_factory=dict)
    # Relative SLO of every *committed* request that carried one (shed
    # requests keep their deadline inside the shed record).
    deadlines_by_name: dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def times(self) -> np.ndarray:
        return np.array([r.time for r in self.records], np.float64)

    @property
    def backlogs(self) -> np.ndarray:
        """Post-commit worst-resource backlog (s) at each arrival."""
        return np.array([r.backlog_after for r in self.records], np.float64)

    @property
    def latencies(self) -> np.ndarray:
        return np.array([x for r in self.records for x in r.latencies],
                        np.float64)

    def percentile(self, q: float) -> float:
        lat = self.latencies
        return float(np.percentile(lat, q)) if lat.size else float("nan")

    def backlog_growth(self, tol: float = 1e-9) -> float:
        """max backlog over the run's second half / first half.

        ~1 for a stable (drained) system that has reached steady state;
        grows without bound for the no-drain commit loop.  A run whose
        backlog never exceeds ``tol`` in *either* half (low-load streams
        that fully drain between arrivals) is flat by definition and
        returns exactly 1.0 — dividing by the floor would report a
        meaningless ~1e12 "growth" from numerical dust.
        """
        b = self.backlogs
        if b.size < 4:
            return float("nan")
        half = b.size // 2
        first, second = float(b[:half].max()), float(b[half:].max())
        if first <= tol and second <= tol:
            return 1.0
        return float(second / max(first, 1e-12))

    def actual_latencies(self) -> np.ndarray:
        """Per-request *actual* latency (completion - arrival), aligned with
        :attr:`latencies` where completion times are known.

        Uses the exact drain's recorded completions, falling back to the
        ground-truth replay record; requests with no known completion are
        skipped (run with ``finish=True`` to complete every job).  Arrival
        instants come from :attr:`arrivals_by_name` where recorded (a
        fault-requeued job keeps its original arrival), else the commit
        record's time.
        """
        comps = self.completions or self.replay_completions
        return np.array(
            [comps[n] - self.arrivals_by_name.get(n, r.time)
             for r in self.records for n in r.names if n in comps],
            np.float64)

    def shed_by_reason(self) -> dict[str, int]:
        by: dict[str, int] = {}
        for s in self.shed:
            why = s.get("reason", "unknown")
            by[why] = by.get(why, 0) + 1
        return by

    def slo_stats(self) -> dict | None:
        """SLO accounting over requests that carried a finite deadline.

        A committed request *meets* its SLO when its actual completion
        (exact drain, falling back to the ground-truth replay) lands
        within ``deadline_s`` of its original arrival; requests shed by
        admission (``admission_reject`` / ``deadline_miss``) count as
        misses against the offered load; committed requests whose
        completion was never recorded (run without ``finish=True``) are
        reported as pending and excluded from the rate.  Returns None
        when no request ever carried a deadline.
        """
        gated = [s for s in self.shed
                 if s["reason"] in ("admission_reject", "deadline_miss")]
        if not self.deadlines_by_name and not gated:
            return None
        comps = self.completions or self.replay_completions
        met = late = pending = 0
        for name, d in self.deadlines_by_name.items():
            if name not in comps:
                pending += 1
                continue
            lat = comps[name] - self.arrivals_by_name.get(name, 0.0)
            if lat <= d + schedule.time_eps(d):
                met += 1
            else:
                late += 1
        decided = met + late + len(gated)
        out = {"offered": decided + pending, "met": met, "late": late,
               "shed": len(gated), "pending": pending, "goodput": met}
        if decided:
            out["slo_miss_rate"] = (late + len(gated)) / decided
        return out

    def summary(self) -> dict:
        out = {
            "arrivals": len(self.records),
            "requests": int(self.latencies.size),
            "p50_latency_s": self.percentile(50),
            "p99_latency_s": self.percentile(99),
            "max_backlog_s": float(self.backlogs.max()) if self.records else 0.0,
            "final_backlog_s": self.records[-1].backlog_after if self.records else 0.0,
            "backlog_growth": self.backlog_growth(),
        }
        act = self.actual_latencies()
        if act.size:
            out["p50_actual_s"] = float(np.percentile(act, 50))
            out["p99_actual_s"] = float(np.percentile(act, 99))
        if self.lost:
            out["lost"] = len(self.lost)
        if self.shed:
            out["shed"] = len(self.shed)
            out["shed_by_reason"] = self.shed_by_reason()
        if self.admission:
            out["admission"] = dict(self.admission)
        replans = sum(1 for e in self.events if e.get("event") == "replan")
        autos = sum(1 for e in self.events if e.get("event") == "auto_replan")
        skipped: dict[str, int] = {}
        for e in self.events:
            if e.get("event") == "replan_skipped":
                r = e.get("reason") or "unknown"
                skipped[r] = skipped.get(r, 0) + 1
        if replans or autos or skipped:
            out["replans"] = replans
            if autos:
                out["auto_replan_triggers"] = autos
            if skipped:
                out["replans_skipped"] = skipped
        slo = self.slo_stats()
        if slo is not None:
            out["slo"] = slo
        return out

    def to_dict(self) -> dict:
        # ``names``/``completions``/``replay_completions`` carry the exact
        # drain's results (PR 4/5): without them a serialized trace loses
        # every actual (ground-truth) completion time and the
        # actual-latency percentiles the summary derives from them.
        return {
            **self.summary(),
            "times": self.times.tolist(),
            "names": [list(r.names) for r in self.records],
            "backlogs": self.backlogs.tolist(),
            "latencies": self.latencies.tolist(),
            "actual_latencies": self.actual_latencies().tolist(),
            "completions": dict(self.completions),
            "replay_completions": dict(self.replay_completions),
            "events": self.events,
            "shed": list(self.shed),
        }


class OnlineScheduler(RoutedScheduler):
    """RoutedScheduler + a clock: drains state to each event before acting.

    ``drain_queues=False`` reproduces the legacy behaviour (queues only
    grow) for divergence comparisons; ``drain="fluid" | "exact"`` picks the
    drain *model* (rate-capacity fluid vs per-plan completion tracking —
    see :mod:`repro.core.completions`); everything else is identical, so
    any gap between two runs is the drain semantics alone.
    """

    def __init__(self, net: Topology, *, method: str = "greedy",
                 drain_queues: bool = True,
                 admission: "AdmissionController | AdmissionPolicy | str | None" = None,
                 auto_replan: "ReplanMonitor | ReplanPolicy | bool | None" = None,
                 **solver_opts):
        super().__init__(net, method=method, **solver_opts)
        self.drain_queues = drain_queues
        self.trace = OnlineTrace()
        if admission is None or isinstance(admission, AdmissionController):
            self.admission = admission
        else:
            self.admission = AdmissionController(admission)
        if self.admission is not None:
            # Live view: the controller mutates this same dict, so the
            # trace summary always reflects current counters.
            self.trace.admission = self.admission.counters
        if auto_replan is None or auto_replan is False:
            self.monitor = None
        elif auto_replan is True:
            self.monitor = ReplanMonitor()
        elif isinstance(auto_replan, ReplanMonitor):
            self.monitor = auto_replan
        else:
            self.monitor = ReplanMonitor(auto_replan)

    # -- clock --------------------------------------------------------------
    @property
    def now(self) -> float:
        """Event time == the scheduler's one authoritative clock."""
        return self.clock

    def advance_to(self, t: float) -> None:
        """Move the clock to absolute time ``t``, draining if enabled.

        The clock always advances — time passing and queue draining are
        independent; ``drain_queues=False`` freezes only the backlogs.
        """
        # Relative tolerance (schedule.time_eps): an absolute 1e-9 slack is
        # below one ulp of the clock once it passes ~2^20 s, so the guard
        # would start rejecting legitimate same-instant events at large
        # clocks (PR 5 converted the other absolute guards; this one was
        # missed).
        if t < self.now - schedule.time_eps(self.now):
            raise ValueError(f"time went backwards: {t} < {self.now}")
        dt = max(t - self.now, 0.0)
        if dt > 0 and self.drain_queues:
            # drains at effective (health-aware) rates, fluid or exact
            self._drain_state(dt)
        self._now = max(self._now, float(t))
        self._stamp_clock()

    # -- events -------------------------------------------------------------
    def submit_jobs(self, t: float, infer_jobs: Sequence[J.InferenceJob],
                    *, pad_to: int | None = None) -> list[Placement]:
        """Arrival event: drain to ``t``, place the batch, record the epoch."""
        return self.submit_window(t, infer_jobs, pad_to=pad_to)

    def submit_window(self, t: float, infer_jobs: Sequence[J.InferenceJob],
                      *, arrivals: Sequence[float] | None = None,
                      pad_to: int | None = None,
                      solve_mode: str = "batched",
                      method: str | None = None) -> list[Placement]:
        """Window-batched submission (the streaming pipeline's hook).

        ``t`` is the *commit* instant: the state drains to it and the whole
        window is placed there in one scheduler entry (one drain sync, one
        backlog accounting pass, one trace record).  ``solve_mode`` picks
        the solver shape inside that entry: ``"batched"`` runs one padded
        batched solve over the window (``batch_jobs(pad_to=)`` operand —
        the accelerator-friendly shape); ``"sequential"`` runs one width-1
        solve per request in window order against the evolving queue state
        — exactly the plans the serial loop would commit for coincident
        arrivals, with none of the padded batch's extra per-round
        evaluation work.  ``arrivals`` gives each request's own arrival
        instant (aligned with ``infer_jobs``); the recorded per-request
        latency is then queueing wait plus the solver's completion bound,
        ``(t - arrival_i) + bound_i`` — the quantity a batching window
        actually delivers.  With ``arrivals`` omitted every request
        arrived at ``t`` and this is exactly :meth:`submit_jobs`; names
        within a window must be unique (they key the wait accounting and
        the exact-drain completions).  After either mode ``last_solve_s``
        holds the window's total solve wall.
        """
        if solve_mode not in ("batched", "sequential"):
            raise ValueError(f"solve_mode must be 'batched' or "
                             f"'sequential', got {solve_mode!r}")
        jobs = list(infer_jobs)
        if arrivals is not None and len(arrivals) != len(jobs):
            raise ValueError(
                f"arrivals ({len(arrivals)}) must align with infer_jobs "
                f"({len(jobs)})")
        arrs = ([float(a) for a in arrivals] if arrivals is not None
                else [float(t)] * len(jobs))
        track_wait = arrivals is not None
        ctl = self.admission
        if ctl is not None and not ctl.external_defer and ctl.deferred:
            # Deferred arrivals ride the next window with their ORIGINAL
            # arrival instants (wait accounting spans the deferral).
            for job, a0 in ctl.pop_deferred():
                jobs.append(job)
                arrs.append(float(a0))
            track_wait = True
        if track_wait:
            names = [j.name for j in jobs]
            if len(set(names)) != len(names):
                raise ValueError("window job names must be unique")
        self.advance_to(t)
        eff = self._effective_topology()
        before = backlog_seconds(eff, self.state)
        reuse, assess_s = None, 0.0
        if ctl is not None and ctl.active(jobs):
            jobs, arrs, reuse, assess_s = self._assess_admission(
                float(t), jobs, arrs, eff, pad_to=pad_to, method=method)
            track_wait = True
        self.trace.deadlines_by_name.update(
            {j.name: j.deadline_s for j in jobs
             if np.isfinite(j.deadline_s)})
        if ctl is not None and not jobs:
            # Admission shed/deferred the whole window: nothing to commit,
            # the shed records already tell the story.
            self.last_solve_s = assess_s
            self.total_solve_s += assess_s
            self.check_replan()
            return []
        wait = ({j.name: float(t) - a for j, a in zip(jobs, arrs)}
                if track_wait else None)
        if solve_mode == "sequential" and len(jobs) > 1:
            placements, walls = [], 0.0
            for job in jobs:
                placements.extend(self.schedule_jobs([job], pad_to=pad_to,
                                                     method=method))
                walls += self.last_solve_s
            self.last_solve_s = walls + assess_s
            self.total_solve_s += assess_s
        elif reuse is not None:
            # Every candidate was admitted: commit the assessment's own
            # solve — admission adds no second dispatch on this path.
            placements = self.commit_presolved(jobs, *reuse)
        else:
            placements = self.schedule_jobs(jobs, pad_to=pad_to,
                                            method=method)
            self.last_solve_s += assess_s
            self.total_solve_s += assess_s
        after = backlog_seconds(eff, self.state)
        self.trace.arrivals_by_name.update(
            {j.name: a for j, a in zip(jobs, arrs)})
        self.trace.records.append(ArrivalRecord(
            time=t,
            names=tuple(p.job_name for p in placements),
            latencies=tuple(p.bound_s if wait is None
                            else wait[p.job_name] + p.bound_s
                            for p in placements),
            backlog_before=before,
            backlog_after=after,
            solve_s=self.last_solve_s,
        ))
        self.check_replan()
        return placements

    def _assess_admission(self, t: float, jobs: list[J.InferenceJob],
                          arrs: list[float], eff: Topology,
                          *, pad_to: int | None, method: str | None):
        """Score one candidate window against its SLOs before committing.

        Pure-solves the whole window (:meth:`~RoutedScheduler.presolve`),
        releases the candidate plan into a *fork* of the live simulation
        (:func:`repro.core.completions.predict_completions` — nothing
        committed), and partitions: a request whose predicted latency
        exceeds ``deadline_s - margin_s`` is shed (``reject``) or parked
        (``defer``).  Falls back to wait + fictitious-system bound when
        there is no exact ledger, or while an outage strands committed
        work (the fork cannot drain to quiescence then).  Returns
        ``(kept_jobs, kept_arrivals, reusable (batch, plan) | None,
        assessment wall)`` — the plan is reusable only when every
        candidate was admitted, otherwise the committed job set differs
        from the assessed batch.
        """
        ctl = self.admission
        ctl.counters["assessed"] += len(jobs)
        batch, plan = self.presolve(jobs, pad_to=pad_to, method=method)
        assess_s = float(plan.meta.get("solve_s", 0.0))
        names = [j.name for j in jobs]
        bounds = np.asarray(plan.bounds, np.float64)
        preds = None
        if self.ledger is not None:
            cand = plan
            if cand.paths is None:
                _, paths, _ = schedule.replay_solution(
                    eff.view(self.state), batch, plan.assign, plan.order)
                cand = dataclasses.replace(plan, paths=paths)
            try:
                preds = C.predict_completions(
                    eff, self.ledger, extra_plans=[(batch, cand, names)],
                    at=t, down=self._down_keys())
            except RuntimeError:
                preds = None
        keep_jobs, keep_arrs = [], []
        for i, (job, a) in enumerate(zip(jobs, arrs)):
            if preds is not None:
                predicted = float(preds[job.name]) - a
            else:
                predicted = (t - a) + float(bounds[i])
            if ctl.admits(predicted, job.deadline_s):
                keep_jobs.append(job)
                keep_arrs.append(a)
                ctl.counters["admitted"] += 1
                continue
            if t - a > job.deadline_s or ctl.final:
                # Already expired (or end-of-stream drain-out): charged as
                # a deadline miss from the ORIGINAL arrival, whatever the
                # policy — deferring again could never help.
                ctl.counters["expired"] += 1
                self._shed_admission(t, job, a, predicted, "deadline_miss")
            elif ctl.policy.policy == "reject":
                ctl.counters["rejected"] += 1
                self._shed_admission(t, job, a, predicted,
                                     "admission_reject")
            else:
                ctl.counters["deferred"] += 1
                ctl.deferred.append((job, a))
                self.trace.events.append(
                    {"time": t, "event": "admission_defer",
                     "name": job.name, "arrival": a,
                     "predicted_s": predicted,
                     "deadline_s": job.deadline_s})
        reuse = (batch, plan) if len(keep_jobs) == len(jobs) else None
        return keep_jobs, keep_arrs, reuse, assess_s

    def _shed_admission(self, t: float, job: J.InferenceJob, arrival: float,
                        predicted: float, reason: str) -> None:
        self.trace.arrivals_by_name.setdefault(job.name, float(arrival))
        self.trace.shed.append({
            "time": float(t), "name": job.name, "reason": reason,
            "arrival": float(arrival), "deadline_s": float(job.deadline_s),
            "predicted_s": float(predicted)})

    def flush_deferred(self, *, at: float | None = None,
                       pad_to: int | None = None) -> list[Placement]:
        """End-of-stream admission sweep: re-assess every still-deferred
        arrival at ``at`` (default: now) in drain-out mode — admitted ones
        commit, predicted misses are shed as ``deadline_miss`` (never
        re-deferred, so the sweep terminates)."""
        ctl = self.admission
        if ctl is None or not ctl.deferred:
            return []
        t = self.now if at is None else max(float(at), self.now)
        ctl.final = True
        try:
            return self.submit_window(t, [], pad_to=pad_to)
        finally:
            ctl.final = False

    def submit_windows(self, t: float,
                       windows: Sequence[Sequence[J.InferenceJob]],
                       *, arrivals: Sequence[Sequence[float]] | None = None,
                       pad_to: int | None = None,
                       method: str | None = None) -> list[list[Placement]]:
        """Cross-arrival fused submission: W queued windows, one dispatch.

        All windows commit at instant ``t`` (one drain sync), solved in
        order against each other's committed queues by
        :meth:`RoutedScheduler.schedule_windows` — the same plans W
        back-to-back :meth:`submit_window` calls at ``t`` would commit,
        in a single fused device program.  One :class:`ArrivalRecord` per
        window keeps the trace shape identical to the sequential path
        (per-window ``solve_s`` is the shared dispatch's per-window
        share); ``arrivals`` aligns per-window arrival instants exactly
        as in :meth:`submit_window`.
        """
        if self.admission is not None and (self.admission.gating
                                           or self.admission.deferred):
            raise ValueError(
                "admission control gates windows one at a time — use "
                "submit_window (fused multi-window dispatch would commit "
                "candidates before they can be assessed)")
        windows = [list(w) for w in windows]
        if arrivals is not None and len(arrivals) != len(windows):
            raise ValueError(f"arrivals ({len(arrivals)}) must align with "
                             f"windows ({len(windows)})")
        waits: list[dict[str, float] | None] = [None] * len(windows)
        if arrivals is not None:
            for w, (jobs, arrs) in enumerate(zip(windows, arrivals)):
                if len(arrs) != len(jobs):
                    raise ValueError(
                        f"window {w}: arrivals ({len(arrs)}) must align "
                        f"with jobs ({len(jobs)})")
                names = [j.name for j in jobs]
                if len(set(names)) != len(names):
                    raise ValueError("window job names must be unique")
                waits[w] = {j.name: float(t) - float(a)
                            for j, a in zip(jobs, arrs)}
        self.advance_to(t)
        eff = self._effective_topology()
        before = backlog_seconds(eff, self.state)
        per_window = self.schedule_windows(windows, pad_to=pad_to,
                                           method=method)
        walls = 0.0
        for w, (jobs, placements) in enumerate(zip(windows, per_window)):
            arrs = (arrivals[w] if arrivals is not None
                    else [t] * len(jobs))
            self.trace.arrivals_by_name.update(
                {j.name: float(a) for j, a in zip(jobs, arrs)})
            # Backlogs come from the scheduler's per-window post-commit
            # snapshots (ledger-synced in exact mode), so the recorded
            # telemetry matches what W submit_window calls would have read
            # — not the solver's fluid committed queues, which differ from
            # the ledger materialization in the last ulp.
            after = backlog_seconds(eff, self._window_states[w])
            solve_w = float(placements[0].plan.meta.get(
                "solve_share_s", placements[0].plan.meta.get("solve_s", 0.0)))
            walls += solve_w
            wait = waits[w]
            self.trace.records.append(ArrivalRecord(
                time=t,
                names=tuple(p.job_name for p in placements),
                latencies=tuple(p.bound_s if wait is None
                                else wait[p.job_name] + p.bound_s
                                for p in placements),
                backlog_before=before,
                backlog_after=after,
                solve_s=solve_w,
            ))
            before = after
        self.last_solve_s = walls
        return per_window

    def submit(self, t: float, requests: list[Request],
               *, pad_to: int | None = None) -> list[Placement]:
        return self.submit_jobs(t, requests_to_jobs(requests), pad_to=pad_to)

    def report_slowdown(self, node: int, factor: float,
                        *, at: float | None = None) -> None:
        """Straggler event on the clock: drain to ``at`` (default: now),
        then degrade the node's effective rate from that instant on
        (``factor=2`` means half speed; must be finite and > 0)."""
        self._check_slowdown(node, factor)  # reject before the clock moves
        if at is not None:
            self.advance_to(at)
        super().report_slowdown(node, factor)
        self.trace.events.append({"time": self.now, "event": "slowdown",
                                  "node": int(node), "factor": float(factor)})

    def report_recovery(self, node: int, *, at: float | None = None) -> None:
        """Recovery event on the clock: drain to ``at`` (default: now) at
        the still-degraded rates, then restore the node to full health."""
        self._check_slowdown(node, 1.0)     # reject before the clock moves
        if at is not None:
            self.advance_to(at)
        RoutedScheduler.report_slowdown(self, node, 1.0)
        self.trace.events.append({"time": self.now, "event": "recovery",
                                  "node": int(node)})

    def set_node_availability(self, node: int, up: bool,
                              *, at: float | None = None) -> None:
        """Availability event on the clock: drain to ``at`` (default: now)
        under the pre-event health, then fail/recover the node."""
        self._check_node(node)              # reject before the clock moves
        if at is not None:
            self.advance_to(at)
        super().set_node_availability(node, up)
        self.trace.events.append(
            {"time": self.now, "event": "node_up" if up else "node_down",
             "node": int(node)})

    def set_link_availability(self, u: int, v: int, up: bool,
                              *, at: float | None = None) -> None:
        """Directed-link availability event on the clock (see
        :meth:`set_node_availability`)."""
        self._check_node(u), self._check_node(v)
        if at is not None:
            self.advance_to(at)
        super().set_link_availability(u, v, up)
        self.trace.events.append(
            {"time": self.now, "event": "link_up" if up else "link_down",
             "link": (int(u), int(v))})

    def replan_last(self, *, min_improvement: float | None = None
                    ) -> list[Placement] | None:
        out = super().replan_last(min_improvement=min_improvement)
        if out is None:
            # Auditable decline: no batch to re-place, or the re-solve
            # didn't clear the min_improvement gate.
            self.trace.events.append(
                {"time": self.now, "event": "replan_skipped",
                 "reason": self.last_replan_reason})
        if out is not None:
            self.trace.events.append({"time": self.now, "event": "replan",
                                      "reason": self.last_replan_reason,
                                      "bound_s": self.last_plan.bound()})
            # The last arrival record described the superseded plan; refresh
            # it so bound-vs-actual comparisons stay honest.  The new bound
            # is measured from *now*, so from the original arrival instant
            # the completion bound is (now - arrival) + new bound.
            rec = self.trace.records[-1] if self.trace.records else None
            if rec is not None and set(rec.names) == {p.job_name
                                                      for p in out}:
                bound_by_name = {p.job_name: p.bound_s for p in out}
                wait = self.now - rec.time
                self.trace.records[-1] = dataclasses.replace(
                    rec,
                    latencies=tuple(wait + bound_by_name[n]
                                    for n in rec.names),
                    backlog_after=backlog_seconds(
                        self._effective_topology(), self.state))
        return out

    # -- SLO guard ----------------------------------------------------------
    def plan_divergence(self) -> float | None:
        """How far reality has drifted from the last committed plan.

        Exact mode: forks the live simulation, predicts every last-batch
        job's completion under *current* health, and returns the worst
        relative excess over the bound it was committed with —
        ``(predicted - commit instant) / bound - 1`` (0 = on plan, 0.5 =
        running 50% over).  Fluid mode falls back to measured-vs-expected
        backlog, scaled by the plan's worst bound.  Returns None when
        there is nothing to compare (no batch committed yet, or an outage
        strands committed work so the fork cannot drain).  Read-only —
        nothing is committed or mutated.
        """
        if self._last is None or self.last_plan is None:
            return None
        _, infer_jobs, _, _, pre_now, _, _ = self._last
        bounds = np.asarray(self.last_plan.bounds, np.float64)
        if self.ledger is not None:
            try:
                preds = C.predict_completions(
                    self._effective_topology(), self.ledger,
                    down=self._down_keys())
            except RuntimeError:
                return None
            worst = None
            for i, job in enumerate(infer_jobs):
                b = float(bounds[i])
                if job.name not in preds or b <= 0:
                    continue
                div = (preds[job.name] - pre_now) / b - 1.0
                worst = div if worst is None else max(worst, div)
            return worst
        if not self.trace.records:
            return None
        rec = self.trace.records[-1]
        expected = max(rec.backlog_after - (self.now - rec.time), 0.0)
        measured = backlog_seconds(self._effective_topology(), self.state)
        return (measured - expected) / max(float(bounds.max()), 1e-9)

    def check_replan(self) -> bool:
        """One auto-replan monitor observation (no-op without
        ``auto_replan``); True iff a re-plan was committed.  Called after
        every window commit; drivers also call it after fault events."""
        return self.monitor is not None and self.monitor.check(self)

    # -- end-of-run accounting -----------------------------------------------
    def finish(self) -> dict[str, float]:
        """Serve all committed work to completion under exact semantics.

        Requires ``drain="exact"``.  The clock jumps to the last
        completion, the queues empty, and every job's absolute completion
        time lands in ``trace.completions`` (and is returned).
        """
        if self.ledger is None:
            raise ValueError("finish() requires drain='exact'")
        comps, self.ledger = C.run_to_completion(
            self._effective_topology(), self.ledger,
            engine=self.sim_engine, down=self._down_keys())
        self._sync_ledger_queues()
        if comps:
            self._now = max(self._now, max(comps.values()))
        self._stamp_clock()
        self.trace.completions.update(comps)
        return comps

    def replay_ground_truth(self) -> dict[str, float]:
        """Full-horizon event replay of every committed plan.

        Requires ``track_commits=True``.  Replays the never-drained commit
        log through the event simulator *piecewise*: every
        ``report_slowdown`` was recorded in the log's health history, and
        each segment replays at the effective topology actually in force
        during it (a log with no health events replays at base health in
        one segment).  Results land in ``trace.replay_completions``.
        """
        if self.commit_log is None:
            raise ValueError("replay_ground_truth() requires "
                             "track_commits=True")
        comps, _ = C.replay_piecewise(self.topology, self.commit_log,
                                      engine=self.sim_engine)
        self.trace.replay_completions.update(comps)
        self.trace.commit_log = self.commit_log
        return comps


def run_online(scenario, *, horizon: float, seed: int = 0,
               process: str = "poisson", rate: float | None = None,
               batch_size: int = 1, method: str = "greedy",
               drain_queues: bool = True, finish: bool = False,
               pad_to: int | None = None,
               process_params: dict | None = None,
               fault_schedule=None, recovery: str = "requeue",
               max_retries: int = 3,
               deadline_s: float | None = None,
               admission=None, auto_replan=None,
               **solver_opts) -> OnlineTrace:
    """Drive a scenario through an arrival stream; return the trace.

    ``scenario`` is anything with ``.topology`` and
    ``.sample_jobs(rng, n) -> list[InferenceJob]`` —
    ``repro.scenarios.make_scenario(...)`` is the canonical source.

    **Process-params contract.**  ``process`` names an arrival process from
    ``repro.core.arrivals``; ``process_params`` are its keyword arguments,
    passed through verbatim and always winning over the ``rate`` shorthand.
    ``rate`` maps onto each built-in process's own parameters where the
    mapping is well-defined:

      * ``poisson`` / ``bursty`` — ``rate`` is the process's ``rate``;
      * ``diurnal`` — ``rate`` scales the whole profile: ``peak_rate =
        rate`` and ``base_rate = peak_rate / 5`` (the module defaults'
        5:1 peak:base ratio) unless given explicitly;
      * any other registered process — the shorthand is ambiguous, so
        passing ``rate`` raises ``ValueError``; use ``process_params``.

    ``drain_queues=False`` is the legacy no-drain baseline; pass
    ``drain="fluid" | "exact"`` / ``track_commits=True`` through to the
    scheduler to pick the drain model and keep a ground-truth commit log.
    ``finish=True`` completes the accounting after the last arrival: the
    exact ledger (if any) is served to completion into
    ``trace.completions`` and the commit log (if any) is replayed into
    ``trace.replay_completions``.

    ``fault_schedule`` (a :class:`~repro.serving.faults.FaultSchedule` or
    any iterable of :class:`~repro.serving.faults.FaultEvent`) injects
    infrastructure events between arrivals on the same clock; ``recovery``
    picks the policy for work caught on a failed resource (``"requeue"`` |
    ``"migrate"`` | ``"lost"``, with at most ``max_retries`` re-placements
    per job) — requires ``drain="exact"``.

    ``deadline_s`` attaches a uniform relative SLO to every sampled job
    (a job's own finite ``deadline_s`` wins); ``admission`` /
    ``auto_replan`` are forwarded to :class:`OnlineScheduler` — an
    :class:`~repro.serving.admission.AdmissionPolicy` (or its name) gates
    arrivals against predicted completions, a
    :class:`~repro.serving.admission.ReplanPolicy` (or ``True``) arms the
    SLO-guarded re-plan monitor, which is also consulted after every
    injected fault.  Still-deferred arrivals get one drain-out admission
    sweep after the last arrival, before ``finish``.
    """
    rng = np.random.default_rng(seed)
    params = A.resolve_rate(process, rate, process_params)
    times = A.make_process(process, **params)(rng, horizon)
    sched = OnlineScheduler(scenario.topology, method=method,
                            drain_queues=drain_queues, admission=admission,
                            auto_replan=auto_replan, **solver_opts)
    if pad_to is None:
        pad_to = getattr(scenario, "max_layers", None)
    injector, faults, fi = None, [], 0
    if fault_schedule is not None:
        from .faults import FaultInjector
        faults = sorted(fault_schedule, key=lambda ev: ev.time)
        injector = FaultInjector(sched, policy=recovery,
                                 max_retries=max_retries, pad_to=pad_to)
    for t in times:
        while fi < len(faults) and faults[fi].time <= float(t):
            injector.apply(faults[fi])
            fi += 1
            sched.check_replan()
        jobs = scenario.sample_jobs(rng, batch_size)
        if deadline_s is not None:
            jobs = [j if np.isfinite(j.deadline_s)
                    else j.with_deadline(deadline_s) for j in jobs]
        if injector is not None and sched.degraded:
            jobs = injector.filter_arrivals(float(t), jobs)
            if not jobs:
                continue
        sched.submit_jobs(float(t), jobs, pad_to=pad_to)
    while fi < len(faults) and faults[fi].time <= horizon:
        injector.apply(faults[fi])
        fi += 1
        sched.check_replan()
    sched.flush_deferred(pad_to=pad_to)
    if finish:
        if sched.ledger is not None:
            sched.finish()
        if sched.commit_log is not None:
            sched.replay_ground_truth()
    sched.trace.commit_log = sched.commit_log
    return sched.trace
