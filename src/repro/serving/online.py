"""Online serving loop: streamed arrivals against time-aware network state.

The static path solves one batch against a snapshot of the queues.  This
loop is the deployment setting: request batches arrive on a clock (Poisson,
bursty, diurnal — ``repro.core.arrivals``), and before each batch is solved
the scheduler **drains** the :class:`~repro.core.state.QueueState` to the
arrival time (fluid q <- max(q - mu dt, 0)) — the work committed by earlier
batches has been getting served in the meantime.  Under sub-capacity load
this keeps backlogs (and hence latency bounds) bounded; the legacy no-drain
commit loop (``drain=False``, the seed behaviour) only ever adds to Q and
diverges under any sustained traffic — ``benchmarks/online_bench.py``
captures both trajectories and ``tests/test_online.py`` asserts the
contrast.

``report_slowdown`` / ``replan_last`` are events on the same clock: a
straggler reported at time t degrades the *effective* topology from t on
(slower service and slower draining), and re-planning the last batch scores
it against the state at the current clock.

Per-arrival latency here is the fictitious-system completion bound of each
request measured from its arrival instant — the same quantity the solver
optimizes, now evaluated against a drained (time-correct) queue state.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import arrivals as A, jobs as J
from repro.core.state import Topology, backlog_seconds
from .scheduler import Placement, Request, RoutedScheduler, requests_to_jobs


@dataclasses.dataclass(frozen=True)
class ArrivalRecord:
    """What happened at one arrival epoch."""

    time: float
    names: tuple[str, ...]
    latencies: tuple[float, ...]     # per-request completion bounds (s)
    backlog_before: float            # worst-resource wait (s) after draining
    backlog_after: float             # ... after committing this batch
    solve_s: float


@dataclasses.dataclass
class OnlineTrace:
    """Recorded trajectory of one online run."""

    records: list[ArrivalRecord] = dataclasses.field(default_factory=list)
    events: list[dict] = dataclasses.field(default_factory=list)

    @property
    def times(self) -> np.ndarray:
        return np.array([r.time for r in self.records], np.float64)

    @property
    def backlogs(self) -> np.ndarray:
        """Post-commit worst-resource backlog (s) at each arrival."""
        return np.array([r.backlog_after for r in self.records], np.float64)

    @property
    def latencies(self) -> np.ndarray:
        return np.array([x for r in self.records for x in r.latencies],
                        np.float64)

    def percentile(self, q: float) -> float:
        lat = self.latencies
        return float(np.percentile(lat, q)) if lat.size else float("nan")

    def backlog_growth(self) -> float:
        """max backlog over the run's second half / first half.

        ~1 for a stable (drained) system that has reached steady state;
        grows without bound for the no-drain commit loop.
        """
        b = self.backlogs
        if b.size < 4:
            return float("nan")
        half = b.size // 2
        first = max(b[:half].max(), 1e-12)
        return float(b[half:].max() / first)

    def summary(self) -> dict:
        return {
            "arrivals": len(self.records),
            "requests": int(self.latencies.size),
            "p50_latency_s": self.percentile(50),
            "p99_latency_s": self.percentile(99),
            "max_backlog_s": float(self.backlogs.max()) if self.records else 0.0,
            "final_backlog_s": self.records[-1].backlog_after if self.records else 0.0,
            "backlog_growth": self.backlog_growth(),
        }

    def to_dict(self) -> dict:
        return {
            **self.summary(),
            "times": self.times.tolist(),
            "backlogs": self.backlogs.tolist(),
            "latencies": self.latencies.tolist(),
            "events": self.events,
        }


class OnlineScheduler(RoutedScheduler):
    """RoutedScheduler + a clock: drains state to each event before acting.

    ``drain=False`` reproduces the legacy behaviour (queues only grow) for
    divergence comparisons; everything else is identical, so any gap between
    the two runs is the drain semantics alone.
    """

    def __init__(self, net: Topology, *, method: str = "greedy",
                 drain_queues: bool = True, **solver_opts):
        super().__init__(net, method=method, **solver_opts)
        self.drain_queues = drain_queues
        self.trace = OnlineTrace()

    # -- clock --------------------------------------------------------------
    @property
    def now(self) -> float:
        """Event time == the scheduler's one authoritative clock."""
        return self.clock

    def advance_to(self, t: float) -> None:
        """Move the clock to absolute time ``t``, draining if enabled.

        The clock always advances — time passing and queue draining are
        independent; ``drain_queues=False`` freezes only the backlogs.
        """
        if t < self.now - 1e-9:
            raise ValueError(f"time went backwards: {t} < {self.now}")
        dt = max(t - self.now, 0.0)
        if dt > 0 and self.drain_queues:
            # drains at effective (health-aware) rates
            self.state = self.state.advance(self._effective_topology(), dt)
        self._now = max(self._now, float(t))
        self._stamp_clock()

    # -- events -------------------------------------------------------------
    def submit_jobs(self, t: float, infer_jobs: Sequence[J.InferenceJob],
                    *, pad_to: int | None = None) -> list[Placement]:
        """Arrival event: drain to ``t``, place the batch, record the epoch."""
        self.advance_to(t)
        eff = self._effective_topology()
        before = backlog_seconds(eff, self.state)
        placements = self.schedule_jobs(list(infer_jobs), pad_to=pad_to)
        after = backlog_seconds(eff, self.state)
        self.trace.records.append(ArrivalRecord(
            time=t,
            names=tuple(p.job_name for p in placements),
            latencies=tuple(p.bound_s for p in placements),
            backlog_before=before,
            backlog_after=after,
            solve_s=float(self.last_plan.meta.get("solve_s", 0.0)),
        ))
        return placements

    def submit(self, t: float, requests: list[Request],
               *, pad_to: int | None = None) -> list[Placement]:
        return self.submit_jobs(t, requests_to_jobs(requests), pad_to=pad_to)

    def report_slowdown(self, node: int, factor: float,
                        *, at: float | None = None) -> None:
        """Straggler event on the clock: drain to ``at`` (default: now),
        then degrade the node's effective rate from that instant on."""
        if at is not None:
            self.advance_to(at)
        super().report_slowdown(node, factor)
        self.trace.events.append({"time": self.now, "event": "slowdown",
                                  "node": int(node), "factor": float(factor)})

    def replan_last(self) -> list[Placement] | None:
        out = super().replan_last()
        if out is not None:
            self.trace.events.append({"time": self.now, "event": "replan",
                                      "bound_s": self.last_plan.bound()})
        return out


def run_online(scenario, *, horizon: float, seed: int = 0,
               process: str = "poisson", rate: float = 1.0,
               batch_size: int = 1, method: str = "greedy",
               drain_queues: bool = True, pad_to: int | None = None,
               process_params: dict | None = None,
               **solver_opts) -> OnlineTrace:
    """Drive a scenario through an arrival stream; return the trace.

    ``scenario`` is anything with ``.topology`` and
    ``.sample_jobs(rng, n) -> list[InferenceJob]`` —
    ``repro.scenarios.make_scenario(...)`` is the canonical source.
    ``process``/``rate`` name an arrival process from
    ``repro.core.arrivals`` (``rate`` is ignored by processes that take
    their own rate parameters via ``process_params``).
    """
    rng = np.random.default_rng(seed)
    params = dict(process_params or {})
    if process in ("poisson", "bursty") and "rate" not in params:
        params["rate"] = rate
    times = A.make_process(process, **params)(rng, horizon)
    sched = OnlineScheduler(scenario.topology, method=method,
                            drain_queues=drain_queues, **solver_opts)
    if pad_to is None:
        pad_to = getattr(scenario, "max_layers", None)
    for t in times:
        jobs = scenario.sample_jobs(rng, batch_size)
        sched.submit_jobs(float(t), jobs, pad_to=pad_to)
    return sched.trace
