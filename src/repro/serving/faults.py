"""Fault-injected dynamic infrastructure: typed events on the serving clock.

Everything below PR 6 assumed a topology that only ever *slows down*
(``report_slowdown``).  The target setting — 6G edge networks serving
ubiquitous AI — is defined by churn: nodes leave and rejoin, links cut,
capacity rescales with a lag.  This module is the event layer and the
recovery policies on top of the exact-drain machinery (ROADMAP item 5):

  * :class:`FaultEvent` / :class:`FaultSchedule` — typed infrastructure
    events on the authoritative clock: ``node_fail`` / ``node_recover``,
    ``node_join`` (standby capacity appearing mid-horizon), ``link_fail``
    / ``link_recover`` (bidirectional at this layer), and ``rescale``
    (elastic capacity change, with an actuation ``lag``).
  * :class:`FaultInjector` — applies events to an
    :class:`~repro.serving.online.OnlineScheduler`, draining to the event
    instant first so everything before it is served at pre-event rates.
    A failure strands the in-flight and queued work of every committed
    plan that still needs the dead resource; the injector withdraws those
    jobs from the ledger and handles their residual work per policy:

      ``requeue``   re-plan the remaining layers onto the surviving
                    topology with the regular solver, paying re-transfer
                    from the node holding the last completed layer's
                    output (layer-0 progress restarts from the source).
      ``migrate``   operator migration: the remaining layers move to one
                    chosen node (the ``"migrate"`` solver registered
                    here — argmin of the fictitious bound over surviving
                    compute nodes), paying the transfer — the
                    sparse-framework orchestrator's policy.
      ``lost``      shed the work and account it.

    Re-placement is *bounded*: each job carries a ``#r<n>`` retry suffix,
    and a job whose residual has been re-placed ``max_retries`` times —
    e.g. because a second failure hit its requeue target mid-recovery —
    is shed with ``retries_exhausted`` instead of looping.  Jobs whose
    progress (or source/destination) sits on the failed node are shed as
    ``data_lost`` / ``unreachable``; a solver exception during
    re-placement is retried once, then shed as ``solver_error``.

  * A scenario-catalog family (:data:`FAULT_FAMILIES` /
    :func:`make_fault_schedule`): transient-node, node-join, link-flap,
    elastic, cascade — each parameterized by the scenario and horizon,
    with :func:`pick_victim` choosing the highest-capacity compute node
    that is not an ingress/egress point.

Ground truth stays exact through all of this: availability events land in
the commit log's ``health`` history (``factor=inf`` = down) and
withdrawals in its ``removed`` records, so
:func:`repro.core.completions.replay_piecewise` replays the run segment
by segment — outages, blocked work, requeues and all — and must agree
with the incremental drain (``benchmarks/fault_bench.py`` gates it).

Training-side fault *tolerance* (checkpoint/rollback across data-parallel
replicas) lives in :mod:`repro.distributed.fault`; this module is the
serving-side counterpart where work is rerouted rather than recomputed
from a checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import numpy as np

from repro.core import jobs as J, routing, solvers
from repro.core.completions import LedgerJob
from repro.core.plan import Plan

KINDS = ("node_fail", "node_recover", "node_join", "link_fail",
         "link_recover", "rescale")
POLICIES = ("requeue", "migrate", "lost")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One typed infrastructure event at an absolute instant.

    ``node`` is the subject of node events and ``rescale``; ``link`` the
    (u, v) pair of link events — link failures are bidirectional here
    (the injector flips both directed links); ``factor`` is the
    ``rescale`` capacity multiple (2.0 = doubled, 0.5 = halved, absolute
    w.r.t. nominal — not cumulative).
    """

    time: float
    kind: str
    node: int = -1
    link: tuple[int, int] | None = None
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"valid: {', '.join(KINDS)}")
        if self.kind.startswith("link"):
            if self.link is None:
                raise ValueError(f"{self.kind} needs link=(u, v)")
        elif self.node < 0:
            raise ValueError(f"{self.kind} needs node=")
        if self.kind == "rescale" and not (np.isfinite(self.factor)
                                           and self.factor > 0):
            raise ValueError(
                f"rescale factor must be finite and > 0, got {self.factor}")
        if not np.isfinite(self.time):
            raise ValueError(f"event time must be finite, got {self.time}")


def node_fail(t: float, node: int) -> FaultEvent:
    return FaultEvent(float(t), "node_fail", node=int(node))


def node_recover(t: float, node: int) -> FaultEvent:
    return FaultEvent(float(t), "node_recover", node=int(node))


def node_join(t: float, node: int) -> FaultEvent:
    """Standby capacity joins at ``t`` (pair with a ``node_fail`` at the
    horizon start to model a node that wasn't there yet — keeps every
    array shape static/jit-stable)."""
    return FaultEvent(float(t), "node_join", node=int(node))


def link_fail(t: float, u: int, v: int) -> FaultEvent:
    return FaultEvent(float(t), "link_fail", link=(int(u), int(v)))


def link_recover(t: float, u: int, v: int) -> FaultEvent:
    return FaultEvent(float(t), "link_recover", link=(int(u), int(v)))


def capacity_rescale(t: float, node: int, scale: float,
                     *, lag: float = 0.0) -> FaultEvent:
    """Elastic capacity change: the node runs at ``scale`` x nominal from
    ``t + lag`` on (``lag`` models actuation delay — autoscalers don't
    take effect the instant they decide)."""
    return FaultEvent(float(t) + float(lag), "rescale", node=int(node),
                      factor=float(scale))


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A time-sorted sequence of fault events (construction sorts)."""

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events,
                                        key=lambda ev: ev.time)))

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def validate(self, num_nodes: int) -> "FaultSchedule":
        for ev in self.events:
            nodes = ev.link if ev.link is not None else (ev.node,)
            for u in nodes:
                if not (0 <= int(u) < num_nodes):
                    raise ValueError(
                        f"fault event {ev} references node {u} outside "
                        f"[0, {num_nodes})")
        return self


# -- scenario catalog ---------------------------------------------------------

def pick_victims(scenario, n: int = 1) -> list[int]:
    """The ``n`` highest-capacity compute nodes that are not ingress/egress
    points (falling back to ingress/egress compute nodes when the family
    has no interior compute) — the nodes whose failure actually strands
    committed work without severing the traffic endpoints."""
    mu = np.asarray(scenario.topology.mu_node, np.float64)
    endpoints = set(scenario.ingress) | set(scenario.egress)
    ranked = [int(u) for u in np.argsort(-mu) if mu[u] > 0]
    cand = [u for u in ranked if u not in endpoints]
    cand += [u for u in ranked if u in endpoints]   # fallback pool
    if len(cand) < n:
        raise ValueError(
            f"scenario {scenario.name!r} has only {len(cand)} "
            f"compute-capable node(s); cannot pick {n} victims")
    return cand[:n]


def pick_victim(scenario) -> int:
    return pick_victims(scenario, 1)[0]


def pick_victim_link(scenario) -> tuple[int, int]:
    """The victim node's highest-capacity outgoing link."""
    v = pick_victim(scenario)
    mu_l = np.asarray(scenario.topology.mu_link, np.float64)
    w = int(np.argmax(mu_l[v]))
    if mu_l[v, w] <= 0:
        raise ValueError(f"victim node {v} of scenario {scenario.name!r} "
                         f"has no outgoing links")
    return v, w


def _transient_node(scenario, horizon: float) -> list[FaultEvent]:
    v = pick_victim(scenario)
    return [node_fail(0.35 * horizon, v), node_recover(0.65 * horizon, v)]


def _node_join(scenario, horizon: float) -> list[FaultEvent]:
    v = pick_victim(scenario)
    return [node_fail(0.0, v), node_join(0.45 * horizon, v)]


def _link_flap(scenario, horizon: float) -> list[FaultEvent]:
    u, v = pick_victim_link(scenario)
    out = []
    for a, b in ((0.30, 0.40), (0.50, 0.60)):
        out += [link_fail(a * horizon, u, v), link_recover(b * horizon, u, v)]
    return out


def _elastic(scenario, horizon: float) -> list[FaultEvent]:
    v = pick_victim(scenario)
    lag = 0.05 * horizon
    return [capacity_rescale(0.30 * horizon, v, 0.5, lag=lag),
            capacity_rescale(0.65 * horizon, v, 1.0, lag=lag)]


def _cascade(scenario, horizon: float) -> list[FaultEvent]:
    """A second failure lands mid-recovery of the first: requeued work can
    be hit again, exercising the bounded-retry path."""
    v1, v2 = pick_victims(scenario, 2)
    return [node_fail(0.30 * horizon, v1), node_fail(0.45 * horizon, v2),
            node_recover(0.70 * horizon, v1),
            node_recover(0.80 * horizon, v2)]


FAULT_FAMILIES = {
    "transient-node": _transient_node,
    "node-join": _node_join,
    "link-flap": _link_flap,
    "elastic": _elastic,
    "cascade": _cascade,
}


def make_fault_schedule(family: str, scenario, horizon: float,
                        *, seed: int = 0) -> FaultSchedule:
    """Build a named fault schedule against a scenario and horizon.

    ``seed`` jitters each event time by up to ±2% of the horizon (event
    order is preserved by construction — the nominal instants are spaced
    wider than the jitter), so repeated benchmark runs don't all fault at
    the same phase of the arrival process.
    """
    try:
        gen = FAULT_FAMILIES[family]
    except KeyError:
        raise ValueError(
            f"unknown fault family {family!r}; available: "
            f"{', '.join(sorted(FAULT_FAMILIES))}") from None
    rng = np.random.default_rng(seed)
    events = []
    for ev in gen(scenario, float(horizon)):
        jitter = float((rng.random() - 0.5) * 0.04 * horizon)
        events.append(dataclasses.replace(
            ev, time=float(np.clip(ev.time + jitter, 0.0, horizon))))
    return FaultSchedule(tuple(events)).validate(scenario.num_nodes)


# -- the migrate solver -------------------------------------------------------

@solvers.register("migrate")
def migrate_solve(net, batch, **opts) -> Plan:
    """Operator migration: each job's (remaining) layers on ONE node.

    For every job, the fictitious completion bound of the all-layers-on-w
    assignment is evaluated for every surviving compute node w (one vmap
    over candidates, closures built once per job) and the argmin is
    committed — transfers in (from wherever the job's data sits) and out
    (to its destination) are paid through the same min-cost paths as any
    other plan.  Jobs are placed in batch order (= priority order), each
    against the queues its predecessors built, exactly like the greedy
    solver — so migrated work keeps spreading over nodes instead of
    piling onto one.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import shortest_path as SP

    mu = np.asarray(net.mu_node, np.float64)
    cand = np.flatnonzero(mu > 0)
    if cand.size == 0:
        raise ValueError("migrate: no compute-capable node is available")
    Jn, Lmax = batch.num_jobs, batch.max_layers
    assign = np.zeros((Jn, Lmax), np.int32)
    bounds = np.zeros((Jn,), np.float64)
    cand_assign = jnp.asarray(np.repeat(cand[:, None], Lmax, axis=1),
                              jnp.int32)                      # [C, Lmax]
    cur = net
    for j in range(Jn):
        args = (batch.comp[j], batch.data[j], batch.src[j], batch.dst[j],
                batch.num_layers[j])
        cl = SP.build_closures(cur, batch.data[j])
        costs = jax.vmap(
            lambda a, _net=cur, _args=args, _cl=cl:
            routing.cost_given_assignment(_net, *_args, a, closures=_cl)
        )(cand_assign)
        best = int(np.argmin(np.asarray(costs)))
        w = int(cand[best])
        bounds[j] = float(np.asarray(costs)[best])
        assign[j, :] = w
        cur = routing.commit_assignment(
            cur, *args, jnp.full((Lmax,), w, jnp.int32), closures=cl)
    return Plan.from_order(assign, np.arange(Jn, dtype=np.int32), bounds,
                           solver="migrate", net=cur,
                           meta={"n_routings": int(Jn) * int(cand.size)})


# -- the injector -------------------------------------------------------------

def _parse_retry(name: str) -> tuple[str, int]:
    """``"x#r2" -> ("x", 2)``; names without a retry suffix are attempt 0."""
    base, sep, n = name.rpartition("#r")
    if sep and n.isdigit():
        return base, int(n)
    return name, 0


class FaultInjector:
    """Applies :class:`FaultEvent`s to an online scheduler, handling the
    stranded work of failed resources per recovery ``policy``.

    Requires ``drain="exact"``: the recovery policies reconstruct each
    affected job's residual (remaining layers + the node holding its last
    completed layer's output) from the committed-work ledger — the fluid
    model has no per-job progress to recover from.
    """

    def __init__(self, sched, *, policy: str = "requeue",
                 max_retries: int = 3, pad_to: int | None = None):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        if getattr(sched, "ledger", None) is None:
            raise ValueError(
                "fault injection requires drain='exact': recovery policies "
                "reconstruct residual jobs from the committed-work ledger")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.sched = sched
        self.policy = policy
        self.max_retries = int(max_retries)
        self.pad_to = pad_to
        self.log: list[dict] = []

    def apply(self, ev: FaultEvent) -> dict:
        """Drain to the event instant (pre-event rates), apply it, and —
        for failures — withdraw and re-place/shed stranded work.  Returns
        (and logs) a record of what happened."""
        sched = self.sched
        sched.advance_to(ev.time)
        rec: dict = {"time": float(ev.time), "event": ev.kind,
                     "policy": self.policy}
        if ev.kind == "rescale":
            rec["node"], rec["factor"] = ev.node, ev.factor
            sched.report_slowdown(ev.node, 1.0 / ev.factor)
        elif ev.kind in ("node_recover", "node_join"):
            rec["node"] = ev.node
            sched.set_node_availability(ev.node, True)
        elif ev.kind == "link_recover":
            u, v = ev.link
            rec["link"] = (u, v)
            sched.set_link_availability(u, v, True)
            sched.set_link_availability(v, u, True)
        elif ev.kind == "node_fail":
            rec["node"] = ev.node
            sched.set_node_availability(ev.node, False)
            self._handle_outage(rec)
        else:  # link_fail
            u, v = ev.link
            rec["link"] = (u, v)
            sched.set_link_availability(u, v, False)
            sched.set_link_availability(v, u, False)
            self._handle_outage(rec)
        self.log.append(rec)
        return rec

    # -- outage handling -----------------------------------------------------
    def _handle_outage(self, rec: dict) -> None:
        sched = self.sched
        now = sched.now
        downs = set(sched._down_keys())
        affected = [job for job in sched.ledger.jobs
                    if any(job.stages[k][0] in downs
                           for k in range(job.ptr, len(job.stages)))]
        rec["affected"] = [j.name for j in affected]
        rec["requeued"], rec["lost"] = [], []
        if not affected:
            return
        residuals = [self._residual(job) for job in affected]
        names = [job.name for job in affected]
        sched.ledger = sched.ledger.remove_jobs(names, at=now)
        if sched.commit_log is not None:
            sched.commit_log = sched.commit_log.record_removal(now, names)
        sched._sync_ledger_queues()
        # The pre-batch snapshot may straddle the outage; a replan_last
        # rollback would resurrect the withdrawn jobs.
        sched._last = None
        viable: list[tuple[J.InferenceJob, float]] = []
        for orig, new_job, arrival, reason in residuals:
            if self.policy == "lost":
                self._lose(now, rec, orig, "failed_resource")
            elif new_job is None:
                self._lose(now, rec, orig, reason)
            else:
                viable.append((new_job, arrival))
        if viable:
            self._resubmit(now, rec, viable)

    def _resubmit(self, now: float, rec: dict,
                  viable: list[tuple[J.InferenceJob, float]]) -> None:
        sched = self.sched
        jobs = [j for j, _ in viable]
        arrs = [a for _, a in viable]
        method = "migrate" if self.policy == "migrate" else None
        for attempt in (0, 1):
            try:
                sched.submit_window(now, jobs, arrivals=arrs,
                                    pad_to=self.pad_to, method=method)
                rec["requeued"].extend(j.name for j in jobs)
                return
            except Exception as e:  # noqa: BLE001 — serving must survive
                err = e
        for j in jobs:
            self._lose(now, rec, j.name, "solver_error", error=repr(err))

    def _lose(self, t: float, rec: dict | None, name: str, reason: str,
              **extra) -> None:
        self.sched.trace.lost.append((name, reason))
        self.sched.trace.events.append(
            {"time": float(t), "event": "lost", "name": name,
             "reason": reason, **extra})
        if rec is not None:
            rec["lost"].append((name, reason))

    # -- residual reconstruction ---------------------------------------------
    def _residual(self, job: LedgerJob):
        """(orig name, residual InferenceJob | None, original arrival,
        shed reason) for one stranded ledger job.

        Completed layers stay completed: the residual restarts from the
        node holding the last finished layer's output (its transfer hops
        re-pay from there — partial hop progress of the *current* layer
        is forfeit, the re-transfer cost the tentpole prices in).  A job
        whose remaining work is only the final delivery becomes a
        1-FLOP, 2-transfer job (the formulation has no compute-free
        jobs; one FLOP is noise at 1e9-FLOP/s scales).
        """
        sched = self.sched
        base, retry = _parse_retry(job.name)
        arrival = sched.trace.arrivals_by_name.get(job.name, job.release)
        src_job = sched.inflight_jobs.get(job.name)
        if src_job is None:
            return job.name, None, arrival, "no_registry_entry"
        if retry + 1 > self.max_retries:
            return job.name, None, arrival, "retries_exhausted"
        k = sum(1 for res, _ in job.stages[:job.ptr] if res[0] == "node")
        if k == 0:
            loc = int(src_job.src)
        else:
            loc = next(int(res[1]) for res, _ in
                       reversed(job.stages[:job.ptr]) if res[0] == "node")
        if not sched._avail_node[loc]:
            return job.name, None, arrival, "data_lost"
        dst = int(src_job.dst)
        if not self.routable(loc, dst):
            return job.name, None, arrival, "unreachable"
        L = src_job.num_layers
        if k >= L:
            comp = np.array([1.0], np.float32)
            data = np.array([src_job.data[L], src_job.data[L]], np.float32)
        else:
            comp = np.asarray(src_job.comp[k:], np.float32)
            data = np.asarray(src_job.data[k:], np.float32)
        name = f"{base}#r{retry + 1}"
        return job.name, J.InferenceJob(name, loc, dst, comp, data), \
            arrival, ""

    # -- routability ---------------------------------------------------------
    def routable(self, src: int, dst: int) -> bool:
        """True iff a job from ``src`` to ``dst`` is serveable on the
        surviving topology: both endpoints up, and some available compute
        node lies on a surviving directed path src -> w -> dst (every plan
        needs at least one compute stage, so src -> dst connectivity alone
        is not enough when the only live route bypasses all compute)."""
        sched = self.sched
        avail = sched._avail_node
        if not (avail[src] and avail[dst]):
            return False
        adj = ((np.asarray(sched.topology.mu_link) > 0) & sched._link_up
               & avail[:, None] & avail[None, :])
        fwd = _bfs(src, adj)
        if not fwd[dst]:
            return False
        bwd = _bfs(dst, adj.T)
        compute = (np.asarray(sched.topology.mu_node) > 0) & avail
        return bool((compute & fwd & bwd).any())

    def filter_arrivals(self, t: float,
                        jobs: list[J.InferenceJob]) -> list[J.InferenceJob]:
        """Drop (and account as lost) arrivals that cannot be served on the
        current surviving topology — a request entering at a dead or
        partitioned ingress has nowhere to go; committing it anyway would
        seat work on dead resources.  Drivers call this only while the
        scheduler is degraded, so the healthy path is untouched."""
        kept = []
        for job in jobs:
            if self.routable(int(job.src), int(job.dst)):
                kept.append(job)
            else:
                self._lose(t, None, job.name, "arrival_unroutable")
        return kept


def _bfs(start: int, adj: np.ndarray) -> np.ndarray:
    """[V] bool reachability (including ``start``) over a directed
    adjacency matrix."""
    seen = np.zeros(adj.shape[0], bool)
    seen[start] = True
    frontier = [int(start)]
    while frontier:
        nxt = []
        for u in frontier:
            for v in np.flatnonzero(adj[u] & ~seen):
                seen[v] = True
                nxt.append(int(v))
        frontier = nxt
    return seen


def schedule_from(events: Iterable[FaultEvent]) -> FaultSchedule:
    """Convenience: a :class:`FaultSchedule` from any event iterable."""
    return FaultSchedule(tuple(events))
